"""Optimizer math, checkpoint internals, and the serving runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OptConfig, apply_update, init_opt_state, lr_schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["adamw", "adamw_bf16", "sgdm", "adafactor"])
def test_optimizer_descends_quadratic(kind):
    cfg = OptConfig(kind=kind, lr=0.05, weight_decay=0.0, warmup_steps=1, total_steps=200)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "m": jnp.zeros((2, 3))}
    state = init_opt_state(cfg, params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, metrics = apply_update(cfg, params, g, state)
    assert float(loss(params)) < 0.2 * l0, f"{kind} failed to descend"
    assert np.isfinite(metrics["grad_norm"])


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]  # decay
    assert lrs[4] >= 0.09 * cfg.lr  # floor ≈ 10%


def test_adamw_bf16_moments_dtype():
    cfg = OptConfig(kind="adamw_bf16")
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = init_opt_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert state["v"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpoint internals
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_bf16(tmp_path):
    from repro.ckpt import load_checkpoint, save_checkpoint

    params = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "nested": [{"b": jnp.ones((3,), jnp.float32)}],
    }
    save_checkpoint(tmp_path, 7, params, sampler_state={"epoch": 1, "cursor": 9})
    out = load_checkpoint(tmp_path, params)
    assert out["step"] == 7
    assert out["sampler"] == {"epoch": 1, "cursor": 9}
    for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    from repro.ckpt import CheckpointManager, latest_step

    params = {"w": jnp.zeros(2)}
    mgr = CheckpointManager(tmp_path, every=1, keep=2)
    for step in (1, 2, 3, 4):
        assert mgr.maybe_save(step, params, {"step": jnp.int32(step)})
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert latest_step(tmp_path) == 4


def test_checkpoint_atomic_no_partial(tmp_path):
    from repro.ckpt import save_checkpoint

    save_checkpoint(tmp_path, 1, {"w": jnp.zeros(4)})
    assert not list(tmp_path.glob(".tmp_*"))
    assert (tmp_path / "step_00000001" / "meta.json").exists()


# ---------------------------------------------------------------------------
# serving runtime
# ---------------------------------------------------------------------------
def test_batch_server_generates():
    pytest.importorskip("repro.dist", reason="dist subsystem not built yet")
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.runtime import BatchServer

    cfg = get_smoke_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch_size=2, prompt_len=8, max_new=4)
    results = server.generate(["hello", "world", "third prompt"])  # ragged tail batch
    assert len(results) == 3
    for r in results:
        assert len(r.token_ids) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.token_ids)
        # greedy sampling must never pick a padding column
        assert all(t < cfg.vocab_size for t in r.token_ids)

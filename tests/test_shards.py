"""Sharded record store tests: format round trip + crc, zero-copy mmap
reads, corruption as per-sample holes, LRU-by-bytes cache eviction,
prefetch dedup, and shard-aware sampler checkpoint/resume."""

import threading
import time

import numpy as np
import pytest

from repro.data import (
    ArrayDataset,
    CheckpointableSampler,
    LocalShardSource,
    ShardCorruption,
    ShardDataset,
    ShardPrefetcher,
    ShardReader,
    ShardWriter,
    SimulatedLatencySource,
    SyntheticImageDataset,
    build_image_loader,
    decode_sample,
    encode_sample,
    pack,
)

# ---------------------------------------------------------------------------
# format: writer -> reader round trip
# ---------------------------------------------------------------------------
def test_writer_reader_byte_exact_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    blobs = [
        encode_sample(rng.integers(0, 256, (rng.integers(4, 64),), dtype=np.uint8))
        for _ in range(17)
    ]
    path = tmp_path / "one.rpshard"
    with ShardWriter(path) as w:
        for j, b in enumerate(blobs):
            assert w.add(b) == j
    with ShardReader(path) as r:
        assert len(r) == 17
        for j, b in enumerate(blobs):
            assert bytes(r.read(j)) == b  # byte-exact, crc verified (default)


def test_reader_rejects_unfinalized_and_foreign_files(tmp_path):
    # crashed writer: header is still the zero placeholder
    w = ShardWriter(tmp_path / "crash.rpshard")
    w.add(b"payload")
    with pytest.raises(ShardCorruption):
        ShardReader(tmp_path / "crash.rpshard")
    w.close()
    ShardReader(tmp_path / "crash.rpshard").close()  # finalized: now valid

    (tmp_path / "foreign.bin").write_bytes(b"GIF89a" + b"\0" * 64)
    with pytest.raises(ShardCorruption):
        ShardReader(tmp_path / "foreign.bin")


def test_crc_detects_flipped_bit(tmp_path):
    path = tmp_path / "s.rpshard"
    blob = encode_sample(np.arange(100, dtype=np.int32))
    with ShardWriter(path) as w:
        w.add(blob)
        w.add(blob)
    r = ShardReader(path)
    off = int(r.offsets[1]) + 10
    r.close()
    raw = bytearray(path.read_bytes())
    raw[off] ^= 0xFF
    path.write_bytes(raw)
    r = ShardReader(path)
    r.read(0)  # sibling sample unaffected
    with pytest.raises(ShardCorruption):
        r.read(1)
    r.read(1, verify=False)  # opt-out skips the crc pass
    r.close()


def test_mmap_reads_are_zero_copy(tmp_path):
    """Buffer-aliasing probe: every read of a sample is a view over the one
    shard mapping, not a fresh copy."""
    path = tmp_path / "s.rpshard"
    with ShardWriter(path) as w:
        w.add(b"a" * 1000)
        w.add(b"b" * 1000)
    with ShardReader(path) as r:
        v1, v2 = r.read(0), r.read(0)
        assert isinstance(v1, memoryview)
        assert v1.obj is v2.obj  # same exporter: the shard's mmap
        assert np.shares_memory(
            np.frombuffer(v1, np.uint8), np.frombuffer(v2, np.uint8)
        )
        # distinct samples alias the same mapping at different offsets
        assert r.read(1).obj is v1.obj
        assert not np.shares_memory(
            np.frombuffer(v1, np.uint8), np.frombuffer(r.read(1), np.uint8)
        )


# ---------------------------------------------------------------------------
# pack migration + ShardDataset
# ---------------------------------------------------------------------------
def test_pack_arraydataset_roundtrip(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 21, hw=(16, 16), seed=3)
    sds = pack(ArrayDataset(tmp_path / "src"), tmp_path / "packed", samples_per_shard=8)
    assert len(sds) == 21
    assert sds.shard_sizes == [8, 8, 5]
    for i in range(21):
        np.testing.assert_array_equal(sds[i], ds[i])
        assert bytes(sds.read_bytes(i)) == ds.read_bytes(i)
    assert [sds.shard_of(i) for i in (0, 7, 8, 20)] == [0, 0, 1, 2]


def test_pack_rolls_on_byte_budget(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 10, hw=(32, 32), seed=0)
    blob_len = len(ds.read_bytes(0))
    sds = pack(
        ds, tmp_path / "packed", samples_per_shard=1000, max_shard_bytes=2 * blob_len
    )
    assert len(sds) == 10
    assert sds.num_shards >= 4  # ~2 samples per shard
    for i in range(10):
        np.testing.assert_array_equal(sds[i], ds[i])


def test_sharddataset_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        ShardDataset(tmp_path)


def test_sharddataset_pickles_in_local_mode_only(tmp_path):
    """The multiprocessing baselines pickle the dataset into workers: local
    mode must survive (reopening mmaps lazily per process), remote mode
    must refuse with a clear error."""
    import pickle

    ds = SyntheticImageDataset.materialize(tmp_path / "src", 12, hw=(8, 8), seed=0)
    sds = pack(ds, tmp_path / "packed", samples_per_shard=4)
    sds.read_bytes(0)  # open a live reader: pickling must drop it
    clone = pickle.loads(pickle.dumps(sds))
    for i in range(12):
        np.testing.assert_array_equal(clone[i], ds[i])

    pf = ShardPrefetcher(
        LocalShardSource(tmp_path / "packed"), tmp_path / "cache", max_bytes=1 << 20
    )
    remote = ShardDataset(tmp_path / "packed", prefetcher=pf)
    with pytest.raises(TypeError, match="cannot be pickled"):
        pickle.dumps(remote)
    remote.close()


def test_corrupt_sample_is_a_hole_not_pipeline_death(tmp_path):
    """A flipped bit in one packed sample holes out that sample only: the
    loader keeps emitting dense batches and counts the failure."""
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 24, hw=(16, 16), seed=1)
    sds = pack(ds, tmp_path / "packed", samples_per_shard=8)
    # corrupt two samples in shard 1 (payload bytes, middle of the blob)
    shard_path = sds.root / sds.shard_names[1]
    r = ShardReader(shard_path)
    offsets = [int(r.offsets[k]) + 12 for k in (2, 5)]
    r.close()
    raw = bytearray(shard_path.read_bytes())
    for off in offsets:
        raw[off] ^= 0xFF
    shard_path.write_bytes(raw)

    sds = ShardDataset(tmp_path / "packed")
    p = build_image_loader(
        sds,
        batch_size=6,
        hw=(8, 8),
        num_threads=4,
        sampler=CheckpointableSampler(len(sds), batch_size=1, shuffle=False),
    )
    with p.auto_stop():
        batches = list(p)
    assert len(batches) == 3  # 22 good samples -> 3 full batches of 6
    stats = {s.name: s for s in p.stats()}
    assert stats["read"].num_failed == 2  # crc caught both at read time


def test_all_tail_failures_do_not_pin_a_slab(tmp_path):
    """A stream whose final samples ALL fail leaves the binder's last slab
    assigned-but-unsealed with no ref ever reaching the aggregate stage;
    the EOF seal_pending sweep must recycle it, so a drained pipeline holds
    exactly as many slabs as an all-clean run (the transfer hold window)."""
    in_flight = {}
    for corrupt_tail in (False, True):
        ds = SyntheticImageDataset.materialize(
            tmp_path / f"src{corrupt_tail}", 22, hw=(16, 16), seed=2
        )
        sds = pack(ds, tmp_path / f"packed{corrupt_tail}", samples_per_shard=8)
        if corrupt_tail:
            shard_path = sds.root / sds.shard_names[-1]
            r = ShardReader(shard_path)
            offsets = [int(r.offsets[k]) + 12 for k in (len(r) - 2, len(r) - 1)]
            r.close()
            raw = bytearray(shard_path.read_bytes())
            for off in offsets:
                raw[off] ^= 0xFF
            shard_path.write_bytes(raw)
            sds = ShardDataset(sds.root)
        p = build_image_loader(
            sds,
            batch_size=4,
            hw=(16, 16),
            num_threads=4,
            sampler=CheckpointableSampler(len(sds), batch_size=1, shuffle=False),
        )
        with p.auto_stop():
            n = sum(1 for _ in p)
        assert n == 5  # 22 (or 20 good) samples -> 5 full batches of 4
        in_flight[corrupt_tail] = {s.name: s for s in p.stats()}["batch"].slabs_in_flight
    assert in_flight[True] == in_flight[False]


# ---------------------------------------------------------------------------
# cache + prefetcher
# ---------------------------------------------------------------------------
def _remote_fixture(tmp_path, n=40, per_shard=8, latency_s=0.0, **pf_kw):
    ds = SyntheticImageDataset.materialize(tmp_path / "src", n, hw=(16, 16), seed=0)
    pack(ds, tmp_path / "remote", samples_per_shard=per_shard)
    src = SimulatedLatencySource(
        LocalShardSource(tmp_path / "remote"), latency_s=latency_s
    )
    pf = ShardPrefetcher(src, tmp_path / "cache", **pf_kw)
    return ds, ShardDataset(tmp_path / "remote", prefetcher=pf), src, pf


def test_cache_eviction_respects_byte_budget(tmp_path):
    ds, rds, src, pf = _remote_fixture(tmp_path, max_bytes=1, max_inflight=1)
    # budget of 1 byte: at most one shard resident (the floor keeps the
    # newest), every new shard evicts the previous one
    shard_bytes = max((rds.root / n).stat().st_size for n in rds.shard_names)
    for i in range(len(rds)):
        np.testing.assert_array_equal(rds[i], ds[i])
        st = pf.stats()
        assert st["bytes_cached"] <= shard_bytes  # never more than the floor
    st = pf.stats()
    assert st["evictions"] == rds.num_shards - 1
    cached_files = [f for f in pf.cache_dir.iterdir() if f.suffix == ".rpshard"]
    assert len(cached_files) == 1  # evicted files were unlinked
    rds.close()


def test_cache_eviction_is_lru(tmp_path):
    ds, rds, src, pf = _remote_fixture(tmp_path, max_bytes=1, max_inflight=1)
    a, b = rds.shard_names[0], rds.shard_names[1]
    pf.reader(a)
    pf.reader(b)  # budget of 1 byte: installing b evicts a
    st = pf.stats()
    assert st["evictions"] == 1
    assert not (pf.cache_dir / a).exists()
    assert (pf.cache_dir / b).exists()
    pf.reader(b)
    assert pf.stats()["hits"] == 1  # b stayed resident
    rds.close()


def test_eviction_keeps_inflight_views_valid(tmp_path):
    """Evicting a shard unlinks its file but reads already handed out keep
    working (the mapping outlives the unlink)."""
    ds, rds, src, pf = _remote_fixture(tmp_path, max_bytes=1, max_inflight=1)
    view = rds.read_bytes(0)  # shard 0 resident, view into its mmap
    for i in range(8, len(rds)):  # touch every other shard: shard 0 evicted
        rds.read_bytes(i)
    assert not (pf.cache_dir / rds.shard_names[0]).exists()
    np.testing.assert_array_equal(decode_sample(view), ds[0])  # still valid
    rds.close()


def test_concurrent_readers_share_one_fetch(tmp_path):
    ds, rds, src, pf = _remote_fixture(
        tmp_path, latency_s=0.02, max_bytes=10**8, max_inflight=2
    )
    errs = []

    def hammer():
        try:
            for i in range(0, len(rds), 3):
                np.testing.assert_array_equal(rds[i], ds[i])
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    # every shard crossed the wire exactly once (+1 for the manifest)
    assert src.fetches == rds.num_shards + 1
    rds.close()


def test_schedule_overlaps_fetch_and_is_advisory(tmp_path):
    ds, rds, src, pf = _remote_fixture(
        tmp_path, latency_s=0.02, max_bytes=10**8, max_inflight=2
    )
    assert pf.schedule(rds.shard_names[0]) is True
    assert pf.schedule(rds.shard_names[0]) is False  # already in flight
    assert pf.stats()["prefetch_depth"] >= 1
    np.testing.assert_array_equal(rds[0], ds[0])  # joins the fetch
    assert pf.schedule(rds.shard_names[0]) is False  # now cached
    assert src.fetches == 2  # manifest + shard 0, despite 3 schedule calls
    rds.close()


def test_manifest_sample_meta_spares_construction_fetch(tmp_path):
    """pack records sample 0's dtype/shape in the manifest; building a
    loader over a remote dataset must sniff from that instead of
    downloading a shard before the pipeline even starts."""
    ds, rds, src, pf = _remote_fixture(tmp_path, max_bytes=1 << 30)
    assert rds.sample_meta == (np.dtype(np.uint8), (16, 16, 3))
    p = build_image_loader(
        rds,
        batch_size=8,
        hw=(16, 16),
        sampler=CheckpointableSampler(len(rds), batch_size=1, shuffle=False),
    )
    assert src.fetches == 1  # manifest only: no shard crossed the wire yet
    with p.auto_stop():
        next(iter(p))
    rds.close()


@pytest.mark.slow
def test_remote_shard_pipeline_end_to_end(tmp_path):
    """Full loader over a simulated-latency remote source: cold epoch pays
    the fetches, the dashboard shows cache counters, batches are correct."""
    ds, rds, src, pf = _remote_fixture(
        tmp_path, n=48, per_shard=8, latency_s=0.01, max_bytes=10**8, max_inflight=2
    )
    sampler = CheckpointableSampler(
        len(rds),
        batch_size=1,
        seed=5,
        shard_sizes=rds.shard_sizes,
        shard_window=16,
    )
    p = build_image_loader(
        rds, batch_size=8, hw=(16, 16), num_threads=4, sampler=sampler, epochs=2
    )
    with p.auto_stop():
        batches = list(p)
    assert len(batches) == 12  # 6 batches/epoch x 2 epochs
    for b in batches:
        assert np.asarray(b["images"]).shape == (8, 16, 16, 3)
    stats = {s.name: s for s in p.stats()}
    read = stats["read"]
    assert read.num_failed == 0
    assert read.cache_hits + read.cache_misses >= 96
    assert read.cache_hits > read.cache_misses  # the cache pulls its weight
    assert src.fetches == rds.num_shards + 1  # epoch 2 fully warm
    assert "shard-cache" in p.format_stats()
    rds.close()


# ---------------------------------------------------------------------------
# shard-aware sampler
# ---------------------------------------------------------------------------
SHARD_SIZES = [8] * 6


def test_shard_sampler_covers_epoch_once():
    s = CheckpointableSampler(
        48, batch_size=4, seed=2, shard_sizes=SHARD_SIZES, shard_window=8
    )
    it = iter(s)
    seen = [i for _ in range(s.batches_per_epoch()) for i in next(it)]
    assert sorted(seen) == list(range(48))


def test_shard_sampler_window_preserves_locality():
    """Two properties the shard cache relies on: (a) the sample emitted at
    position k is never more than ``window`` ahead of the shard-ordered
    stream front (a shard is never needed before its turn), and (b) under a
    fixed seed, consecutive samples touch far fewer distinct shards than a
    uniform global shuffle would."""
    window = 8
    n, seed = 48, 2
    s = CheckpointableSampler(
        n, batch_size=4, seed=seed, shard_sizes=SHARD_SIZES, shard_window=window
    )
    order = s._epoch_order(0)
    starts = np.concatenate(([0], np.cumsum(SHARD_SIZES)))
    # reconstruct the pre-window-shuffle stream (shard permutation is the
    # generator's first draw, same as in _epoch_order)
    rng = np.random.default_rng((seed, 0))
    stream = np.concatenate(
        [np.arange(starts[t], starts[t + 1]) for t in rng.permutation(len(SHARD_SIZES))]
    )
    stream_pos = {int(v): k for k, v in enumerate(stream)}
    for k, v in enumerate(order):
        assert stream_pos[int(v)] < k + window  # (a): bounded lookahead

    def mean_distinct(idx: np.ndarray, run: int = 8) -> float:
        shard_of = lambda i: int(np.searchsorted(starts, i, side="right")) - 1
        spans = [
            len({shard_of(int(i)) for i in idx[k : k + run]})
            for k in range(0, len(idx) - run)
        ]
        return float(np.mean(spans))

    uniform = CheckpointableSampler(n, batch_size=4, seed=seed)._epoch_order(0)
    assert mean_distinct(order) < mean_distinct(uniform)  # (b): locality


def test_shard_sampler_resume_no_gap_no_overlap():
    kw = dict(batch_size=4, seed=9, shard_sizes=SHARD_SIZES, shard_window=8)
    s1 = CheckpointableSampler(48, **kw)
    it1 = iter(s1)
    first = [next(it1) for _ in range(5)]
    state = s1.state_dict()

    s2 = CheckpointableSampler(48, **kw)
    s2.load_state_dict(state)
    it2 = iter(s2)
    rest = [next(it2) for _ in range(7)]
    assert rest == [next(it1) for _ in range(7)]
    epoch0 = [i for b in first + rest for i in b]
    assert sorted(epoch0) == list(range(48))


def test_shard_sampler_rejects_mismatched_sizes():
    with pytest.raises(ValueError, match="shard_sizes"):
        CheckpointableSampler(10, batch_size=2, shard_sizes=[4, 4])


def test_shard_sampler_checkpoint_rejects_changed_shard_layout():
    """The epoch order depends on (shard_sizes, shard_window): a MID-EPOCH
    checkpoint resumed under a different layout must fail loudly, not
    silently repeat/skip samples.  A cursor-0 checkpoint consumed nothing,
    so any layout may resume there."""
    s1 = CheckpointableSampler(48, batch_size=4, seed=1, shard_sizes=[8] * 6)
    it = iter(s1)
    for _ in range(3):
        next(it)
    state = s1.state_dict()  # mid-epoch: cursor == 3
    s2 = CheckpointableSampler(48, batch_size=4, seed=1, shard_sizes=[16] * 3)
    with pytest.raises(AssertionError, match="shard configuration"):
        s2.load_state_dict(state)
    s3 = CheckpointableSampler(
        48, batch_size=4, seed=1, shard_sizes=[8] * 6, shard_window=7
    )
    with pytest.raises(AssertionError, match="shard configuration"):
        s3.load_state_dict(state)
    # a pre-shard checkpoint (no shard keys at all) is just as mismatched
    legacy = dict(state)
    del legacy["shard_sizes"], legacy["shard_window"]
    with pytest.raises(AssertionError, match="shard configuration"):
        CheckpointableSampler(48, batch_size=4, shard_sizes=[8] * 6).load_state_dict(
            legacy
        )
    # matching layout loads mid-epoch; any layout loads at cursor 0
    CheckpointableSampler(48, batch_size=4, shard_sizes=[8] * 6).load_state_dict(state)
    boundary = dict(state, cursor=0)
    CheckpointableSampler(48, batch_size=4).load_state_dict(boundary)


def test_prefetcher_close_during_demand_fetch(tmp_path):
    """close() must not cancel a demand fetch's hand-made future out from
    under the fetching thread (InvalidStateError at set_result)."""
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 16, hw=(8, 8), seed=0)
    pack(ds, tmp_path / "remote", samples_per_shard=8)
    src = SimulatedLatencySource(
        LocalShardSource(tmp_path / "remote"), latency_s=0.05
    )
    pf = ShardPrefetcher(src, tmp_path / "cache", max_bytes=1 << 30)
    results: list = []

    def fetch():
        try:
            results.append(pf.reader("shard-00000.rpshard"))
        except Exception as e:
            results.append(e)

    t = threading.Thread(target=fetch)
    t.start()
    time.sleep(0.01)  # thread is inside the simulated-latency fetch
    pf.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert len(results) == 1
    # the fetch must complete cleanly: a usable reader, never InvalidStateError
    assert not isinstance(results[0], Exception), results[0]
    assert bytes(results[0].read(0)) == ds.read_bytes(0)


def test_sampler_resume_at_epoch_boundary():
    """Checkpoint taken when ``cursor == batches_per_epoch()`` (the last
    batch handed out, rollover not yet executed): resume must continue into
    the next epoch with no gap and no overlap."""
    n, bs = 32, 4
    s1 = CheckpointableSampler(n, batch_size=bs, seed=11)
    it1 = iter(s1)
    nb = s1.batches_per_epoch()
    epoch0 = [next(it1) for _ in range(nb)]
    state = s1.state_dict()
    assert state["cursor"] == nb  # exactly at the boundary

    s2 = CheckpointableSampler(n, batch_size=bs, seed=11)
    s2.load_state_dict(state)
    it2 = iter(s2)
    epoch1_resumed = [next(it2) for _ in range(nb)]
    assert epoch1_resumed == [next(it1) for _ in range(nb)]
    # epoch 0 already complete at checkpoint time: nothing repeated, and the
    # resumed epoch is itself a full cover
    assert sorted(i for b in epoch0 for i in b) == list(range(n))
    assert sorted(i for b in epoch1_resumed for i in b) == list(range(n))
    assert s2.state_dict()["epoch"] >= 1


def test_shard_shuffle_deterministic_across_seed_epoch():
    """Shard-aware order is a pure function of (seed, epoch): same pair →
    identical order, different seed or epoch → different order."""
    kw = dict(batch_size=4, shard_sizes=SHARD_SIZES, shard_window=8)
    a = CheckpointableSampler(48, seed=5, **kw)
    b = CheckpointableSampler(48, seed=5, **kw)
    np.testing.assert_array_equal(a._epoch_order(0), b._epoch_order(0))
    np.testing.assert_array_equal(a._epoch_order(3), b._epoch_order(3))
    assert not np.array_equal(a._epoch_order(0), a._epoch_order(1))
    c = CheckpointableSampler(48, seed=6, **kw)
    assert not np.array_equal(a._epoch_order(0), c._epoch_order(0))
    assert sorted(a._epoch_order(0).tolist()) == list(range(48))


# ---------------------------------------------------------------------------
# dataset satellite fixes (test_data.py is module-skipped without hypothesis,
# so the always-on coverage for these lives here)
# ---------------------------------------------------------------------------
def test_arraydataset_missing_index_names_directory(tmp_path):
    with pytest.raises(FileNotFoundError, match=str(tmp_path)):
        ArrayDataset(tmp_path)


def test_arraydataset_skips_whitespace_index_lines(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path, 3, hw=(8, 8), seed=0)
    names = [p.name for p in ds.paths]
    (tmp_path / "index.txt").write_text(
        "\n".join([names[0], "   ", "", f"  {names[1]}\t", names[2], "  \t "])
    )
    ds2 = ArrayDataset(tmp_path)
    assert len(ds2) == 3
    for i in range(3):
        np.testing.assert_array_equal(ds2[i], ds[i])


def test_synthetic_token_dataset_honors_seed_in_index_mapping():
    from repro.data import SyntheticTokenDataset

    n = 300  # well beyond the 64-entry doc pool
    a = SyntheticTokenDataset(n, vocab=500, seed=1)
    b = SyntheticTokenDataset(n, vocab=500, seed=1)
    c = SyntheticTokenDataset(n, vocab=500, seed=2)
    # deterministic per (seed, i) ...
    assert all(a.read_bytes(i) == b.read_bytes(i) for i in range(n))
    # ... seed changes the per-index mapping, not just the pool contents
    assert [a._pool_index(i) for i in range(n)] != [c._pool_index(i) for i in range(n)]
    # ... and indices one pool-length apart no longer alias in lockstep
    aliases = sum(a._pool_index(i) == a._pool_index(i + 64) for i in range(n - 64))
    assert aliases < (n - 64) // 4


# ---------------------------------------------------------------------------
# coalesced crc verification (install-time / eager-open)
# ---------------------------------------------------------------------------
def _corrupt_sample(shard_path, reader_cls, sample):
    """Flip a payload byte of ``sample`` in the shard file on disk."""
    r = reader_cls(shard_path)
    off = int(r.offsets[sample]) + 5
    r.close()
    raw = bytearray(shard_path.read_bytes())
    raw[off] ^= 0xFF
    shard_path.write_bytes(raw)


def test_verify_all_memoizes_good_samples_only(tmp_path):
    from repro.data import encode_sample

    path = tmp_path / "s.rpshard"
    with ShardWriter(path) as w:
        for i in range(4):
            w.add(encode_sample(np.full(32, i, dtype=np.int32)))
    _corrupt_sample(path, ShardReader, 2)
    r = ShardReader(path)
    assert r.verify_all() == 1  # one corrupt sample found
    assert list(r._verified) == [True, True, False, True]
    r.read(0)  # memoized: no crc work, no raise
    with pytest.raises(ShardCorruption):
        r.read(2)  # corrupt sample keeps raising per sample
    r.close()


def test_cache_install_verifies_whole_shard_once(tmp_path):
    """A fetched shard is crc-verified at install time (coalesced pass on
    the fetch thread); reads then skip per-sample crc entirely, while a
    corrupt sample stays a per-sample hole."""
    ds, rds, src, pf = _remote_fixture(tmp_path, n=16, per_shard=8)
    name = rds.shard_names[0]
    _corrupt_sample(tmp_path / "remote" / name, ShardReader, 3)
    reader = pf.reader(name)
    # install-time verification memoized every intact sample ...
    assert list(reader._verified) == [True] * 3 + [False] + [True] * 4
    reader.read(0)  # pure pointer math now
    # ... and the corrupt one still raises, per sample, on every read
    with pytest.raises(ShardCorruption):
        reader.read(3)
    with pytest.raises(ShardCorruption):
        reader.read(3)
    rds.close()


def test_eager_local_verification_at_open(tmp_path):
    src = SyntheticImageDataset.materialize(tmp_path / "src", 16, hw=(8, 8), seed=1)
    sds = pack(src, tmp_path / "packed", samples_per_shard=8)
    name = sds.shard_names[1]
    sds.close()
    _corrupt_sample(tmp_path / "packed" / name, ShardReader, 2)

    eager = ShardDataset(tmp_path / "packed", verify_crc="eager")
    assert bytes(eager.read_bytes(0)) == bytes(src.read_bytes(0))
    # first touch of shard 1 ran the coalesced pass; sample 8+2 is corrupt
    with pytest.raises(ShardCorruption):
        eager.read_bytes(10)
    assert bytes(eager.read_bytes(9)) == bytes(src.read_bytes(9))
    # every intact sample of the touched shards is memoized
    assert list(eager._readers[1]._verified) == [True, True, False] + [True] * 5
    eager.close()


def test_read_bytes_many_matches_read_bytes(tmp_path):
    src = SyntheticImageDataset.materialize(tmp_path / "src", 20, hw=(8, 8), seed=2)
    sds = pack(src, tmp_path / "packed", samples_per_shard=6)
    order = np.random.default_rng(0).permutation(20).tolist()
    many = sds.read_bytes_many(order)
    assert [bytes(v) for v in many] == [bytes(sds.read_bytes(i)) for i in order]
    with pytest.raises(IndexError):
        sds.read_bytes_many([0, 20])
    assert sds.read_bytes_many([]) == []
    sds.close()


def test_verify_on_install_opt_out(tmp_path):
    """verify_crc=False must not pay (or memoize) any install-time crc."""
    ds, rds, src, pf = _remote_fixture(tmp_path, n=8, per_shard=8)
    pf.verify_on_install = False
    reader = pf.reader(rds.shard_names[0])
    assert not reader._verified.any()  # no coalesced pass ran
    rds.close()


# ---------------------------------------------------------------------------
# format v2: columnar fields + projection pushdown
# ---------------------------------------------------------------------------
import json
import struct

from repro.data.shards import (
    ShardIndexV2,
    ShardReaderV2,
    ShardWriterV2,
    open_shard_reader,
)
from repro.data.shards.format import (
    INDEX_PREAMBLE_SIZE,
    _FIELD_HEAD_SIZE,
    parse_shard_header,
)


def _v2_shard(tmp_path, n=6):
    """One columnar shard: fixed-width ``image`` + variable ``caption``."""
    rng = np.random.default_rng(0)
    samples = [
        {
            "image": rng.integers(0, 256, 64, dtype=np.uint8).tobytes(),
            "caption": bytes(rng.integers(0, 256, 3 + j, dtype=np.uint8)),
        }
        for j in range(n)
    ]
    path = tmp_path / "v2.rpshard"
    with ShardWriterV2(path) as w:
        for j, s in enumerate(samples):
            assert w.add(s) == j
    return path, samples


def test_v2_roundtrip_fixed_and_var_columns(tmp_path):
    path, samples = _v2_shard(tmp_path)
    with ShardReaderV2(path) as r:
        assert r.field_names == ("image", "caption")
        assert r.index.column("image").fixed  # equal lengths auto-vectorize
        assert not r.index.column("caption").fixed
        for j, s in enumerate(samples):
            got = r.read_fields(j)
            assert {k: bytes(v) for k, v in got.items()} == s
            assert got["image"].obj is got["caption"].obj  # zero-copy mmap views
        # vectorized chunk read: one contiguous view over a sample run
        chunk = r.read_field_chunk("image", 1, 3)
        assert isinstance(chunk, memoryview)
        assert bytes(chunk) == b"".join(s["image"] for s in samples[1:4])
        with pytest.raises(TypeError, match="variable-width"):
            r.read_field_chunk("caption", 0, 2)
        with pytest.raises(IndexError):
            r.read_field_chunk("image", 4, 5)


def test_open_shard_reader_dispatches_on_version(tmp_path):
    v2_path, _ = _v2_shard(tmp_path)
    v1_path = tmp_path / "v1.rpshard"
    with ShardWriter(v1_path) as w:
        w.add(b"blob")
    r1, r2 = open_shard_reader(v1_path), open_shard_reader(v2_path)
    try:
        assert type(r1) is ShardReader
        assert type(r2) is ShardReaderV2
    finally:
        r1.close()
        r2.close()


def test_wrong_version_reader_fails_loudly(tmp_path):
    """A v2 shard handed to the v1 reader (and vice versa) must refuse with
    an error naming the right entry point, never misparse."""
    v2_path, _ = _v2_shard(tmp_path)
    v1_path = tmp_path / "v1.rpshard"
    with ShardWriter(v1_path) as w:
        w.add(b"blob")
    with pytest.raises(ShardCorruption, match="not a v1 shard"):
        ShardReader(v2_path)
    with pytest.raises(ShardCorruption, match="not a v2 shard"):
        ShardReaderV2(v1_path)


def test_v2_truncated_column_index_rejected(tmp_path):
    path, _ = _v2_shard(tmp_path)
    raw = path.read_bytes()
    _, _, index_off, _ = parse_shard_header(raw[:32], "t")
    # file cut mid-preamble
    cut = tmp_path / "cut.rpshard"
    cut.write_bytes(raw[: index_off + 8])
    with pytest.raises(ShardCorruption, match="preamble extends past"):
        ShardReaderV2(cut)
    # preamble claims a longer index region than the file holds
    grown = bytearray(raw)
    struct.pack_into("<Q", grown, index_off, len(raw))
    (tmp_path / "grown.rpshard").write_bytes(grown)
    with pytest.raises(ShardCorruption, match="region extends past"):
        ShardReaderV2(tmp_path / "grown.rpshard")
    # preamble claims a region too short to hold its own field table
    shrunk = bytearray(raw)
    struct.pack_into("<Q", shrunk, index_off, INDEX_PREAMBLE_SIZE + 4)
    (tmp_path / "shrunk.rpshard").write_bytes(shrunk)
    with pytest.raises(ShardCorruption, match="field table"):
        ShardReaderV2(tmp_path / "shrunk.rpshard")


def test_v2_overlapping_column_regions_rejected(tmp_path):
    """A column whose region reaches into a sibling's bytes would let one
    flipped region corrupt two fields while each column's crcs 'verify'."""
    path, _ = _v2_shard(tmp_path)
    raw = bytearray(path.read_bytes())
    _, _, index_off, _ = parse_shard_header(bytes(raw[:32]), "t")
    # second field-table entry ("caption"): after the preamble and the
    # "image" entry (fixed head + name bytes)
    e1 = index_off + INDEX_PREAMBLE_SIZE + _FIELD_HEAD_SIZE + len(b"image")
    (col_off,) = struct.unpack_from("<Q", raw, e1 + 6)
    (col_len,) = struct.unpack_from("<Q", raw, e1 + 14)
    struct.pack_into("<Q", raw, e1 + 6, col_off - 1)  # reach into "image"
    struct.pack_into("<Q", raw, e1 + 14, col_len + 1)
    path.write_bytes(raw)
    with pytest.raises(ShardCorruption, match="overlapping column regions"):
        ShardReaderV2(path)


def test_v2_unknown_field_raises(tmp_path):
    path, _ = _v2_shard(tmp_path)
    with ShardReaderV2(path) as r:
        with pytest.raises(KeyError, match="nope"):
            r.read_fields(0, ("nope",))
        with pytest.raises(KeyError):
            r.read_field(0, "nope")


def test_v2_per_column_crc_is_a_per_sample_per_field_hole(tmp_path):
    path, samples = _v2_shard(tmp_path)
    with ShardReaderV2(path) as r:
        off, ln, _ = r.index.locate("caption", 2)
    raw = bytearray(path.read_bytes())
    raw[off + 1] ^= 0xFF
    path.write_bytes(raw)
    r = ShardReaderV2(path)
    assert r.verify_all() == 1  # exactly one corrupt cell
    # sibling field of the same sample and sibling samples are untouched
    assert bytes(r.read_field(2, "image")) == samples[2]["image"]
    assert bytes(r.read_field(1, "caption")) == samples[1]["caption"]
    for _ in range(2):  # never memoized: raises on every read
        with pytest.raises(ShardCorruption, match="field 'caption'"):
            r.read_field(2, "caption")
    r.read_field(2, "caption", verify=False)  # opt-out skips the crc
    r.close()


def test_pack_v2_sharddataset_parity_and_projection(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 18, hw=(8, 8), seed=4)
    v2 = pack(
        ds, tmp_path / "v2", samples_per_shard=5, format_version=2, fields=("image",)
    )
    assert v2.format_version == 2
    assert v2.schema_fields == ("image",)
    man = json.loads((tmp_path / "v2" / "manifest.json").read_text())
    assert man["format_version"] == 2 and man["fields"] == ["image"]
    assert v2.sample_meta == (np.dtype(np.uint8), (8, 8, 3))  # via field_meta
    for i in range(18):
        np.testing.assert_array_equal(v2[i], ds[i])
        assert bytes(v2.read_bytes(i)) == ds.read_bytes(i)
    proj = ShardDataset(tmp_path / "v2", fields=("image",))
    np.testing.assert_array_equal(proj[3], ds[3])
    with pytest.raises(ValueError, match="nope"):
        ShardDataset(tmp_path / "v2", fields=("nope",))
    v1 = pack(ds, tmp_path / "v1", samples_per_shard=5)
    with pytest.raises(TypeError, match="columnar"):
        ShardDataset(tmp_path / "v1", fields=("image",))
    for d in (v2, proj, v1):
        d.close()


def test_pack_cli_v1_to_v2_migration_parity(tmp_path):
    """Satellite: ``python -m repro.data.shards`` migrates v1→v2 (and back)
    with per-field byte parity."""
    from repro.data.shards.__main__ import main

    ds = SyntheticImageDataset.materialize(tmp_path / "src", 12, hw=(8, 8), seed=7)
    main([str(tmp_path / "src"), str(tmp_path / "v1"), "--samples-per-shard", "5"])
    main(
        [
            str(tmp_path / "v1"),
            str(tmp_path / "v2"),
            "--samples-per-shard",
            "4",
            "--format-version",
            "2",
            "--fields",
            "image",
        ]
    )
    v1, v2 = ShardDataset(tmp_path / "v1"), ShardDataset(tmp_path / "v2")
    assert v2.schema_fields == ("image",)
    for i in range(12):
        assert bytes(v2.read_fields(i)["image"]) == bytes(v1.read_bytes(i))
        np.testing.assert_array_equal(v2[i], ds[i])
    # and back down: v2 → v1 restores plain one-blob shards
    main([str(tmp_path / "v2"), str(tmp_path / "back"), "--format-version", "1"])
    back = ShardDataset(tmp_path / "back")
    assert back.format_version == 1
    assert bytes(back.read_bytes(5)) == bytes(v1.read_bytes(5))
    for d in (v1, v2, back):
        d.close()


def _columnar_corpus(tmp_path, n=16, name="shard-00000.rpshard"):
    """Image-light corpus (image = 25% of payload) for wire-byte tests."""
    root = tmp_path / "corpus"
    root.mkdir()
    with ShardWriterV2(root / name) as w:
        for j in range(n):
            w.add(
                {
                    "image": bytes([j]) * 2000,
                    "caption": bytes([j % 251]) * 3000,
                    "meta": bytes([(j * 7) % 251]) * 3000,
                }
            )
    return root, name


def test_v2_projection_fetches_only_requested_columns(tmp_path):
    """A sparse fetch with ``fields=("image",)`` pulls only the image
    column's ranges over the wire and accounts the skipped bytes."""
    from repro.data.shards.sources import HttpShardSource
    from repro.data.shards.testing import serve_shards

    root, name = _columnar_corpus(tmp_path)
    wanted = list(range(8))
    with serve_shards(root) as srv:
        pf = ShardPrefetcher(
            HttpShardSource(srv.url), tmp_path / "cache", max_bytes=1 << 30
        )
        reader = pf.reader(name, samples=wanted, fields=("image",))
        assert reader.field_names == ("image", "caption", "meta")
        for j in wanted:
            assert bytes(reader.read_field(j, "image")) == bytes([j]) * 2000
        with pytest.raises(TypeError):
            reader.read(0)  # one-blob read has no meaning on a v2 shard
        st = pf.stats()
        assert st["bytes_skipped"] >= 8 * 6000  # caption+meta never fetched
        assert st["fields_requested"] == 1
        with srv.lock:
            wire = srv.bytes_served
        # wire bytes ≈ header + column index + 8 image cells — far below
        # the 8 samples' full 64000 payload bytes
        assert wire < 8 * 8000 * 0.5
        pf.close()


def test_v2_sparse_entry_serves_column_ranges_to_peers(tmp_path):
    """A peer whose cache holds a sparse *projected* entry serves exactly
    the resident column spans (and the re-serialized index); everything
    else is a structured miss."""
    from repro.data.shards.peer import PeerMiss, PeerShardServer, PeerShardSource
    from repro.data.shards.sources import HttpShardSource
    from repro.data.shards.testing import serve_shards

    root, name = _columnar_corpus(tmp_path)
    with ShardReaderV2(root / name) as local:
        img = local.index.locate("image", 3)
        cap = local.index.locate("caption", 3)
    with serve_shards(root) as srv:
        pf = ShardPrefetcher(
            HttpShardSource(srv.url), tmp_path / "cache", max_bytes=1 << 30
        )
        pf.reader(name, samples=list(range(8)), fields=("image",))
        with PeerShardServer(pf) as peer:
            ps = PeerShardSource([peer.url])
            got = ps.fetch_range(name, img[0], img[1])
            assert got == bytes([3]) * 2000  # resident image cell served
            with pytest.raises(PeerMiss):
                ps.fetch_range(name, cap[0], cap[1])  # caption never fetched
        pf.close()


def test_build_image_loader_field_projection(tmp_path):
    """``build_image_loader(fields=("image",))`` over a multi-field v2
    dataset decodes only the image column; extra fields ride along unread."""

    class _TwoField:
        """dict-of-blobs source: encoded image + a caption sidecar."""

        schema_fields = ("image", "caption")

        def __init__(self, inner):
            self.inner = inner

        def __len__(self):
            return len(self.inner)

        def read_fields(self, i, fields=None):
            blobs = {
                "image": self.inner.read_bytes(i),
                "caption": b"caption-%d" % i,
            }
            return {f: blobs[f] for f in (fields or self.schema_fields)}

    ds = SyntheticImageDataset.materialize(tmp_path / "src", 24, hw=(8, 8), seed=9)
    sds = pack(
        _TwoField(ds), tmp_path / "packed", samples_per_shard=6, format_version=2
    )
    assert sds.schema_fields == ("image", "caption")
    assert bytes(sds.read_fields(5)["caption"]) == b"caption-5"
    with pytest.raises(ValueError, match="one field per sample"):
        build_image_loader(sds, batch_size=4, hw=(8, 8), fields=("image", "caption"))
    p = build_image_loader(
        sds,
        batch_size=6,
        hw=(8, 8),
        num_threads=2,
        fields=("image",),
        sampler=CheckpointableSampler(len(sds), batch_size=1, shuffle=False),
    )
    with p.auto_stop():
        batches = list(p)
    assert len(batches) == 4
    for b in batches:
        assert np.asarray(b["images"]).shape == (6, 8, 8, 3)
    sds.close()


def test_v2_fields_only_demand_fetch_stays_projected(tmp_path):
    """A demand read carrying a projection but no sample hints (e.g. its
    schedule hint was dropped under inflight pressure) still goes
    index-first and fetches only the projected columns of the shard."""
    from repro.data.shards.sources import HttpShardSource
    from repro.data.shards.testing import serve_shards

    root, name = _columnar_corpus(tmp_path)
    with serve_shards(root) as srv:
        pf = ShardPrefetcher(
            HttpShardSource(srv.url), tmp_path / "cache", max_bytes=1 << 30
        )
        reader = pf.reader(name, fields=("image",))
        assert reader.field_names is not None  # sparse columnar entry
        for j in range(16):
            assert bytes(reader.read_field(j, "image")) == bytes([j]) * 2000
        st = pf.stats()
        assert st["sparse_shards"] == 1
        assert st["bytes_skipped"] >= 16 * 6000  # caption+meta never fetched
        pf.close()

"""Data substrate tests: codec, sampler (checkpoint/resume!), packing,
loaders end-to-end, and robustness against corrupt samples."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.data import (
    ArrayDataset,
    ByteTokenizer,
    CheckpointableSampler,
    SyntheticImageDataset,
    SyntheticTokenDataset,
    build_image_loader,
    build_lm_loader,
    decode_sample,
    encode_sample,
)
from repro.data.packing import SequencePacker, collate


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=25)
@given(
    shape=st.sampled_from([(7,), (16, 3), (32, 32, 3), (2, 5, 4)]),
    dtype=st.sampled_from([np.uint8, np.int32, np.float32]),
    seed=st.integers(0, 1000),
)
def test_codec_roundtrip(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.random(shape) * 100).astype(dtype)
    out = decode_sample(encode_sample(arr))
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


def test_codec_rejects_corrupt():
    arr = np.arange(10, dtype=np.int32)
    data = b"XXXX" + encode_sample(arr)[4:]
    with pytest.raises(ValueError):
        decode_sample(data)


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------
def test_sampler_epoch_covers_all_once():
    s = CheckpointableSampler(100, batch_size=10, seed=1)
    it = iter(s)
    seen = [i for _ in range(10) for i in next(it)]
    assert sorted(seen) == list(range(100))


def test_sampler_shards_partition_dataset():
    batches = []
    for rank in range(4):
        s = CheckpointableSampler(64, batch_size=4, seed=3, rank=rank, world=4)
        it = iter(s)
        batches += [i for _ in range(s.batches_per_epoch()) for i in next(it)]
    assert sorted(batches) == list(range(64))


def test_sampler_checkpoint_resume_no_overlap_no_gap():
    s1 = CheckpointableSampler(64, batch_size=4, seed=7)
    it1 = iter(s1)
    first = [next(it1) for _ in range(5)]
    state = s1.state_dict()

    s2 = CheckpointableSampler(64, batch_size=4, seed=0)
    s2.load_state_dict(state)
    it2 = iter(s2)
    rest_resumed = [next(it2) for _ in range(11)]
    rest_orig = [next(it1) for _ in range(11)]
    assert rest_resumed == rest_orig
    epoch0 = [i for b in first + rest_resumed for i in b]
    assert sorted(epoch0) == list(range(64))


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(8, 200),
    bs=st.integers(1, 8),
    stop=st.integers(0, 30),
    seed=st.integers(0, 99),
)
def test_sampler_resume_property(n, bs, stop, seed):
    s1 = CheckpointableSampler(n, batch_size=bs, seed=seed)
    it1 = iter(s1)
    for _ in range(stop):
        next(it1)
    state = s1.state_dict()
    s2 = CheckpointableSampler(n, batch_size=bs, seed=seed)
    s2.load_state_dict(state)
    assert [next(iter(s2)) for _ in range(3)] == [next(it1) for _ in range(3)]


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------
def test_packer_rows_are_dense_and_aligned():
    p = SequencePacker(16)
    rng = np.random.default_rng(0)
    rows = []
    for _ in range(20):
        rows += p.add(rng.integers(3, 100, rng.integers(4, 30), dtype=np.int32))
    assert rows, "no rows emitted"
    for r in rows:
        assert r["tokens"].shape == (16,)
        assert r["labels"].shape == (16,)
        assert r["positions"].shape == (16,)
        # labels align: where same segment, labels == next token
        same = r["segment_ids"][1:] == r["segment_ids"][:-1]
        np.testing.assert_array_equal(r["labels"][:-1][same], r["tokens"][1:][same])
        # positions restart at each segment boundary
        starts = np.where(np.diff(r["segment_ids"]) != 0)[0] + 1
        assert all(r["positions"][s] == 0 for s in starts)


def test_collate_contiguous():
    rows = [
        {"tokens": np.arange(8, dtype=np.int32), "labels": np.arange(8, dtype=np.int32)}
        for _ in range(4)
    ]
    batch = collate(rows)
    assert batch["tokens"].shape == (4, 8)
    assert batch["tokens"].flags["C_CONTIGUOUS"]


def test_tokenizer_roundtrip():
    t = ByteTokenizer()
    ids = t.encode("hello spdl")
    assert ids[0] == t.BOS and ids[-1] == t.EOS
    assert t.decode(ids) == b"hello spdl"


# ---------------------------------------------------------------------------
# loaders end-to-end
# ---------------------------------------------------------------------------
def test_image_loader_end_to_end(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path / "img", 24, hw=(32, 32), seed=0)
    p = build_image_loader(ds, batch_size=8, hw=(16, 16), num_threads=4)
    with p.auto_stop():
        batches = [b for b, _ in zip(p, range(3))]
    assert len(batches) == 3
    assert batches[0]["images"].shape == (8, 16, 16, 3)
    assert str(batches[0]["images"].dtype) == "uint8"  # uint8 wire format


def test_image_loader_skips_corrupt_samples(tmp_path):
    ds = SyntheticImageDataset.materialize(
        tmp_path / "imgc", 30, hw=(16, 16), corrupt_every=5
    )
    p = build_image_loader(ds, batch_size=6, hw=(8, 8), num_threads=4)
    with p.auto_stop():
        batches = list(p)
    # 30 samples, 6 corrupt -> 24 good -> 4 full batches; pipeline survived
    assert len(batches) == 4
    stats = {s.name: s for s in p.stats()}
    assert stats["decode"].num_failed == 6


def test_lm_loader_end_to_end():
    ds = SyntheticTokenDataset(200, vocab=1000, min_len=32, max_len=200, seed=1)
    p, sampler = build_lm_loader(ds, seq_len=64, batch_size=4, num_threads=4)
    with p.auto_stop():
        batches = [b for b, _ in zip(p, range(5))]
    for b in batches:
        assert np.asarray(b["tokens"]).shape == (4, 64)
        assert np.asarray(b["segment_ids"]).shape == (4, 64)
        assert np.asarray(b["labels"]).max() < 1000
    assert sampler.state_dict()["cursor"] >= 0

"""Per-architecture smoke tests: reduced configs, one train + serve pass on
CPU, asserting shapes and no NaNs (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="dist subsystem not built yet")

from repro.configs import all_archs, get_config, get_smoke_config
from repro.models import Model

B, S = 2, 32


def make_batch(cfg, key):
    kt, kl, kv = jax.random.split(key, 3)
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    tokens = jax.random.randint(kt, tok_shape, 0, cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(kl, tok_shape, 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.vis_prefix_len:
        batch["vis_embed"] = jax.random.normal(
            kv, (B, cfg.vis_prefix_len, cfg.d_model), jnp.bfloat16
        )
        # mask the vision prefix out of the loss
        batch["labels"] = labels.at[:, : cfg.vis_prefix_len].set(-1)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0

    # gradients flow and are finite
    g = jax.jit(jax.grad(lambda p: model.train_loss(p, batch)[0]))(params)
    leaves = jax.tree.leaves(g)
    assert leaves, "no grads"
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits, caches = jax.jit(model.prefill)(params, batch)
    # serving logits use the 128-padded vocab; padding columns are -inf-masked
    pv = cfg.padded_vocab
    expect = (B, cfg.n_codebooks, pv) if cfg.n_codebooks > 1 else (B, pv)
    assert logits.shape == expect
    real = np.asarray(logits, jnp.float32)[..., : cfg.vocab_size]
    assert np.all(np.isfinite(real))
    assert np.asarray(logits)[..., cfg.vocab_size :].max(initial=-np.inf) < -1e9 or pv == cfg.vocab_size

    # pad cache to capacity S+4 and decode a few tokens
    cap = S + 4
    caches = pad_cache_to(model, caches, cap)
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    step = jax.jit(model.decode_step)
    for i in range(3):
        tokens = jnp.full(tok_shape, (7 + i) % cfg.vocab_size, jnp.int32)
        logits, caches = step(params, caches, tokens, jnp.int32(S + i))
        assert logits.shape == expect
        assert np.all(np.isfinite(np.asarray(logits, jnp.float32))), f"{arch}: step {i}"


def pad_cache_to(model, caches, cap):
    """Grow seq-capacity dims (attn k/v, mla ckv/k_rope) from S to cap."""

    def pad_tree(spec, real):
        return jax.tree.map(
            lambda sp, x: _pad(x, sp.shape), spec, real,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    def _pad(x, target):
        pads = [(0, t - s) for s, t in zip(x.shape, target)]
        return jnp.pad(x, pads)

    spec = model.cache_spec(B, cap)
    return pad_tree(spec, caches)


@pytest.mark.parametrize("arch", all_archs())
def test_decode_matches_prefill(arch):
    """Teacher-forced decode over the same tokens must reproduce the prefill
    last-position logits (cache correctness).  Run in fp32: the bf16 paths
    accumulate rounding differences between the chunked-prefill and
    stepwise-decode orders that are noise, not cache bugs."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.moe is not None:
        # no-drop capacity: prefill (capacity over T=B·S tokens) and decode
        # (T=B tokens) otherwise drop *different* tokens — a property of
        # capacity-based MoE, not a cache bug (verified separately).
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    ref_logits, _ = jax.jit(model.prefill)(params, batch)

    # decode token-by-token from an empty cache
    caches = jax.tree.map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype),
        model.cache_spec(B, S),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    step = jax.jit(model.decode_step)
    if cfg.vis_prefix_len:
        pytest.skip("vlm decode starts from prefill cache (prefix splice)")
    logits = None
    for t in range(S):
        tok = batch["tokens"][:, t : t + 1]
        logits, caches = step(params, caches, tok, jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(ref_logits, np.float32),
        rtol=2e-3, atol=2e-3,
    )

"""Auto-tuner: visibility-driven concurrency suggestions."""

import time

from repro.core import PipelineBuilder
from repro.core.autotune import autotune, suggest


def _build(conc: dict[str, int], n=64, slow_s=0.01):
    def slow(x):
        time.sleep(slow_s)  # releases the GIL: widening genuinely helps
        return x

    return (
        PipelineBuilder()
        .add_source(range(n))
        .pipe(slow, concurrency=conc.get("slow", 1), name="slow")
        .pipe(lambda x: x + 1, concurrency=conc.get("fast", 1), name="fast")
        .add_sink(buffer_size=4)
        .build(num_threads=16)
    )


def test_suggest_targets_the_hot_stage():
    p = _build({"slow": 1})
    with p.auto_stop():
        for _ in p:
            pass
        s = suggest(p)
    assert s.stage == "slow"
    assert s.concurrency == 2


def test_autotune_improves_throughput():
    def probe(pipe):
        t0 = time.monotonic()
        n = sum(1 for _ in pipe)
        return n / (time.monotonic() - t0)

    conc, log = autotune(lambda c: _build(c), probe, initial={"slow": 1}, rounds=3)
    assert conc["slow"] >= 2, log
    assert log[-1]["rate"] > log[0]["rate"] * 1.5, log


def test_autotune_returns_best_measured_map_not_last_applied():
    """Regression: a final round that regresses must not win just by being
    the last map applied — the returned map is the best-MEASURED one."""
    rates = iter([100.0, 40.0, 30.0])

    def probe(pipe):
        for _ in pipe:  # consume so suggest() has stats to work with
            pass
        return next(rates)

    conc, log = autotune(lambda c: _build(c), probe, initial={"slow": 1}, rounds=3)
    assert conc == {"slow": 1}, (conc, log)  # round 0 measured best
    assert log[0]["rate"] == 100.0


def test_suggest_proposes_chunk_for_loop_bound_stage():
    """A busy stage doing near-zero work per item is loop-overhead-bound:
    the remedy is a chunk size, not more concurrency."""

    def probe():
        # sink buffer > stream length: the stage is never backpressured by
        # the (slow, per-item) test consumer, so its own loop overhead is
        # what shows
        p = (
            PipelineBuilder()
            .add_source(range(512))
            .pipe(lambda x: x, concurrency=1, name="passthrough")
            .add_sink(buffer_size=600)
            .build(num_threads=4)
        )
        with p.auto_stop():
            for _ in p:
                pass
            return suggest(p)

    # the avg-task-time threshold classifies against wall-clock noise on a
    # loaded box: accept the first clean run out of three
    for _ in range(3):
        s = probe()
        if s.chunk is not None:
            break
    assert s.stage == "passthrough"
    assert s.chunk == 32
    assert "loop-overhead-bound" in s.reason


def test_suggest_does_not_re_chunk_a_chunked_stage():
    p = (
        PipelineBuilder()
        .add_source(range(2048))
        .pipe(lambda x: x, concurrency=1, name="passthrough", chunk=32)
        .add_sink(buffer_size=4)
        .build(num_threads=4)
    )
    with p.auto_stop():
        for _ in p:
            pass
        s = suggest(p)
    assert s.chunk is None  # already chunked: widen or leave alone

"""Auto-tuner: visibility-driven concurrency suggestions."""

import time

from repro.core import PipelineBuilder
from repro.core.autotune import autotune, suggest


def _build(conc: dict[str, int], n=64, slow_s=0.01):
    def slow(x):
        time.sleep(slow_s)  # releases the GIL: widening genuinely helps
        return x

    return (
        PipelineBuilder()
        .add_source(range(n))
        .pipe(slow, concurrency=conc.get("slow", 1), name="slow")
        .pipe(lambda x: x + 1, concurrency=conc.get("fast", 1), name="fast")
        .add_sink(buffer_size=4)
        .build(num_threads=16)
    )


def test_suggest_targets_the_hot_stage():
    p = _build({"slow": 1})
    with p.auto_stop():
        for _ in p:
            pass
        s = suggest(p)
    assert s.stage == "slow"
    assert s.concurrency == 2


def test_autotune_improves_throughput():
    def probe(pipe):
        t0 = time.monotonic()
        n = sum(1 for _ in pipe)
        return n / (time.monotonic() - t0)

    conc, log = autotune(lambda c: _build(c), probe, initial={"slow": 1}, rounds=3)
    assert conc["slow"] >= 2, log
    assert log[-1]["rate"] > log[0]["rate"] * 1.5, log

"""Guard the dry-run deliverable: one full cell (lower + compile + census)
in a subprocess with forced host devices, asserting the report invariants.

Runs a small arch on a reduced 8×8 mesh so CI stays fast; the full
16×16 / 2×16×16 sweep artifacts live in experiments/dryrun (regenerate with
``python -m repro.launch.dryrun``)."""

import json
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("repro.dist", reason="dist subsystem not built yet")

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_config, SHAPES
    from repro.launch.hlo_census import census
    from repro.launch.steps import build_step

    mesh = jax.make_mesh((4, 16), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("qwen3-0.6b")
    bundle = build_step(cfg, mesh, SHAPES["decode_32k"])
    with mesh:
        compiled = bundle.jitted.lower(*bundle.in_specs).compile()
    c = census(compiled.as_text())
    ma = compiled.memory_analysis()
    print(json.dumps({
        "flops": c["dot_flops"],
        "tpu_bytes": c["tpu_bytes"],
        "coll_count": c["collective_count"],
        "temp_gb": ma.temp_size_in_bytes / 2**30,
        "arg_gb": ma.argument_size_in_bytes / 2**30,
    }))
    """
)


@pytest.mark.slow
def test_decode_cell_compiles_and_census_sane():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, timeout=900, cwd="."
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads([l for l in out.stdout.splitlines() if l.startswith("{")][0])
    # decode: flops ≈ 2·N_active·B/devs + attention over 32k cache — nonzero,
    # far below a train step
    assert 1e8 < rec["flops"] < 1e13
    assert rec["tpu_bytes"] > rec["flops"] / 300  # decode is memory-heavy
    assert rec["coll_count"] >= 1  # TP requires at least output reductions
    # on this REDUCED 64-dev mesh the 32k KV cache is ~15 GB/dev (args) and
    # the CPU BufferAssignment double-buffers it (temp); the production
    # 256-dev mesh shards it 4x smaller (verified by the sweep artifacts).
    # Here we only guard against runaway blowup:
    assert rec["arg_gb"] < 20.0
    assert rec["temp_gb"] < 4.0 * rec["arg_gb"]

"""Elastic shard fleet: consistent-hash placement, membership + heartbeat
lifecycle, breaker/membership interaction (no double-bench, exactly one
half-open probe on re-join), warm restart from persisted spans/cache, and
admission control (token-bucket quotas, max-inflight, structured 429 +
Retry-After honored by RetryingSource)."""

import json
import struct
import threading
import time
import zlib

import pytest

from repro.core.health import origin_only, shrink_replication
from repro.core.metrics import MetricsExporter
from repro.data import (
    AdmissionController,
    FleetMember,
    HashRing,
    LocalShardSource,
    MembershipRegistry,
    PeerShardServer,
    PeerShardSource,
    ShardDataset,
    ShardPrefetcher,
    SourceUnavailable,
    SyntheticImageDataset,
    TieredSource,
    pack,
)
from repro.data.shards import TokenBucket
from repro.data.shards.membership import _fleet_call
from repro.data.shards.peer import _CLOSED, _OPEN, PeerMiss
from repro.data.shards.prefetch import _WARM_DIR, _WARM_MAGIC, SparseShardReader
from repro.data.shards.sources import HttpShardSource, RetryingSource


@pytest.fixture()
def packed(tmp_path):
    """(files dataset, packed shard dir) — 40 samples in 5 shards of 8."""
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 40, hw=(16, 16), seed=0)
    pack(ds, tmp_path / "shards", samples_per_shard=8)
    return ds, tmp_path / "shards"


def _wait_for(cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while not cond() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert cond(), "condition not reached before timeout"


# ---------------------------------------------------------------------------
# HashRing: determinism + bounded remap
# ---------------------------------------------------------------------------
KEYS = [f"shard-{i:05d}.rpshard" for i in range(500)]


def test_ring_is_deterministic_across_instances():
    a = HashRing(["p1", "p2", "p3"])
    b = HashRing(["p3", "p1", "p2"])  # member order must not matter
    for k in KEYS[:50]:
        assert a.owners(k, 2) == b.owners(k, 2)


def test_ring_replicas_are_distinct_members():
    ring = HashRing(["p1", "p2", "p3"])
    for k in KEYS[:50]:
        owners = ring.owners(k, 2)
        assert len(owners) == 2 and len(set(owners)) == 2
    # asking for more replicas than members yields every member once
    assert sorted(ring.owners("x", 9)) == ["p1", "p2", "p3"]


def test_ring_leave_remaps_bounded_fraction():
    members = ["p1", "p2", "p3", "p4"]
    ring = HashRing(members)
    before = {k: ring.owners(k, 1)[0] for k in KEYS}
    moved_arcs = ring.rebuild(["p1", "p2", "p3"])  # p4 leaves
    assert moved_arcs > 0
    after = {k: ring.owners(k, 1)[0] for k in KEYS}
    remapped = sum(1 for k in KEYS if before[k] != after[k])
    # only p4's keys move, and they ALL must move (p4 is gone)
    assert all(before[k] == "p4" for k in KEYS if before[k] != after[k])
    # bounded: ≤ 2/N of the keyspace per membership change (N=4)
    assert 0 < remapped / len(KEYS) <= 2 / len(members)
    # survivors keep their keys byte-for-byte
    assert all(after[k] == before[k] for k in KEYS if before[k] != "p4")


def test_ring_join_remaps_only_newcomers_share():
    ring = HashRing(["p1", "p2", "p3"])
    before = {k: ring.owners(k, 1)[0] for k in KEYS}
    ring.rebuild(["p1", "p2", "p3", "p4"])
    after = {k: ring.owners(k, 1)[0] for k in KEYS}
    changed = [k for k in KEYS if before[k] != after[k]]
    assert changed and all(after[k] == "p4" for k in changed)
    assert len(changed) / len(KEYS) <= 2 / 4
    # no-op rebuild moves nothing
    assert ring.rebuild(["p1", "p2", "p3", "p4"]) == 0


# ---------------------------------------------------------------------------
# MembershipRegistry: register / heartbeat / suspect / sweep (fake clock)
# ---------------------------------------------------------------------------
def _registry():
    clock = [0.0]
    reg = MembershipRegistry(
        suspect_after_s=3.0, dead_after_s=10.0, clock=lambda: clock[0]
    )
    return reg, clock


def test_registry_lifecycle_suspect_then_dead():
    reg, clock = _registry()
    reg.register("r1", "http://a:1")
    reg.register("r2", "http://b:2")
    v0 = reg.members()["version"]
    clock[0] = 2.0
    assert reg.heartbeat("r1")  # r1 stays fresh
    clock[0] = 4.5  # r2's last beat is 4.5s old -> suspect
    view = reg.members()
    assert [m["id"] for m in view["live"]] == ["r1"]
    assert [m["id"] for m in view["suspect"]] == ["r2"]
    assert view["version"] > v0
    clock[0] = 11.5  # r2 now 11.5s quiet -> swept; r1 9.5s quiet -> suspect
    view = reg.members()
    assert [m["id"] for m in view["suspect"]] == ["r1"]
    assert not any(m["id"] == "r2" for m in view["live"] + view["suspect"])
    assert not reg.heartbeat("r2")  # swept: must re-register
    # re-registration re-admits live and bumps the version
    v1 = view["version"]
    view = reg.register("r2", "http://b:2")
    assert any(m["id"] == "r2" for m in view["live"])
    assert view["version"] > v1
    st = reg.stats()
    assert st["joins"] == 3 and st["deaths"] == 1
    assert st["suspect_transitions"] >= 2


def test_registry_heartbeat_clears_suspect():
    reg, clock = _registry()
    reg.register("r1", "http://a:1")
    clock[0] = 5.0
    assert [m["id"] for m in reg.members()["suspect"]] == ["r1"]
    assert reg.heartbeat("r1")  # a beat from a suspect revives it
    view = reg.members()
    assert [m["id"] for m in view["live"]] == ["r1"] and not view["suspect"]


# ---------------------------------------------------------------------------
# /fleet/* endpoints on PeerShardServer + FleetMember agent
# ---------------------------------------------------------------------------
def test_fleet_endpoints_over_http(packed, tmp_path):
    _, shards = packed
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a")
    reg = MembershipRegistry()
    with PeerShardServer(pf, registry=reg) as srv:
        view = _fleet_call(srv.url, "/fleet/register?id=r1&url=http%3A//x%3A1", 2.0)
        assert [m["id"] for m in view["live"]] == ["r1"]
        assert _fleet_call(srv.url, "/fleet/heartbeat?id=r1", 2.0)["ok"]
        assert not _fleet_call(srv.url, "/fleet/heartbeat?id=ghost", 2.0)["ok"]
        assert _fleet_call(srv.url, "/fleet/members", 2.0)["version"] >= 1
        with pytest.raises(OSError):  # missing params -> structured 400
            _fleet_call(srv.url, "/fleet/register?id=r2", 2.0)
        _fleet_call(srv.url, "/fleet/leave?id=r1", 2.0)
        assert _fleet_call(srv.url, "/fleet/members", 2.0)["live"] == []
        # control-plane chatter never skews the shard request counters
        assert srv.stats()["requests"] == 0
    pf.close()


def test_fleet_member_registers_heartbeats_and_leaves(packed, tmp_path):
    _, shards = packed
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a")
    reg = MembershipRegistry()
    with PeerShardServer(pf, registry=reg) as srv:
        m = FleetMember(
            srv.url, peer_id="r1", serve_url="http://me:9", heartbeat_s=0.05
        )
        m.start()
        _wait_for(lambda: m.heartbeats >= 2)
        assert [x["id"] for x in reg.members()["live"]] == ["r1"]
        m.close()  # graceful leave
        assert reg.members()["live"] == []
        assert m.registry_errors == 0
    pf.close()


def test_fleet_member_view_drives_peer_source():
    """A membership view application adds/removes/benches peers on a
    ring-placed PeerShardSource — and a suspect→live transition rewinds
    the cooldown for exactly one probe (never force-closes)."""
    clock = [0.0]
    ps = PeerShardSource(
        [], placement="ring", cooldown_s=10.0, clock=lambda: clock[0]
    )
    m = FleetMember("http://unused:1", peers=ps)
    m._apply(
        {
            "version": 1,
            "live": [
                {"id": "a", "url": "http://a:1"},
                {"id": "b", "url": "http://b:2"},
            ],
            "suspect": [],
        }
    )
    assert sorted(ps.peer_urls) == ["http://a:1", "http://b:2"]
    assert ps.stats()["membership_changes"] >= 1
    # b misses heartbeats -> suspect: benched preemptively
    m._apply(
        {
            "version": 2,
            "live": [{"id": "a", "url": "http://a:1"}],
            "suspect": [{"id": "b", "url": "http://b:2"}],
        }
    )
    i = ps.peer_urls.index("http://b:2")
    assert ps._state[i] == _OPEN and ps._down_until[i] == 10.0
    assert ps.stats()["peers_suspect"] == 1 and ps.stats()["suspected"] == 1
    # stale (same-version) view is a no-op
    m._apply({"version": 2, "live": [], "suspect": []})
    assert len(ps.peer_urls) == 2
    # b heartbeats again -> live: cooldown rewound, circuit still OPEN
    clock[0] = 1.0
    m._apply(
        {
            "version": 3,
            "live": [
                {"id": "a", "url": "http://a:1"},
                {"id": "b", "url": "http://b:2"},
            ],
            "suspect": [],
        }
    )
    assert ps._state[i] == _OPEN  # the data path keeps final say
    assert ps._down_until[i] <= clock[0]  # next request admits ONE probe
    # a departs entirely
    m._apply(
        {"version": 4, "live": [{"id": "b", "url": "http://b:2"}], "suspect": []}
    )
    assert ps.peer_urls == ["http://b:2"]
    ps.close()


# ---------------------------------------------------------------------------
# breaker × membership: no double-bench, exactly one probe on re-join
# ---------------------------------------------------------------------------
class _FakePeer:
    def __init__(self):
        self.mode = "ok"  # ok | dead
        self.calls = 0
        self.root_url = "http://fake:0"

    def fetch(self, name):
        self.calls += 1
        if self.mode == "dead":
            raise OSError("connection refused")
        return b"payload-" + name.encode()

    def close(self):
        pass


def test_mark_suspect_does_not_double_bench_open_peer():
    """A peer already OPEN from a request-path trip keeps its original
    cooldown when the registry later calls it suspect — the verdicts must
    not stack into a longer bench."""
    clock = [0.0]
    ps = PeerShardSource(
        ["http://a:1"], cooldown_s=5.0, clock=lambda: clock[0]
    )
    fake = _FakePeer()
    ps._sources = [fake]
    fake.mode = "dead"
    with pytest.raises(PeerMiss):
        ps.fetch("s")  # request-path trip at t=0: down until 5.0
    assert ps._state[0] == _OPEN and ps._down_until[0] == 5.0
    clock[0] = 3.0
    ps.mark_suspect("http://a:1")  # registry verdict arrives mid-cooldown
    assert ps._down_until[0] == 5.0  # NOT extended to 8.0
    assert ps.stats()["suspected"] == 0  # no second benching counted
    ps.close()


def test_rejoined_peer_gets_exactly_one_half_open_probe():
    clock = [0.0]
    ps = PeerShardSource(
        ["http://a:1"], cooldown_s=100.0, clock=lambda: clock[0]
    )
    fake = _FakePeer()
    ps._sources = [fake]
    ps.mark_suspect("http://a:1")  # benched until t=100
    with pytest.raises(PeerMiss):
        ps.fetch("s")  # cooling: peer not contacted
    assert fake.calls == 0
    clock[0] = 1.0
    ps.mark_live("http://a:1")  # re-registered: cooldown rewound
    assert ps._state[0] == _OPEN  # not force-closed
    assert ps.fetch("s") == b"payload-s"  # exactly one probe, succeeds
    st = ps.stats()
    assert st["probes"] == 1 and st["recoveries"] == 1
    assert ps._state[0] == _CLOSED
    # mark_live on a CLOSED peer is a no-op (no cooldown to rewind)
    ps.mark_live("http://a:1")
    assert ps._state[0] == _CLOSED
    ps.close()


def test_ring_routes_to_owner_and_replica_only(packed, tmp_path):
    """Ring placement probes owner + replicas, not the whole fleet; the
    shard lands from a peer that holds it via the replica hop."""
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=False)
    pf.reader(name)  # warm
    with PeerShardServer(pf) as warm_srv:
        ps = PeerShardSource([], placement="ring", replicas=1, timeout=1.0)
        # fabricate a fleet where the warm peer is in the owner set
        ps.add_peer(warm_srv.url)
        assert ps.fetch(name) == raw
        assert ps.stats()["hits"] == 1
        # a removed peer's arcs move; fetch now misses (no peers at all)
        ps.remove_peer(warm_srv.url)
        assert ps.stats()["membership_changes"] == 2
        with pytest.raises(PeerMiss):
            ps.fetch(name)
        ps.close()
    pf.close()


# ---------------------------------------------------------------------------
# warm restart: persisted cache manifest + sparse spans
# ---------------------------------------------------------------------------
class _CountingSource:
    """LocalShardSource wrapper that counts what actually hits 'the wire'."""

    def __init__(self, root):
        self.inner = LocalShardSource(root)
        self.fetches = 0
        self.range_fetches = 0

    def fetch(self, name):
        self.fetches += 1
        return self.inner.fetch(name)

    def fetch_range(self, name, start, length):
        self.range_fetches += 1
        return self.inner.fetch_range(name, start, length)

    def close(self):
        pass


def test_warm_restart_reuses_full_shards_without_refetch(packed, tmp_path):
    _, shards = packed
    cache = tmp_path / "cache"
    names = ["shard-00000.rpshard", "shard-00001.rpshard"]
    raws = {n: (shards / n).read_bytes() for n in names}

    src1 = _CountingSource(shards)
    pf1 = ShardPrefetcher(src1, cache, index_first=False, persist_state=True)
    for n in names:
        pf1.reader(n)
    pf1.close()  # persists the manifest
    assert (cache / _WARM_DIR / "manifest.json").is_file()

    src2 = _CountingSource(shards)
    pf2 = ShardPrefetcher(src2, cache, index_first=False, persist_state=True)
    assert pf2.warm_restart_bytes_reused == sum(len(r) for r in raws.values())
    for n in names:
        reader = pf2.reader(n)
        assert bytes(reader.raw(0, reader.nbytes)) == raws[n]
    # zero re-fetch of already-resident bytes
    assert src2.fetches == 0 and src2.range_fetches == 0
    assert pf2.stats()["warm_restart_bytes_reused"] > 0
    pf2.close()


def test_warm_restart_restores_sparse_spans(packed, tmp_path):
    _, shards = packed
    cache = tmp_path / "cache"
    name = "shard-00000.rpshard"

    src1 = _CountingSource(shards)
    pf1 = ShardPrefetcher(src1, cache, index_first=True, persist_state=True)
    r1 = pf1.reader(name, samples=[0, 1])
    assert isinstance(r1, SparseShardReader)
    want = [bytes(r1.read(i)) for i in (0, 1)]
    pf1.close()
    assert (cache / _WARM_DIR / f"{name}.spans").is_file()

    src2 = _CountingSource(shards)
    pf2 = ShardPrefetcher(src2, cache, index_first=True, persist_state=True)
    assert pf2.warm_restart_bytes_reused > 0
    r2 = pf2.peek(name)  # resident without any fetch
    assert isinstance(r2, SparseShardReader)
    assert [bytes(r2.read(i)) for i in (0, 1)] == want
    assert src2.fetches == 0 and src2.range_fetches == 0  # spans were reused
    # a cold sample still demand-fetches exactly its range
    r2.read(5)
    assert src2.range_fetches == 1
    pf2.close()


def test_warm_restart_skips_torn_sidecar(packed, tmp_path):
    _, shards = packed
    cache = tmp_path / "cache"
    name = "shard-00000.rpshard"
    pf1 = ShardPrefetcher(
        _CountingSource(shards), cache, index_first=True, persist_state=True
    )
    pf1.reader(name, samples=[0])
    pf1.close()
    side = cache / _WARM_DIR / f"{name}.spans"
    blob = bytearray(side.read_bytes())
    assert blob.startswith(_WARM_MAGIC)
    blob[len(_WARM_MAGIC) + 10] ^= 0xFF  # flip a payload bit: crc must fail
    side.write_bytes(bytes(blob))

    src2 = _CountingSource(shards)
    pf2 = ShardPrefetcher(src2, cache, index_first=True, persist_state=True)
    assert pf2.warm_restart_bytes_reused == 0  # skipped, never trusted
    assert pf2.peek(name) is None  # cold again; re-fetched on demand
    pf2.close()


# ---------------------------------------------------------------------------
# admission control: token buckets, quotas, inflight cap, Retry-After
# ---------------------------------------------------------------------------
def test_token_bucket_admits_burst_then_rejects_with_eta():
    clock = [0.0]
    tb = TokenBucket(100.0, 200.0, clock=lambda: clock[0])
    assert tb.try_take(200) == 0.0  # the full burst is available
    wait = tb.try_take(100)
    assert wait == pytest.approx(1.0)  # 100 bytes / 100 Bps away
    clock[0] = 1.0  # refilled exactly that much
    assert tb.try_take(100) == 0.0


def test_token_bucket_oversized_body_eventually_admitted():
    clock = [0.0]
    tb = TokenBucket(100.0, 50.0, clock=lambda: clock[0])
    # a body larger than the whole burst: afford threshold clamps to the
    # burst so it admits at full bucket (balance goes negative — that is
    # what enforces the long-run rate)
    assert tb.try_take(500) == 0.0
    assert tb.try_take(1) > 0.0  # deeply in debt now
    clock[0] = 100.0
    assert tb.try_take(1) == 0.0


def test_admission_controller_quota_and_inflight():
    clock = [0.0]
    adm = AdmissionController(max_inflight=1, clock=lambda: clock[0])
    adm.set_quota("greedy", 100.0, 100.0)
    assert adm.admit("greedy", 100) is None  # burst
    assert adm.admit("greedy", 100) == pytest.approx(1.0)  # throttled
    assert adm.admit("polite", 10_000) is None  # no quota -> unmetered
    assert adm.start_request()
    assert not adm.start_request()  # at capacity
    adm.end_request()
    assert adm.start_request()
    st = adm.stats()
    assert st["quota_rejections"] == 1 and st["inflight_rejections"] == 1
    assert st["admission_rejections"] == 2


def test_server_429_carries_retry_after_and_retrying_source_honors_it(
    packed, tmp_path
):
    """Over-quota requests get a structured 429 whose Retry-After stretches
    RetryingSource's backoff (counted in ``throttled``)."""
    from repro.data.shards.testing import serve_shards

    _, shards = packed
    name = "shard-00000.rpshard"
    size = (shards / name).stat().st_size
    adm = AdmissionController()
    # burst covers exactly one whole-shard body; trickle refill
    adm.set_quota("default", 1.0, float(size))
    with serve_shards(shards, admission=adm) as srv:
        http_src = HttpShardSource(srv.url, timeout=5.0)
        assert http_src.fetch(name)  # drains the bucket
        with pytest.raises(SourceUnavailable) as ei:
            http_src.fetch(name)
        assert ei.value.retry_after is not None and ei.value.retry_after > 0
        # RetryingSource stretches its sleep to the server's hint
        sleeps = []
        rs = RetryingSource(http_src, max_retries=2, sleep=sleeps.append)
        with pytest.raises(SourceUnavailable):
            rs.fetch(name)
        assert rs.throttled >= 1
        assert all(s >= ei.value.retry_after * 0.5 for s in sleeps)
        http_src.close()
    assert adm.stats()["quota_rejections"] >= 2


def test_server_inflight_cap_answers_429_at_capacity(packed, tmp_path):
    from repro.data.shards.testing import serve_shards

    _, shards = packed
    adm = AdmissionController(max_inflight=0)  # reject everything
    with serve_shards(shards, admission=adm) as srv:
        src = HttpShardSource(srv.url, timeout=5.0)
        with pytest.raises(SourceUnavailable) as ei:
            src.fetch("shard-00000.rpshard")
        assert ei.value.retry_after == pytest.approx(adm.retry_wait_s)
        src.close()
    assert adm.stats()["inflight_rejections"] >= 1


def test_peer_server_admission_gates_shard_bodies(packed, tmp_path):
    _, shards = packed
    name = "shard-00000.rpshard"
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=False)
    pf.reader(name)
    adm = AdmissionController()
    adm.set_quota("default", 1.0, 1.0)  # one body, then deep debt
    with PeerShardServer(pf, admission=adm) as srv:
        src = HttpShardSource(srv.url)
        assert src.fetch(name)  # full bucket admits once (negative balance)
        with pytest.raises(SourceUnavailable) as ei:
            src.fetch(name)
        assert ei.value.retry_after is not None
        src.close()
    assert adm.stats()["quota_rejections"] == 1
    pf.close()


# ---------------------------------------------------------------------------
# RetryingSource max_elapsed_s: bounded total failure time
# ---------------------------------------------------------------------------
class _AlwaysDown:
    def __init__(self):
        self.calls = 0

    def fetch(self, name):
        self.calls += 1
        raise SourceUnavailable("down")


def test_max_elapsed_s_bounds_the_retry_ladder():
    inner = _AlwaysDown()
    clock = [0.0]

    def fake_sleep(s):
        clock[0] += s

    rs = RetryingSource(
        inner,
        max_retries=50,
        base_delay_s=1.0,
        max_delay_s=10.0,
        jitter=0.0,
        sleep=fake_sleep,
        max_elapsed_s=5.0,
        clock=lambda: clock[0],
    )
    with pytest.raises(SourceUnavailable):
        rs.fetch("x")
    # 1s + 2s sleeps fit in the 5s budget; the 4s one would cross it
    assert clock[0] <= 5.0
    assert inner.calls == 3
    assert rs.deadline_exhausted == 1
    assert rs.stats()["deadline_exhausted"] == 1


def test_max_elapsed_s_validation():
    with pytest.raises(ValueError):
        RetryingSource(_AlwaysDown(), max_elapsed_s=0.0)


# ---------------------------------------------------------------------------
# degradation ladder: shrink_replication rung
# ---------------------------------------------------------------------------
def test_shrink_replication_rung_sheds_replica_probes():
    ps = PeerShardSource(["http://a:1", "http://b:2"], placement="ring", replicas=1)
    tiered = TieredSource(_AlwaysDown(), ps)
    action = shrink_replication(tiered)
    assert action.name == "shrink_replication"
    assert ps.replicas == 1
    action.apply()
    assert ps.replicas == 0
    # after the shed, routing consults only the ring owner
    with ps._lock:
        assert len(ps._candidates_locked("some-shard")) == 1
    # the rung below still works on top of it
    origin_only(tiered).apply()
    assert tiered.peers_disabled
    tiered.close()


# ---------------------------------------------------------------------------
# fleet gauges on /metrics
# ---------------------------------------------------------------------------
def test_add_fleet_renders_fleet_gauges(packed, tmp_path):
    _, shards = packed
    pf = ShardPrefetcher(
        LocalShardSource(shards), tmp_path / "a", persist_state=True
    )
    ps = PeerShardSource(["http://a:1"], placement="ring")
    reg = MembershipRegistry()
    reg.register("r1", "http://a:1")
    adm = AdmissionController(max_inflight=4)
    exp = MetricsExporter()
    exp.add_fleet(peers=ps, registry=reg, admission=adm, prefetcher=pf)
    text = exp.render()
    for metric in (
        "repro_fleet_peers_live",
        "repro_fleet_peers_suspect",
        "repro_fleet_ring_remaps_total",
        "repro_fleet_admission_rejections_total",
        "repro_fleet_warm_restart_bytes_reused_total",
    ):
        assert metric in text, f"missing {metric}"
    assert 'fleet="fleet"' in text
    ps.close()
    pf.close()


# ---------------------------------------------------------------------------
# ShardDataset(fleet=...) end-to-end smoke
# ---------------------------------------------------------------------------
def test_shard_dataset_fleet_mode(packed, tmp_path):
    """A consumer pointed at a registry discovers a warm serving rank and
    reads through it; membership arrives by heartbeat, not config."""
    from repro.data.shards.testing import serve_shards

    _, shards = packed
    # serving rank: a warm prefetcher + peer server hosting the registry
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "rank0")
    for i in range(5):
        pf.reader(f"shard-{i:05d}.rpshard")
    reg = MembershipRegistry()
    with serve_shards(shards) as origin, PeerShardServer(pf, registry=reg) as srv:
        member = FleetMember(
            srv.url, peer_id="rank0", serve_url=srv.url, heartbeat_s=0.05
        )
        member.start()
        ds = ShardDataset(
            origin.url + "/",
            fleet=srv.url,
            cache_dir=tmp_path / "consumer",
            verify_crc=False,
        )
        try:
            _wait_for(lambda: "rank0" in [
                m["id"] for m in reg.members()["live"]
            ])
            _wait_for(
                lambda: ds.prefetcher.source.peers.stats()["peers"] == 1
            )
            assert ds[0] is not None and ds[39] is not None
            st = ds.prefetcher.stats()
            assert st["source_peers_live"] == 1
        finally:
            ds.close()
            member.close()
    pf.close()


def test_shard_dataset_fleet_validation(tmp_path):
    with pytest.raises(TypeError):
        ShardDataset("http://x/", fleet="http://r/", peers=["http://p/"])
    with pytest.raises(TypeError):
        ShardDataset(tmp_path, fleet="http://r/")
    with pytest.raises(TypeError):  # persist_cache needs a real cache_dir
        ShardDataset("http://x/", persist_cache=True)

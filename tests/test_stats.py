"""Dashboard rendering + resource sampling (core/stats.py).

format_stats is the thing a human reads at 3am; these tests pin the
rendering contract — which extra lines appear for which snapshot fields,
how the ttfi / windowed-rate columns format — and the snapshot
passthrough of the cumulative fields the windowed-rate math depends on.
"""

import dataclasses
import time

import pytest

from repro.core.metrics import WindowRates
from repro.core.stats import (
    MAX_ERROR_TYPES,
    ResourceSampler,
    StageStats,
    StageStatsSnapshot,
    format_stats,
)


def snap(name="s", **kw) -> StageStatsSnapshot:
    base = dict(
        name=name, concurrency=2, num_in=10, num_out=9, num_failed=1,
        qps=3.0, avg_task_time=0.002, occupancy=0.5, get_wait=0.1,
        put_wait=0.2, last_error="ValueError('bad')",
    )
    base.update(kw)
    return StageStatsSnapshot(**base)


# -- format_stats ----------------------------------------------------------
def test_format_stats_basic_columns():
    out = format_stats([snap(time_to_first_s=0.1234)])
    hdr = out.splitlines()[0]
    for col in ("stage", "conc", "in", "out", "fail", "qps", "task_ms",
                "occ%", "get_w", "put_w", "ttfi_ms"):
        assert col in hdr
    assert "123.4" in out  # ttfi rendered in ms
    # no window given: no windowed columns
    assert "qps_w" not in hdr


def test_format_stats_ttfi_dash_before_first_item():
    row = format_stats([snap(time_to_first_s=None)]).splitlines()[2]
    assert row.rstrip().endswith("-")


def test_format_stats_window_columns():
    w = {"s": WindowRates(name="s", dt=5.0, in_rate=2.0, qps=7.5,
                          fail_rate=0.0, occupancy=0.25,
                          get_wait_frac=0.1, put_wait_frac=0.0)}
    out = format_stats([snap(), snap(name="other")], window=w)
    hdr = out.splitlines()[0]
    assert "qps_w" in hdr and "occ_w%" in hdr
    row_s = out.splitlines()[2]
    assert "7.5" in row_s and "25.0" in row_s
    # a stage absent from the window dict renders dashes, not garbage
    row_other = out.splitlines()[3]
    assert row_other.rstrip().endswith("-")


def test_format_stats_errors_line():
    out = format_stats(
        [snap(errors_by_type=(("KeyError", 2), ("ValueError", 5)))]
    )
    assert "[s] errors: KeyError=2 ValueError=5 last=ValueError('bad')" in out


def test_format_stats_extra_lines():
    s = snap(
        stragglers=3, straggler_time=0.6, straggler_shed=1,
        num_slabs=4, slabs_in_flight=2, bytes_allocated=2 << 20,
        cache_hits=8, cache_misses=2, cache_evictions=1,
        bytes_cached=1 << 20, prefetch_depth=1, bytes_fetched=1 << 20,
        promotions=2, source_errors=1, source_retries=3,
        peer_hits=5, peer_bytes=1 << 20, origin_bytes=2 << 20,
    )
    out = format_stats([s])
    assert "[s] stragglers: detached=3 avg_ms=200.0 shed=1" in out
    assert "[s] arena: slabs_in_flight=2/4 bytes_allocated=2.0MB" in out
    assert "shard-cache: hits=8 misses=2 (80% hit)" in out
    assert "src_errors=1 src_retries=3" in out
    assert "promotions=2" in out
    assert "[s] peers: peer_hits=5" in out


def test_format_stats_quiet_without_optionals():
    out = format_stats([snap()])
    assert "stragglers" not in out
    assert "arena" not in out
    assert "shard-cache" not in out
    assert "peers" not in out
    assert "errors:" not in out


# -- StageStats recording + snapshot passthrough ---------------------------
def test_errors_by_type_bounded():
    st = StageStats(name="s")
    for i in range(MAX_ERROR_TYPES + 5):
        err = type(f"Err{i}", (RuntimeError,), {})("boom")
        st.record_failure(err)
    assert len(st.errors_by_type) == MAX_ERROR_TYPES + 1  # incl. _other
    assert st.errors_by_type["_other"] == 5
    assert st.num_failed == MAX_ERROR_TYPES + 5
    # an already-tracked type keeps counting even at the cap
    st.record_failure(type("Err0", (RuntimeError,), {})("again"))
    assert st.errors_by_type["Err0"] == 2


def test_snapshot_passthrough():
    st = StageStats(name="s", concurrency=3)
    st.record_task(0.25)
    st.record_out_many(4)
    st.record_failure(ValueError("x"))
    s = st.snapshot()
    assert s.task_time == pytest.approx(0.25)
    assert s.elapsed > 0
    assert s.time_to_first_s is not None and s.time_to_first_s >= 0
    assert dict(s.errors_by_type) == {"ValueError": 1}
    assert dataclasses.asdict(s)["num_out"] == 4


def test_snapshot_ttfi_none_before_output():
    assert StageStats(name="s").snapshot().time_to_first_s is None


def test_record_out_many_zero_keeps_first_out_unset():
    st = StageStats(name="s")
    st.record_out_many(0)
    assert st.first_out_t is None and st.num_out == 0


# -- ResourceSampler -------------------------------------------------------
def test_resource_sampler_read_plausible():
    cpu, rss = ResourceSampler._read()
    assert cpu >= 0.0
    assert rss > 1 << 20  # a CPython process is bigger than 1MB


def test_resource_sampler_current_prefers_background_sample():
    r = ResourceSampler()
    cpu, rss = r.current()  # no samples yet: fresh /proc read
    assert rss > 0
    r.samples.append((time.monotonic(), 1.5, 123))
    assert r.current() == (1.5, 123)


def test_resource_sampler_summary_edge_cases():
    r = ResourceSampler()
    s = r.summary()  # <2 samples: util 0, rss from a fresh read
    assert s["cpu_util"] == 0.0 and s["peak_rss_mb"] > 0
    r.samples = [(0.0, 1.0, 100 << 20), (10.0, 6.0, 300 << 20)]
    s = r.summary()
    assert s["cpu_util"] == pytest.approx(0.5)
    assert s["peak_rss_mb"] == pytest.approx(300.0)
    assert s["avg_rss_mb"] == pytest.approx(200.0)


def test_resource_sampler_background_thread():
    with ResourceSampler(interval=0.01) as r:
        time.sleep(0.08)
    assert len(r.samples) >= 2
    assert r.summary()["peak_rss_mb"] > 0

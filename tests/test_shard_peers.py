"""Peer-to-peer shard exchange + sparse→full promotion: PeerShardServer
serving a live prefetcher cache (whole shards, ranged reads, resident
sparse spans, structured misses), PeerShardSource health tracking,
TieredSource peer→origin fall-through, ShardDataset(peers=[...]) wiring,
stats plumbing to the dashboard, and promotion determinism."""

import http.server
import threading
import time

import numpy as np
import pytest

from repro.core.stats import StageStats, format_stats
from repro.data import (
    LocalShardSource,
    PeerShardServer,
    PeerShardSource,
    ShardDataset,
    ShardPrefetcher,
    ShardReader,
    SimulatedLatencySource,
    SyntheticImageDataset,
    TieredSource,
    pack,
)
from repro.data.shards import PeerMiss
from repro.data.shards.format import HEADER_SIZE, parse_shard_header
from repro.data.shards.prefetch import SparseShardReader
from repro.data.shards.sources import HttpShardSource, RetryingSource
from repro.data.shards.testing import serve_shards


@pytest.fixture()
def packed(tmp_path):
    """(files dataset, packed shard dir) — 40 samples in 5 shards of 8."""
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 40, hw=(16, 16), seed=0)
    pack(ds, tmp_path / "shards", samples_per_shard=8)
    return ds, tmp_path / "shards"


def _wait_for(cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while not cond() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert cond(), "condition not reached before timeout"


# ---------------------------------------------------------------------------
# PeerShardServer: serving the warm cache
# ---------------------------------------------------------------------------
def test_peer_serves_warm_whole_shard_and_ranges(packed, tmp_path):
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=False)
    pf.reader(name)  # warm the cache with a full disk entry
    with PeerShardServer(pf) as peer:
        client = HttpShardSource(peer.url)
        assert client.fetch(name) == raw  # whole shard, byte-exact
        assert client.fetch_range(name, 100, 57) == raw[100:157]  # 206 path
        with pytest.raises(FileNotFoundError):  # structured 404 miss
            client.fetch("shard-00001.rpshard")  # exists at origin, not warm here
        st = peer.stats()
        assert st["served_whole"] == 1 and st["served_ranges"] == 1
        assert st["misses"] == 1
        assert st["bytes_served"] >= len(raw) + 57
        client.close()
    pf.close()


def test_peer_serves_resident_sparse_spans_and_misses_cold(packed, tmp_path):
    """A sparse entry answers header/index ranged reads (re-serialized from
    the parsed index) and resident payload spans; everything else is a
    structured miss — including a whole-shard GET."""
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    _, n, index_off, _ = parse_shard_header(raw[:HEADER_SIZE], name)
    local = ShardReader(shards / name)
    offs, lens = local.offsets, local.lengths
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=True)
    reader = pf.reader(name, samples=[0, 1])
    assert isinstance(reader, SparseShardReader)
    with PeerShardServer(pf) as peer:
        ps = PeerShardSource([peer.url])
        # index-first reads a peer prefetcher would issue: served from the
        # sparse entry without the original header/index blobs
        assert ps.fetch_range(name, 0, HEADER_SIZE) == raw[:HEADER_SIZE]
        assert ps.fetch_range(name, index_off, n * 16) == raw[index_off : index_off + n * 16]
        a, ln = int(offs[0]), int(lens[0]) + int(lens[1])
        assert ps.fetch_range(name, a, ln) == raw[a : a + ln]  # resident span
        with pytest.raises(PeerMiss):  # cold payload range
            ps.fetch_range(name, int(offs[5]), int(lens[5]))
        with pytest.raises(PeerMiss):  # sparse entries can't serve whole shards
            ps.fetch(name)
        assert ps.stats()["misses"] == 2
        ps.close()
    local.close()
    pf.close()


def test_peek_is_non_mutating(packed, tmp_path):
    _, shards = packed
    pf = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a")
    pf.reader("shard-00000.rpshard")
    before = pf.stats()
    assert pf.peek("shard-00000.rpshard") is not None
    assert pf.peek("shard-00001.rpshard") is None  # never fetches
    after = pf.stats()
    assert (after["hits"], after["misses"]) == (before["hits"], before["misses"])
    assert after["bytes_fetched"] == before["bytes_fetched"]
    pf.close()
    assert pf.peek("shard-00000.rpshard") is None  # closed: nothing served


# ---------------------------------------------------------------------------
# the acceptance path: rank B reads rank A's warm cache, zero origin GETs
# ---------------------------------------------------------------------------
def test_rank_b_reads_warm_shards_from_peer_with_zero_origin_requests(packed, tmp_path):
    ds, shards = packed
    with serve_shards(shards) as origin:
        # rank A: warm every shard from the origin
        pf_a = ShardPrefetcher(
            RetryingSource(HttpShardSource(origin.url)),
            tmp_path / "rank_a",
            index_first=False,
        )
        ds_a = ShardDataset(shards, prefetcher=pf_a)
        for name in ds_a.shard_names:
            pf_a.reader(name)
        with PeerShardServer(pf_a) as peer:
            # rank B: origin → retry → peers → prefetcher
            origin_b = HttpShardSource(origin.url)
            tiered = TieredSource(
                RetryingSource(origin_b), PeerShardSource([peer.url])
            )
            pf_b = ShardPrefetcher(tiered, tmp_path / "rank_b", index_first=False)
            ds_b = ShardDataset(shards, prefetcher=pf_b)  # manifest → origin
            origin_requests_before = origin.requests
            for i in range(len(ds_b)):
                np.testing.assert_array_equal(ds_b[i], ds[i])
            # every shard came from the peer: ZERO origin requests
            assert origin.requests == origin_requests_before
            assert origin_b.fetches == 1  # the manifest, nothing else
            tstats = tiered.stats()
            assert tstats["peer_hits"] == ds_b.num_shards
            assert tstats["peer_bytes"] > 0
            assert peer.stats()["served_whole"] == ds_b.num_shards
            # stats flow: tiered → prefetcher (source_*) → snapshot → dashboard
            st = pf_b.stats()
            assert st["source_peer_hits"] == ds_b.num_shards
            assert st["source_origin_bytes"] > 0  # the manifest bytes
            snap = StageStats(name="read", cache=pf_b).snapshot()
            assert snap.peer_hits == ds_b.num_shards
            assert snap.peer_bytes == tstats["peer_bytes"]
            assert snap.origin_bytes == tstats["origin_bytes"]
            rendered = format_stats([snap])
            assert "peer_hits=" in rendered and "origin_bytes=" in rendered
            ds_b.close()
        ds_a.close()


def test_rank_b_ranged_reads_served_by_peer(packed, tmp_path):
    """Index-first rank B: header/index/sample ranged reads all land on the
    peer's full entry — the origin is never consulted for the shard."""
    ds, shards = packed
    pf_a = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=False)
    pf_a.reader("shard-00000.rpshard")
    with serve_shards(shards) as origin, PeerShardServer(pf_a) as peer:
        origin_b = HttpShardSource(origin.url)
        tiered = TieredSource(RetryingSource(origin_b), PeerShardSource([peer.url]))
        pf_b = ShardPrefetcher(tiered, tmp_path / "b", index_first=True)
        reader = pf_b.reader("shard-00000.rpshard", samples=[0, 1])
        assert isinstance(reader, SparseShardReader)
        assert origin.requests == 0  # header + index + span: all peer-served
        assert peer.stats()["served_ranges"] >= 3
        assert tiered.stats()["origin_fetches"] == 0
        assert bytes(reader.read(0)) == bytes(
            pf_a.reader("shard-00000.rpshard").read(0)
        )
        pf_b.close()
    pf_a.close()


def test_shard_dataset_peers_argument_builds_tiered_stack(packed, tmp_path):
    ds, shards = packed
    pf_a = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=False)
    for name in ["shard-%05d.rpshard" % k for k in range(5)]:
        pf_a.reader(name)
    with serve_shards(shards) as origin, PeerShardServer(pf_a) as peer:
        rds = ShardDataset(
            origin.url, cache_dir=tmp_path / "b", peers=[peer.url], peer_timeout=1.0
        )
        requests_after_manifest = origin.requests
        for i in range(len(rds)):
            np.testing.assert_array_equal(rds[i], ds[i])
        assert origin.requests == requests_after_manifest  # shards: peers only
        assert rds.prefetcher.stats()["source_peer_hits"] > 0
        rds.close()
    pf_a.close()
    # misuse is loud
    with pytest.raises(TypeError, match="http"):
        ShardDataset(shards, peers=["http://127.0.0.1:1"])
    with pytest.raises(TypeError, match="TieredSource"):
        ShardDataset("http://127.0.0.1:1", prefetcher=object(), peers=["http://x"])


# ---------------------------------------------------------------------------
# fault paths
# ---------------------------------------------------------------------------
class _DyingPeerHandler(http.server.BaseHTTPRequestHandler):
    """Advertises a body, sends a fragment, kills the connection."""

    protocol_version = "HTTP/1.1"

    def do_GET(self):  # noqa: N802
        self.send_response(200)
        self.send_header("Content-Length", "1000000")
        self.end_headers()
        self.wfile.write(b"x" * 64)
        self.wfile.flush()
        self.connection.close()

    def log_message(self, *args):
        pass


def test_peer_dying_mid_transfer_falls_back_without_poisoning_dedup(packed, tmp_path):
    ds, shards = packed
    dying = http.server.ThreadingHTTPServer(("127.0.0.1", 0), _DyingPeerHandler)
    threading.Thread(target=dying.serve_forever, daemon=True).start()
    try:
        with serve_shards(shards) as origin:
            host, port = dying.server_address[:2]
            tiered = TieredSource(
                RetryingSource(HttpShardSource(origin.url)),
                PeerShardSource([f"http://{host}:{port}"], timeout=1.0),
            )
            pf = ShardPrefetcher(tiered, tmp_path / "c", index_first=False)
            name = "shard-00000.rpshard"
            reader = pf.reader(name)  # peer dies mid-body → origin covers
            assert isinstance(reader, ShardReader)
            assert len(reader.read(0)) > 0
            st = tiered.stats()
            assert st["peer_errors"] == 1 and st["peers_down"] == 1
            assert st["origin_fetches"] == 1
            # dedup not poisoned: no stuck in-flight entry, next read is a hit
            assert name not in pf._inflight
            hits_before = pf.stats()["hits"]
            pf.reader(name)
            assert pf.stats()["hits"] == hits_before + 1
            # the benched peer is skipped outright: next shard goes straight
            # to origin without paying another error/timeout
            pf.reader("shard-00001.rpshard")
            assert tiered.stats()["peer_errors"] == 1
            pf.close()
    finally:
        dying.shutdown()
        dying.server_close()


def test_peer_with_stale_short_copy_is_benched_not_fatal(packed, tmp_path):
    """A peer holding a stale/shorter object under the same shard name
    answers with a 416 or a short 206 — that must bench the peer and fall
    through to the origin, never crash the read path."""
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    stale = tmp_path / "stale"
    stale.mkdir()
    (stale / name).write_bytes(b"x" * 50)  # much shorter than the real shard
    with serve_shards(shards) as origin, serve_shards(stale) as bad_peer:
        tiered = TieredSource(
            RetryingSource(HttpShardSource(origin.url)),
            PeerShardSource([bad_peer.url], timeout=1.0),
        )
        assert tiered.fetch_range(name, 100, 57) == raw[100:157]  # origin covered
        st = tiered.stats()
        assert st["peer_errors"] == 1 and st["peers_down"] == 1
        assert st["origin_fetches"] == 1
        tiered.close()


def test_peer_sparse_miss_falls_through_to_origin(packed, tmp_path):
    """A peer holding only a sparse slice of a shard answers 404 for cold
    ranges; the tier falls through to origin and the read still succeeds."""
    ds, shards = packed
    pf_a = ShardPrefetcher(LocalShardSource(shards), tmp_path / "a", index_first=True)
    pf_a.reader("shard-00000.rpshard", samples=[0, 1])  # sparse on rank A
    with serve_shards(shards) as origin, PeerShardServer(pf_a) as peer:
        tiered = TieredSource(
            RetryingSource(HttpShardSource(origin.url)),
            PeerShardSource([peer.url]),
        )
        pf_b = ShardPrefetcher(tiered, tmp_path / "b", index_first=True)
        reader = pf_b.reader("shard-00000.rpshard", samples=[0])  # peer-served
        assert origin.requests == 0
        # sample 5 is cold on the peer: structured miss → origin range read
        view = reader.read(5)
        local = ShardReader(shards / "shard-00000.rpshard")
        assert bytes(view) == bytes(local.read(5))
        local.close()
        assert origin.requests >= 1
        st = tiered.stats()
        assert st["peer_misses"] >= 1 and st["origin_fetches"] >= 1
        pf_b.close()
    pf_a.close()


# ---------------------------------------------------------------------------
# sparse→full promotion
# ---------------------------------------------------------------------------
def test_promotion_upgrades_with_exactly_one_whole_shard_get(packed, tmp_path):
    ds, shards = packed
    src = SimulatedLatencySource(LocalShardSource(shards), latency_s=0, ranges=True)
    pf = ShardPrefetcher(
        src, tmp_path / "c", index_first=True, promote_threshold=0.25
    )
    rds = ShardDataset(shards, prefetcher=pf)
    name = rds.shard_names[0]
    reader = pf.reader(name, samples=[0])
    assert isinstance(reader, SparseShardReader)
    assert src.fetches == 1  # the manifest; no shard GET yet
    for k in range(1, 5):  # demand reads push demand_bytes past 25% of payload
        np.testing.assert_array_equal(rds[k], ds[k])
    _wait_for(lambda: pf.stats()["promotions"] == 1)
    assert src.fetches == 2  # manifest + EXACTLY ONE whole-shard GET
    promoted = pf.reader(name)
    assert isinstance(promoted, ShardReader)  # a normal disk cache entry
    assert pf.stats()["sparse_shards"] == 0
    ranges_after = pf.stats()["range_fetches"]
    for k in range(8):  # all samples now served from disk, zero wire traffic
        np.testing.assert_array_equal(rds[k], ds[k])
    assert pf.stats()["range_fetches"] == ranges_after
    assert src.fetches == 2
    # the orphaned sparse reader still answers (local-serve, no wire fetch)
    assert bytes(reader.read(7)) == bytes(promoted.read(7))
    assert src.fetches == 2 and pf.stats()["range_fetches"] == ranges_after
    rds.close()


def test_promotion_is_deterministic_under_concurrent_demand_reads(packed, tmp_path):
    ds, shards = packed
    src = SimulatedLatencySource(LocalShardSource(shards), latency_s=0, ranges=True)
    pf = ShardPrefetcher(
        src, tmp_path / "c", index_first=True, promote_threshold=0.1
    )
    rds = ShardDataset(shards, prefetcher=pf)
    name = rds.shard_names[0]
    pf.reader(name, samples=[0])
    fetches_before = src.fetches
    errs = []

    def demand(k):
        try:
            np.testing.assert_array_equal(rds[k], ds[k])
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=demand, args=(k,)) for k in range(1, 8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    _wait_for(lambda: pf.stats()["promotions"] == 1)
    time.sleep(0.1)  # any duplicate upgrade would land in this window
    assert pf.stats()["promotions"] == 1
    assert src.fetches == fetches_before + 1  # exactly one whole-shard GET
    assert isinstance(pf.reader(name), ShardReader)
    rds.close()


def test_promoted_entry_becomes_peer_servable(packed, tmp_path):
    """The point of promotion at multi-rank scale: once rank A upgrades a
    sparse entry, its peer server can hand the WHOLE shard to rank B."""
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    src = SimulatedLatencySource(LocalShardSource(shards), latency_s=0, ranges=True)
    pf = ShardPrefetcher(src, tmp_path / "a", index_first=True, promote_threshold=0.1)
    reader = pf.reader(name, samples=[0])
    with PeerShardServer(pf) as peer:
        client = HttpShardSource(peer.url)
        with pytest.raises(FileNotFoundError):  # sparse: whole GET misses
            client.fetch(name)
        for k in range(1, 4):
            reader.read(k)  # demand reads cross the promotion threshold
        _wait_for(lambda: pf.stats()["promotions"] == 1)
        assert client.fetch(name) == raw  # now served whole to peers
        client.close()
    pf.close()

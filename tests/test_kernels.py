"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle; hypothesis drives randomized shape/content
cases on top of the fixed sweep grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.dequant_normalize import dequant_normalize
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk_qkv(key, b, h, hkv, sq, skv, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, hd), jnp.float32).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,h,hkv,sq,skv,hd",
    [
        (1, 1, 1, 128, 128, 64),
        (2, 4, 4, 256, 256, 64),  # MHA
        (2, 8, 2, 256, 256, 64),  # GQA 4:1
        (1, 4, 1, 128, 512, 128),  # cross-length (decode-ish window)
        (1, 2, 2, 384, 384, 128),  # non-pow2 block count
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, sq, skv, hd, dtype, causal):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), b, h, hkv, sq, skv, hd, dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_attention_block_shapes():
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 1, 2, 2, 512, 512, 64, jnp.float32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-5,
            err_msg=f"block ({bq},{bk})",
        )


@settings(deadline=None, max_examples=10)
@given(
    h=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    nq=st.integers(1, 3),
    nk=st.integers(1, 3),
    hd=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_property(h, group, nq, nk, hd, seed):
    if h % group:
        group = 1
    if nq > nk:
        nq = nk  # causal contract: sq <= skv (queries right-aligned to kv end)
    q, k, v = _mk_qkv(
        jax.random.PRNGKey(seed), 1, h, h // group, nq * 128, nk * 128, hd, jnp.float32
    )
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,l,h,p,g,n,chunk",
    [
        (1, 128, 2, 32, 1, 16, 32),
        (2, 256, 4, 64, 2, 32, 64),
        (1, 256, 4, 64, 4, 128, 128),  # mamba2-780m-like head
        (2, 512, 8, 64, 1, 64, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = (jax.random.normal(ks[0], (b, l, h, p), jnp.float32)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = (jax.random.normal(ks[3], (b, l, g, n)) * 0.3).astype(dtype)
    cm = (jax.random.normal(ks[4], (b, l, g, n)) * 0.3).astype(dtype)
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, a, bm, cm)
    tol = 3e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), atol=tol, rtol=tol)


@settings(deadline=None, max_examples=8)
@given(
    nc=st.integers(1, 4),
    chunk=st.sampled_from([16, 32, 64]),
    h=st.sampled_from([1, 2, 4]),
    p=st.sampled_from([16, 32]),
    n=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssd_scan_property(nc, chunk, h, p, n, seed):
    l = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (1, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (1, l, 1, n)) * 0.3
    cm = jax.random.normal(ks[4], (1, l, 1, n)) * 0.3
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# dequant + normalize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,h,w,c", [(2, 32, 32, 3), (1, 224, 224, 3), (4, 64, 48, 1), (2, 56, 56, 4)]
)
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_dequant_normalize_sweep(n, h, w, c, out_dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (n, h, w, c), 0, 256, jnp.int32).astype(jnp.uint8)
    mean = jnp.array([0.485, 0.456, 0.406, 0.5][:c], jnp.float32)
    std = jnp.array([0.229, 0.224, 0.225, 0.25][:c], jnp.float32)
    out = dequant_normalize(x, mean, std, out_dtype=out_dtype, interpret=True)
    expect = ref.dequant_normalize_ref(x, mean, std, out_dtype=out_dtype)
    assert out.shape == (n, c, h, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=1e-2, rtol=1e-2
    )


def test_ops_auto_dispatch_cpu_matches_ref():
    """ops.* on CPU uses the jnp path; results equal ref directly."""
    from repro.kernels import ops

    q, k, v = _mk_qkv(jax.random.PRNGKey(2), 1, 2, 2, 128, 128, 64, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention_ref(q, k, v)),
        atol=1e-6,
    )

"""Zero-copy batch assembly: slab arena, decode-into-slot, aggregate_into,
double-buffered transfer, and the uint8 wire-format downcast."""

import threading
import time

import numpy as np
import pytest

from repro.core import PipelineBuilder
from repro.data import (
    ArenaClosed,
    SlabArena,
    SyntheticImageDataset,
    SyntheticTokenDataset,
    build_image_loader,
    build_lm_loader,
    decode_sample,
    encode_sample,
)
from repro.data.arena import SLAB_KEY
from repro.data.codec import decode_into, resize_nearest, resize_nearest_into
from repro.data.packing import SequencePacker
from repro.data.transfer import DeviceTransfer


# ---------------------------------------------------------------------------
# arena primitives
# ---------------------------------------------------------------------------
def test_arena_preallocates_and_recycles():
    a = SlabArena({"x": ((4, 4), np.uint8)}, batch_size=8, num_slabs=3)
    assert a.bytes_allocated == 3 * 8 * 16
    assert a.slabs_in_flight == 0
    s1, s2, s3 = a.acquire(), a.acquire(), a.acquire()
    assert a.slabs_in_flight == 3
    assert a.try_acquire() is None  # ring exhausted, non-blocking path
    buf_id = id(s1.arrays["x"])
    a.release(s1)
    s4 = a.acquire()
    assert id(s4.arrays["x"]) == buf_id  # same memory, recycled
    assert a.acquires == 4
    with pytest.raises(RuntimeError):
        a.release(s4) or a.release(s4)  # double release
    a.release(s2), a.release(s3)


def test_arena_acquire_blocks_and_close_wakes():
    a = SlabArena({"x": ((2,), np.int32)}, batch_size=2, num_slabs=2)
    a.acquire(), a.acquire()
    with pytest.raises(TimeoutError):
        a.acquire(timeout=0.05)
    errs = []

    def blocked():
        try:
            a.acquire()
        except ArenaClosed as e:
            errs.append(e)

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()  # parked on the ring
    a.close()
    t.join(timeout=5)
    assert not t.is_alive() and len(errs) == 1


# ---------------------------------------------------------------------------
# decode-into-slot codec variants
# ---------------------------------------------------------------------------
def test_decode_into_matches_decode_sample():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (16, 12, 3), dtype=np.uint8)
    data = encode_sample(img)
    out = np.empty((16, 12, 3), np.uint8)
    decode_into(data, out)
    np.testing.assert_array_equal(out, decode_sample(data))
    with pytest.raises(ValueError):
        decode_into(data, np.empty((8, 12, 3), np.uint8))  # shape mismatch
    with pytest.raises(ValueError):
        decode_into(b"XXXX" + data[4:], out)  # corrupt


def test_resize_nearest_into_matches_resize_nearest():
    rng = np.random.default_rng(1)
    img = rng.integers(0, 256, (37, 53, 3), dtype=np.uint8)
    out = np.empty((16, 24, 3), np.uint8)
    resize_nearest_into(img, out)
    np.testing.assert_array_equal(out, resize_nearest(img, (16, 24)))


# ---------------------------------------------------------------------------
# packer slab emission
# ---------------------------------------------------------------------------
def test_packer_add_into_matches_add():
    rng = np.random.default_rng(2)
    docs = [rng.integers(3, 100, int(rng.integers(4, 40)), dtype=np.int32) for _ in range(12)]
    p_ref, p_slab = SequencePacker(16), SequencePacker(16)
    # nothing releases slabs here, so the ring must cover every emitted row:
    # <= sum(len(doc)) / seq_len rows, comfortably under 16 slabs * 4 rows
    a = SlabArena(
        {k: ((16,), np.int32) for k in ("tokens", "labels", "positions", "segment_ids")},
        batch_size=4,
        num_slabs=16,
    )
    next_slot = a.slot_writer()
    got, want = [], []
    for doc in docs:
        want += p_ref.add(doc)
        got += [r.views() for r in p_slab.add_into(doc.copy(), next_slot)]
    assert len(got) == len(want) > 0
    for g, w in zip(got, want):
        for k in w:
            np.testing.assert_array_equal(g[k], w[k])


# ---------------------------------------------------------------------------
# aggregate_into through the engine
# ---------------------------------------------------------------------------
def _slot_pipeline(arena, n_items, write, *, agg=4, drop_last=False, **pipe_kw):
    return (
        PipelineBuilder()
        .add_source(range(n_items))
        .pipe(arena.binder(), concurrency=1, name="slot")
        .pipe(write, concurrency=2, name="write", **pipe_kw)
        .aggregate_into(arena, agg, drop_last=drop_last, name="batch")
        .add_sink(buffer_size=2)
        .build(num_threads=4)
    )


def _write_x(item):
    i, ref = item
    ref.slab.arrays["x"][ref.slot] = i
    return ref


def test_aggregate_into_clean_path_and_partial_batch():
    arena = SlabArena({"x": ((), np.int64)}, batch_size=4, num_slabs=3)
    p = _slot_pipeline(arena, 10, _write_x)
    out = []
    with p.auto_stop():
        for b in p:
            slab = b.pop(SLAB_KEY)
            out.append(b["x"].copy())
            slab.release()
    assert [list(o) for o in out] == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9]]
    assert arena.slabs_in_flight == 0  # everything recycled


def test_aggregate_into_compacts_holes_into_dense_batches():
    def flaky(item):
        i, ref = item
        if i % 4 == 1:  # 0..11 -> drop 1, 5, 9
            ref.mark_hole()
            raise ValueError(f"bad {i}")
        return _write_x(item)

    arena = SlabArena({"x": ((), np.int64)}, batch_size=4, num_slabs=3)
    p = _slot_pipeline(arena, 12, flaky, drop_last=True)
    out = []
    with p.auto_stop():
        for b in p:
            slab = b.pop(SLAB_KEY)
            out.append(list(b["x"]))
            slab.release()
    # 9 surviving items -> two dense batches of 4, tail dropped
    assert out == [[0, 2, 3, 4], [6, 7, 8, 10]]
    assert arena.slabs_in_flight == 0  # drained slabs auto-released


def test_aggregate_into_never_corrupts_under_out_of_order_upstream():
    """A completion-ordered stage between binder and aggregate violates the
    slot-order contract.  The stage must either fail loudly (monotonic-slot
    guard) or emit every row exactly once — never duplicate/lose rows."""
    import random

    def jitter_write(item):
        time.sleep(random.random() * 0.004)
        return _write_x(item)

    arena = SlabArena({"x": ((), np.int64)}, batch_size=4, num_slabs=4)
    p = (
        PipelineBuilder()
        .add_source(range(64))
        .pipe(arena.binder(), concurrency=1, name="slot")
        .pipe(jitter_write, concurrency=4, name="write", output_order="completion")
        .aggregate_into(arena, 4, name="batch")
        .add_sink(buffer_size=2)
        .build(num_threads=4)
    )
    got = []
    with p.auto_stop():
        try:
            for b in p:
                slab = b.pop(SLAB_KEY)
                got += list(b["x"])
                slab.release()
        except RuntimeError as e:
            assert "preserve input order" in str(e) or "pending rows" in str(e)
        else:
            assert sorted(got) == list(range(64))  # no row lost or duplicated


def test_aggregate_into_releases_tail_slab_spanning_partial_batch():
    """Regression: a final partial batch whose rows span two slabs fully
    drains the trailing (never-sealed) slab via compaction — it must still
    be released, not pinned forever."""

    def flaky(item):
        i, ref = item
        if i in (4, 5, 6):  # hole out most of slab 1
            ref.mark_hole()
            raise ValueError(f"bad {i}")
        return _write_x(item)

    arena = SlabArena({"x": ((), np.int64)}, batch_size=4, num_slabs=4)
    p = _slot_pipeline(arena, 10, flaky)  # drop_last=False
    out = []
    with p.auto_stop():
        for b in p:
            slab = b.pop(SLAB_KEY)
            out.append(list(b["x"]))
            slab.release()
    assert out == [[0, 1, 2, 3], [7, 8, 9]]
    assert arena.slabs_in_flight == 0


def test_image_loader_survives_read_failures(tmp_path):
    """Regression: a failing read must mark its pre-assigned slot as a hole,
    or the slab never fills and the loader stalls out of slabs."""
    ds = SyntheticImageDataset.materialize(tmp_path / "img", 64, hw=(8, 8), seed=0)

    class FlakyReads:
        def __len__(self):
            return len(ds)

        def read_bytes(self, i: int) -> bytes:
            if 16 <= i < 48:  # a failure burst spanning whole slabs
                raise OSError(f"transient I/O error on {i}")
            return ds.read_bytes(i)

    p = build_image_loader(FlakyReads(), batch_size=8, hw=(8, 8), num_threads=4)
    with p.auto_stop():
        batches = [np.asarray(b["images"]) for b in p]
    assert len(batches) == 4  # 32 surviving images -> 4 dense batches
    stats = {s.name: s for s in p.stats()}
    assert stats["read"].num_failed == 32


def test_arena_bounded_under_stalled_consumer_and_stats_exposed(tmp_path):
    """Acceptance: the arena never exceeds its ring under a stalled consumer,
    and Pipeline.stats() reports slabs_in_flight / bytes_allocated."""
    ds = SyntheticImageDataset.materialize(tmp_path / "img", 16, hw=(8, 8), seed=0)
    p = build_image_loader(ds, batch_size=4, hw=(8, 8), num_threads=4, epochs=None)
    p.start()
    try:
        time.sleep(0.02)
        ring = {s.name: s for s in p.stats()}["batch"].num_slabs
        assert ring >= 2
        for _ in range(40):  # sample while the pipeline fills up and stalls
            stats = {s.name: s for s in p.stats()}
            assert stats["batch"].slabs_in_flight <= ring
            time.sleep(0.01)
        stats = {s.name: s for s in p.stats()}
        assert stats["batch"].bytes_allocated == ring * 4 * 8 * 8 * 3
        assert stats["batch"].slabs_in_flight >= 1  # it is genuinely stalled
        assert "arena: slabs_in_flight=" in p.format_stats()
    finally:
        t0 = time.monotonic()
        p.stop()  # must not hang on a binder blocked in acquire
        assert time.monotonic() - t0 < 10


# ---------------------------------------------------------------------------
# loaders end-to-end: zero-copy path must be value-identical to list-collate
# ---------------------------------------------------------------------------
def test_image_loader_zero_copy_matches_fallback(tmp_path):
    ds = SyntheticImageDataset.materialize(tmp_path / "img", 24, hw=(32, 32), seed=0)
    got = {}
    for zc in (True, False):
        p = build_image_loader(ds, batch_size=8, hw=(16, 16), num_threads=4, zero_copy=zc)
        with p.auto_stop():
            got[zc] = [np.asarray(b["images"]).copy() for b in p]
    assert len(got[True]) == len(got[False]) == 3
    for a, b in zip(got[True], got[False]):
        np.testing.assert_array_equal(a, b)


def test_image_loader_zero_copy_native_size_decode(tmp_path):
    """stored hw == target hw routes through decode_into (no resize)."""
    ds = SyntheticImageDataset.materialize(tmp_path / "img", 8, hw=(16, 16), seed=3)
    p = build_image_loader(ds, batch_size=4, hw=(16, 16), num_threads=4)
    with p.auto_stop():
        batches = list(p)
    assert len(batches) == 2
    np.testing.assert_array_equal(np.asarray(batches[0]["images"])[0], ds[0])


def test_image_loader_falls_back_for_non_image_samples(tmp_path):
    """Regression: non-uint8/(H,W,3) datasets must not silently hole out
    every sample on the slab path — the loader sniffs one sample at build
    time and routes to list-collate."""
    import pathlib

    root = pathlib.Path(tmp_path / "clips")
    root.mkdir()
    rng = np.random.default_rng(0)
    names = []
    for i in range(8):  # 4-D "video" samples, like bench_video's
        clip = rng.integers(0, 256, (2, 16, 16, 3), dtype=np.uint8)
        name = f"{i:05d}.rpr"
        (root / name).write_bytes(encode_sample(clip))
        names.append(name)
    (root / "index.txt").write_text("\n".join(names))
    from repro.data import ArrayDataset

    p = build_image_loader(ArrayDataset(root), batch_size=4, hw=(8, 8), num_threads=4)
    with p.auto_stop():
        batches = list(p)
    assert len(batches) == 2  # all samples delivered, none holed out
    stats = {s.name: s for s in p.stats()}
    assert stats["decode"].num_failed == 0
    assert "collate" in stats  # it is the fallback pipeline


def test_lm_loader_zero_copy_matches_fallback():
    ds = SyntheticTokenDataset(200, vocab=1000, min_len=32, max_len=200, seed=1)
    got = {}
    for zc in (True, False):
        p, _ = build_lm_loader(
            ds, seq_len=64, batch_size=4, num_threads=4, seed=7, zero_copy=zc
        )
        with p.auto_stop():
            got[zc] = [
                {k: np.asarray(v).copy() for k, v in b.items()}
                for b, _ in zip(p, range(5))
            ]
    for a, b in zip(got[True], got[False]):
        for k in b:
            np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
# device transfer: double buffering + uint8 wire format
# ---------------------------------------------------------------------------
def test_transfer_double_buffers_slab_release():
    arena = SlabArena({"x": ((4,), np.uint8)}, batch_size=2, num_slabs=3)
    tr = DeviceTransfer(hold_slabs=2)
    slabs = [arena.acquire() for _ in range(3)]
    for i, s in enumerate(slabs):
        s.arrays["x"][:] = i
        tr(s.as_batch())
    # the last hold_slabs=2 stay pinned; the oldest went back to the ring
    assert arena.slabs_in_flight == 2
    assert arena.try_acquire() is slabs[0]
    tr.flush()
    assert arena.slabs_in_flight == 1  # only our re-acquired slab remains


def test_transfer_hold_window_protects_delivered_batches():
    """The copy-then-free race, closed: recycling a slab must never corrupt
    a batch still inside the consumer window.  XLA's CPU backend ALIASES
    slab-sized host buffers in ``device_put`` (small probe arrays get
    copied — the decision is per-buffer), so this must use realistic slab
    sizes to bite."""
    tr = DeviceTransfer(consumer_window=0)  # hold = 2
    n = tr.hold_slabs
    assert n == 2
    row = 384 * 384 * 3  # the image loader's slab row: big enough to alias
    arena = SlabArena({"x": ((row,), np.uint8)}, batch_size=4, num_slabs=n + 1)
    outs = []
    for i in range(n + 1):
        s = arena.acquire()
        s.arrays["x"][:] = i
        outs.append(tr(s.as_batch()))
    # n+1 transfers -> exactly one slab (batch 0's) was recycled; scribble it
    s = arena.acquire()
    s.arrays["x"][:] = 255
    # every batch still inside the hold window must be intact
    for i in range(1, n + 1):
        assert (np.asarray(outs[i]["x"]) == i).all(), f"batch {i} corrupted"


def test_uint8_wire_downcasts_floats_4x_fewer_bytes():
    """Regression: the wire conversion used to be a no-op dict comprehension
    (`v if ... else v`), moving f32 images at full width."""
    rng = np.random.default_rng(0)
    imgs = rng.random((4, 8, 8, 3)).astype(np.float32)  # [0,1]-normalized
    scalars = np.arange(4, dtype=np.float32)  # non-image payload

    wire = DeviceTransfer(uint8_wire=True)
    full = DeviceTransfer(uint8_wire=False)
    out_w = wire({"images": imgs, "t": scalars})
    full({"images": imgs, "t": scalars})

    img_bytes = imgs.nbytes
    assert full.bytes_moved - wire.bytes_moved == img_bytes - img_bytes // 4
    assert full.bytes_moved - scalars.nbytes == 4 * (wire.bytes_moved - scalars.nbytes)
    assert np.asarray(out_w["images"]).dtype == np.uint8
    np.testing.assert_array_equal(
        np.asarray(out_w["images"]),
        np.clip(np.rint(imgs * 255.0), 0, 255).astype(np.uint8),
    )
    assert np.asarray(out_w["t"]).dtype == np.float32  # 1-D payload untouched


def test_uint8_wire_passes_uint8_through():
    imgs = np.arange(4 * 2 * 2 * 3, dtype=np.uint8).reshape(4, 2, 2, 3)
    tr = DeviceTransfer(uint8_wire=True)
    out = tr({"images": imgs})
    assert tr.bytes_moved == imgs.nbytes
    np.testing.assert_array_equal(np.asarray(out["images"]), imgs)

"""Training runtime: end-to-end loop, checkpoint/restart, fault tolerance."""

import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip("repro.dist", reason="dist subsystem not built yet")

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokenDataset, build_lm_loader
from repro.data.sampler import CheckpointableSampler
from repro.runtime import Trainer, TrainerConfig

SHAPE = ShapeConfig("tiny_train", seq_len=32, global_batch=4, kind="train")


def make_parts(tmp_path, *, ckpt_every=5, seed=0):
    cfg = get_smoke_config("olmo-1b")
    ds = SyntheticTokenDataset(200, vocab=cfg.vocab_size, min_len=16, max_len=80, seed=3)
    sampler = CheckpointableSampler(len(ds), batch_size=4, seed=seed)
    pipe, sampler = build_lm_loader(
        ds, seq_len=SHAPE.seq_len, batch_size=SHAPE.global_batch,
        sampler=sampler, num_threads=4,
    )
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=ckpt_every, log_every=5)
    return cfg, pipe, sampler, tcfg


def test_train_loss_decreases(tmp_path):
    cfg, pipe, sampler, tcfg = make_parts(tmp_path)
    trainer = Trainer(cfg, SHAPE, tcfg=tcfg)
    with pipe.auto_stop():
        out = trainer.fit(pipe, steps=30, sampler=sampler)
    hist = out["history"]
    assert trainer.step == 30
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, f"no learning: {first} -> {last}"


def test_checkpoint_restart_resumes_exactly(tmp_path):
    cfg, pipe, sampler, tcfg = make_parts(tmp_path, ckpt_every=10)
    trainer = Trainer(cfg, SHAPE, tcfg=tcfg)
    with pipe.auto_stop():
        trainer.fit(pipe, steps=10, sampler=sampler)
    trainer.manager.wait()
    params_at_10 = jax.tree.map(np.asarray, trainer.params)

    # simulate preemption: new process state, resume from disk
    cfg2, pipe2, sampler2, _ = make_parts(tmp_path)
    resumed = Trainer.from_checkpoint(cfg2, SHAPE, sampler=sampler2, tcfg=tcfg)
    assert resumed.step == 10
    for a, b in zip(jax.tree.leaves(resumed.params), jax.tree.leaves(params_at_10)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # sampler cursor restored (no repeated/ skipped epochs beyond prefetch skew)
    assert sampler2.state_dict()["epoch"] == sampler.state_dict()["epoch"]
    with pipe2.auto_stop():
        out = resumed.fit(pipe2, steps=5, sampler=sampler2)
    assert resumed.step == 15
    assert np.isfinite(out["history"][-1]["loss"])


def test_health_reports_starvation_signal(tmp_path):
    cfg, pipe, sampler, tcfg = make_parts(tmp_path)
    trainer = Trainer(cfg, SHAPE, tcfg=tcfg)
    with pipe.auto_stop():
        trainer.fit(pipe, steps=6, sampler=sampler)
        h = trainer.health()
        assert 0.0 <= h["data_wait_frac"] <= 1.0
        hint = trainer.tuning_hint(pipe)
    assert isinstance(hint, str) and hint


def test_grad_accum_matches_single_batch(tmp_path):
    """accum=2 over the same global batch ≈ accum=1 (same grads modulo bf16)."""
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), dtype="float32")
    from repro.launch.steps import build_train_step
    from repro.optim import init_opt_state
    import jax.numpy as jnp

    shape = ShapeConfig("t", 16, 4, "train")
    b1 = build_train_step(cfg, None, shape, grad_accum=1, donate=False)
    b2 = build_train_step(cfg, None, shape, grad_accum=2, donate=False)
    params = b1.model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(b1.opt_cfg, params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32),
    }
    p1, _, m1 = b1.jitted(params, opt, batch)
    p2, _, m2 = b2.jitted(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-3)

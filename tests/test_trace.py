"""Flight recorder + time-series telemetry (core/trace.py, core/metrics.py).

Covers the tracer's ring/export contract, the module-global install, the
engine integration (a traced pipeline run yields stage + queue spans), the
StatsHistory window/staleness math, and the Prometheus export surface —
standalone server and the mounts on both shard HTTP servers.
"""

import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.core import (
    NULL_TRACER,
    MetricsExporter,
    PipelineBuilder,
    StatsHistory,
    Tracer,
    get_tracer,
    set_tracer,
    tracing,
)
from repro.core.metrics import CONTENT_TYPE_LATEST, stage_metrics_lines
from repro.core.stats import StageStatsSnapshot


def snap(name="s", **kw) -> StageStatsSnapshot:
    base = dict(
        name=name, concurrency=2, num_in=0, num_out=0, num_failed=0,
        qps=0.0, avg_task_time=0.0, occupancy=0.0, get_wait=0.0,
        put_wait=0.0, last_error=None,
    )
    base.update(kw)
    return StageStatsSnapshot(**base)


def _get(url: str) -> tuple[int, str, str]:
    with urllib.request.urlopen(url, timeout=5) as resp:
        return resp.status, resp.headers.get("Content-Type", ""), resp.read().decode()


# -- Tracer ----------------------------------------------------------------
def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", "cat"):
        pass
    NULL_TRACER.complete("x", "cat", 0.0, 1.0)
    NULL_TRACER.instant("x")
    NULL_TRACER.counter("x", {"v": 1})
    assert NULL_TRACER.events() == []


def test_tracer_records_all_phases():
    tr = Tracer()
    t0 = time.monotonic()
    tr.complete("work", "stage", t0, 0.5, {"items": 3})
    tr.instant("mark", "straggler")
    tr.counter("depth", {"q": 7})
    with tr.span("fetch", "shard"):
        pass
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["X", "i", "C", "X"]
    x = evs[0]
    assert x["name"] == "work" and x["cat"] == "stage"
    assert x["dur"] == pytest.approx(0.5e6)
    assert x["args"] == {"items": 3}
    assert evs[1]["s"] == "t"  # thread-scoped instant
    assert len(tr) == 4


def test_tracer_events_sorted_and_epoch_relative():
    tr = Tracer()
    now = time.monotonic()
    tr.complete("late", "c", now + 2.0, 0.1)
    tr.complete("early", "c", now + 1.0, 0.1)
    evs = tr.events()
    assert [e["name"] for e in evs] == ["early", "late"]
    assert all(e["ts"] >= 0 for e in evs)


def test_tracer_ring_is_bounded():
    tr = Tracer(capacity_per_thread=16)
    for i in range(100):
        tr.instant(f"e{i}")
    assert len(tr) == 16
    assert tr.events()[-1]["name"] == "e99"  # newest survive


def test_tracer_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity_per_thread=0)


def test_tracer_one_track_per_thread():
    tr = Tracer()
    tr.instant("main")

    def worker():
        tr.instant("from-worker")

    t = threading.Thread(target=worker, name="trace-worker")
    t.start()
    t.join()
    assert len({e["tid"] for e in tr.events()}) == 2
    names = {
        m["args"]["name"]
        for m in tr.to_chrome()["traceEvents"]
        if m["ph"] == "M" and m["name"] == "thread_name"
    }
    assert "trace-worker" in names


def test_tracer_clear():
    tr = Tracer()
    tr.instant("x")
    tr.clear()
    assert len(tr) == 0 and tr.events() == []


def test_chrome_export_round_trip(tmp_path):
    tr = Tracer()
    tr.complete("work", "stage", time.monotonic(), 0.01,
                {"obj": object()})  # non-JSON arg must not break export
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.loads((tmp_path / "trace.json").read_text())
    assert path.endswith("trace.json")
    assert doc["displayTimeUnit"] == "ms"
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert "M" in phs and "X" in phs
    proc = [e for e in doc["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"]
    assert proc[0]["args"]["name"] == "repro-pipeline"


def test_jsonl_export(tmp_path):
    tr = Tracer()
    tr.instant("a", "cat")
    tr.export_jsonl(str(tmp_path / "ev.jsonl"))
    rows = [json.loads(l) for l in (tmp_path / "ev.jsonl").read_text().splitlines()]
    assert rows and rows[0]["name"] == "a" and "thread" in rows[0]


def test_tracing_context_installs_and_restores():
    assert get_tracer() is NULL_TRACER
    with tracing() as tr:
        assert get_tracer() is tr and tr.enabled
        with tracing() as inner:
            assert get_tracer() is inner
        assert get_tracer() is tr
    assert get_tracer() is NULL_TRACER
    prev = set_tracer(None)
    assert prev is NULL_TRACER


# -- engine integration ----------------------------------------------------
def test_traced_pipeline_emits_stage_and_queue_spans():
    tr = Tracer()
    p = (
        PipelineBuilder()
        .add_source(range(64))
        .pipe(lambda x: x + 1, concurrency=2, chunk=8, name="inc")
        .aggregate(16, name="agg")
        .add_sink(buffer_size=2)
        .build(num_threads=4, trace=tr)
    )
    with p.auto_stop():
        out = [x for b in p for x in b]
    assert out == [x + 1 for x in range(64)]
    cats = {e["cat"] for e in tr.events()}
    assert "stage" in cats and "queue" in cats
    stage_spans = [e for e in tr.events() if e["cat"] == "stage"]
    assert any(e["name"] == "inc" for e in stage_spans)
    assert all(e["dur"] >= 0 for e in stage_spans)


def test_untraced_pipeline_records_nothing():
    p = (
        PipelineBuilder()
        .add_source(range(8))
        .pipe(lambda x: x, name="id")
        .add_sink(buffer_size=2)
        .build(num_threads=2)
    )
    with p.auto_stop():
        list(p)
    assert len(get_tracer().events()) == 0  # NULL tracer throughout


# -- StatsHistory ----------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def rows_fn(counts):
    """stats_fn producing one row whose counters follow `counts` (mutable)."""

    def fn():
        return [
            snap(
                num_out=counts["out"], num_in=counts["out"],
                task_time=counts["task"], get_wait=counts.get("get", 0.0),
                put_wait=counts.get("put", 0.0),
            )
        ]

    return fn


def test_history_requires_source_and_capacity():
    with pytest.raises(ValueError):
        StatsHistory()
    with pytest.raises(ValueError):
        StatsHistory(stats_fn=lambda: [], capacity=1)


def test_history_window_rates():
    clock = FakeClock()
    counts = {"out": 0, "task": 0.0}
    h = StatsHistory(stats_fn=rows_fn(counts), clock=clock)
    h.sample()
    clock.t += 10.0
    counts.update(out=50, task=5.0, get=2.0, put=1.0)
    h.sample()
    w = h.window()["s"]
    assert w.qps == pytest.approx(5.0)
    assert w.in_rate == pytest.approx(5.0)
    assert w.dt == pytest.approx(10.0)
    assert w.occupancy == pytest.approx(5.0 / (10.0 * 2))  # conc=2
    assert w.get_wait_frac == pytest.approx(0.2)
    assert w.put_wait_frac == pytest.approx(0.1)


def test_history_window_needs_two_samples():
    h = StatsHistory(stats_fn=rows_fn({"out": 0, "task": 0.0}))
    assert h.window() == {}
    assert h.last() is None


def test_history_window_picks_deep_enough_baseline():
    clock = FakeClock()
    counts = {"out": 0, "task": 0.0}
    h = StatsHistory(stats_fn=rows_fn(counts), clock=clock)
    for out in (0, 10, 20, 30):
        counts["out"] = out
        h.sample()
        clock.t += 1.0
    clock.t -= 1.0  # the last sample's timestamp
    # ask for 2s: baseline must be the newest sample >= 2s old (t=100+1),
    # giving dt=2 and a delta of 20 items -> 10/s
    w = h.window(2.0)["s"]
    assert w.dt == pytest.approx(2.0)
    assert w.qps == pytest.approx(10.0)
    # deeper than history: falls back to the oldest sample
    w = h.window(100.0)["s"]
    assert w.dt == pytest.approx(3.0)


def test_history_quiet_for_tracks_progress():
    clock = FakeClock()
    counts = {"out": 0, "task": 0.0}
    h = StatsHistory(stats_fn=rows_fn(counts), clock=clock)
    h.sample()
    clock.t += 5.0
    h.sample()  # no progress: quiet grows
    assert h.quiet_for(0) == pytest.approx(5.0)
    assert h.quiet_for(-1) == pytest.approx(5.0)  # pipeline sentinel
    counts["out"] = 3
    clock.t += 1.0
    h.sample()
    assert h.quiet_for(0) == 0.0
    assert h.quiet_for(99) == 0.0  # unknown row: never reported stalled


def test_history_ring_bounded_and_background():
    h = StatsHistory(stats_fn=rows_fn({"out": 0, "task": 0.0}), capacity=4)
    for _ in range(10):
        h.sample()
    assert len(h) == 4
    with StatsHistory(stats_fn=rows_fn({"out": 0, "task": 0.0})) as bg:
        bg._stop_evt.wait(0.05)
    bg.stop()  # idempotent


# -- Prometheus export -----------------------------------------------------
def test_stage_metrics_lines_families_and_labels():
    s = snap(num_out=5, errors_by_type=(("ValueError", 2),),
             time_to_first_s=0.5, cache_hits=3, cache_misses=1,
             peer_hits=2, peer_bytes=10, origin_bytes=20,
             num_slabs=2, slabs_in_flight=1, stragglers=1)
    text = "\n".join(stage_metrics_lines([s], pipeline="train"))
    assert '# TYPE repro_stage_items_out_total counter' in text
    assert 'repro_stage_items_out_total{pipeline="train",stage="s"} 5' in text
    assert 'repro_stage_errors_total{type="ValueError",pipeline="train",stage="s"} 2' in text
    assert "repro_stage_time_to_first_item_seconds" in text
    assert "repro_shard_cache_hits_total" in text
    assert "repro_shard_peer_hits_total" in text
    assert "repro_arena_slabs_in_flight" in text
    assert "repro_stage_stragglers_total" in text
    # HELP/TYPE rendered once per family even with many rows
    two = "\n".join(stage_metrics_lines([s, snap(name="t")]))
    assert two.count("# TYPE repro_stage_items_out_total counter") == 1


def test_metrics_exporter_render_and_errors():
    exp = MetricsExporter()
    exp.add_collector(lambda: ["a_metric 1"])

    def bad():
        raise RuntimeError("scrape-time failure")

    exp.add_collector(bad)
    text = exp.render()
    assert "a_metric 1" in text
    assert "# collector error:" in text and "scrape-time failure" in text


class FakeSampler:
    def current(self):
        return 2.5, 1 << 30


def test_metrics_server_scrape():
    exp = MetricsExporter()
    exp.add_resource_sampler(FakeSampler())
    with exp.serve() as server:
        status, ctype, body = _get(server.url)
        assert status == 200 and ctype == CONTENT_TYPE_LATEST
        assert "repro_process_cpu_seconds_total 2.5" in body
        assert f"repro_process_rss_bytes {1 << 30}" in body
        with pytest.raises(urllib.error.HTTPError):
            _get(server.url.replace("/metrics", "/other"))


def test_metrics_exporter_add_pipeline_samples_history():
    counts = {"out": 0, "task": 0.0}

    class FakePipe:
        def stats(self):
            return rows_fn(counts)()

    pipe = FakePipe()
    h = StatsHistory(pipeline=pipe)
    exp = MetricsExporter()
    exp.add_pipeline(pipe, name="train", history=h)
    exp.render()
    counts["out"] = 4
    text = exp.render()  # each scrape appends a sample -> window gauges
    assert len(h) == 2
    assert 'repro_stage_window_qps{pipeline="train",stage="s"}' in text
    assert 'repro_stage_items_out_total{pipeline="train",stage="s"} 4' in text


def test_shard_server_metrics_mount(tmp_path):
    from repro.data.shards.testing import serve_shards

    (tmp_path / "x.bin").write_bytes(b"payload")
    exp = MetricsExporter()
    exp.add_collector(lambda: ["mounted_metric 42"])
    with serve_shards(tmp_path, metrics=exp) as srv:
        before = srv.requests
        status, ctype, body = _get(srv.url + "/metrics")
        assert status == 200 and ctype == CONTENT_TYPE_LATEST
        assert "mounted_metric 42" in body
        assert srv.requests == before  # scrapes bypass the chaos counters
        # shard serving still works on the same port
        status, _, body = _get(srv.url + "/x.bin")
        assert status == 200 and body == "payload"


def test_shard_server_metrics_unmounted_404(tmp_path):
    from repro.data.shards.testing import serve_shards

    with serve_shards(tmp_path) as srv:
        with pytest.raises(urllib.error.HTTPError):
            _get(srv.url + "/metrics")


def test_peer_server_metrics_mount():
    from repro.data import PeerShardServer

    exp = MetricsExporter()
    exp.add_collector(lambda: ["peer_metric 7"])
    server = PeerShardServer(object(), metrics=exp).start()
    try:
        status, ctype, body = _get(server.url + "/metrics")
        assert status == 200 and ctype == CONTENT_TYPE_LATEST
        assert "peer_metric 7" in body
    finally:
        server.close()

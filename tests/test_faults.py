"""The robustness layer under injected faults: straggler slow lane
(detach, hole-fill, shed, fail-fast provenance), the peer circuit
breaker's half-open probe cycle, hedged fetches, health monitoring +
graceful degradation, the chaos HTTP server's fault repertoire
(truncation, kill, flakiness), Content-Length validation, and
deterministic fault injection."""

import itertools
import socket
import threading
import time
import types

import pytest

from repro.core import (
    ChaosError,
    DegradeAction,
    FaultInjectingStage,
    HealthMonitor,
    PipelineBuilder,
    PipelineFailure,
    PipelineStalled,
    StageHealth,
)


def build(src, *stages, sink=3, threads=4, **bkw):
    b = PipelineBuilder().add_source(src)
    for st in stages:
        st(b)
    return b.add_sink(buffer_size=sink).build(num_threads=threads, **bkw)


def slow_on(slow_set, slow_s=0.3):
    def fn(x):
        if x in slow_set:
            time.sleep(slow_s)
        return x * 10

    return fn


# ---------------------------------------------------------------------------
# straggler slow lane
# ---------------------------------------------------------------------------
def test_slowlane_ordered_holefill_preserves_order():
    """Detached stragglers re-enter at their original position; the wall
    clock shows their chunk-mates did NOT wait for them."""
    p = build(
        range(64),
        lambda b: b.pipe(
            slow_on({5, 21}, 0.4), concurrency=4, chunk=8, straggler_after=0.05
        ),
    )
    t0 = time.monotonic()
    with p.auto_stop():
        out = list(p)
    wall = time.monotonic() - t0
    assert out == [x * 10 for x in range(64)]
    row = p.stats()[1]
    assert row.stragglers == 2
    assert row.straggler_time > 0
    # two 0.4s stragglers overlapped with the stream, not serialized after it
    assert wall < 1.2


def test_slowlane_unordered_emits_stragglers_late():
    p = build(
        range(40),
        lambda b: b.pipe(
            slow_on({3}, 0.3),
            concurrency=2,
            chunk=8,
            straggler_after=0.05,
            output_order="completion",
        ),
    )
    with p.auto_stop():
        out = list(p)
    assert sorted(out) == [x * 10 for x in range(40)]
    # the straggler landed later than its input position
    assert out.index(30) > 3


def test_slowlane_straggler_failure_is_a_hole_under_skip():
    def fn(x):
        if x == 7:
            time.sleep(0.2)
            raise ValueError("slow AND broken")
        return x

    p = build(
        range(32),
        lambda b: b.pipe(fn, concurrency=2, chunk=8, straggler_after=0.05),
    )
    with p.auto_stop():
        out = list(p)
    assert out == [x for x in range(32) if x != 7]


def test_slowlane_straggler_failure_failfast_provenance():
    def fn(x):
        if x == 9:
            time.sleep(0.2)
            raise ValueError("boom")
        return x

    p = build(
        range(32),
        lambda b: b.pipe(
            fn,
            name="work",
            concurrency=2,
            chunk=8,
            straggler_after=0.05,
            on_error="fail",
        ),
    )
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            list(p)
    assert ei.value.stage == "work"
    assert ei.value.item_index == 9


def test_slowlane_sheds_inline_when_pool_saturated():
    """A saturated straggler pool degrades to inline execution (counted),
    never drops or reorders items."""
    p = build(
        range(48),
        lambda b: b.pipe(
            slow_on(set(range(0, 48, 4)), 0.1),
            concurrency=4,
            chunk=8,
            straggler_after=0.02,
        ),
        threads=6,
        straggler_workers=1,
    )
    with p.auto_stop():
        out = list(p)
    assert out == [x * 10 for x in range(48)]
    row = p.stats()[1]
    assert row.straggler_shed > 0


def test_builder_rejects_bad_straggler_config():
    b = PipelineBuilder().add_source(range(4))
    with pytest.raises(ValueError, match="chunk > 1"):
        b.pipe(lambda x: x, straggler_after=0.1)
    with pytest.raises(ValueError, match="> 0 seconds"):
        b.pipe(lambda x: x, chunk=4, straggler_after=0.0)
    with pytest.raises(ValueError, match="vectorized"):
        b.pipe(lambda xs: xs, chunk=4, vectorized=True, straggler_after=0.1)
    with pytest.raises(ValueError, match=">= 0"):
        b.pipe(lambda x: x, chunk=4, straggler_runahead=-1)


def test_fused_straggler_failure_names_phase_and_fused_stage():
    def broken(x):
        if x == 5:
            raise ValueError("bad item")
        return x

    b = (
        PipelineBuilder()
        .add_source(range(16))
        .pipe(lambda x: x, name="a", concurrency=2, chunk=4, straggler_after=0.5)
        .pipe(broken, name="b", concurrency=2, chunk=4, on_error="fail")
    )
    b.fuse("a", "b")
    p = b.add_sink(buffer_size=3).build(num_threads=4)
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            list(p)
    assert ei.value.stage == "b"  # the raising PHASE, not the fused unit
    assert ei.value.phase == "b"
    assert ei.value.fused_stage  # ...but the fused stage is named too
    assert ei.value.item_index == 5


# ---------------------------------------------------------------------------
# chunked fail-fast teardown when a sync fn hangs (whole-chunk backstop)
# ---------------------------------------------------------------------------
def test_chunked_failfast_hang_tears_down_promptly():
    release = threading.Event()

    def hang(x):
        if x == 3:
            release.wait(timeout=30)  # "never returns" at test timescales
        return x

    p = build(
        range(16),
        lambda b: b.pipe(
            hang,
            name="work",
            concurrency=2,
            chunk=4,
            timeout=0.05,  # every phase timed -> whole-chunk budget armed
            on_error="fail",
        ),
    )
    t0 = time.monotonic()
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            list(p)
        assert ei.value.stage == "work"
        release.set()  # let the stuck worker thread exit so stop() can join
    assert time.monotonic() - t0 < 5.0  # consumer unblocked, teardown bounded


# ---------------------------------------------------------------------------
# peer circuit breaker (unit: fake sources + fake clock)
# ---------------------------------------------------------------------------
class _FakePeer:
    def __init__(self):
        self.mode = "ok"  # ok | dead | missing
        self.calls = 0

    def fetch(self, name):
        self.calls += 1
        if self.mode == "dead":
            raise OSError("connection refused")
        if self.mode == "missing":
            raise FileNotFoundError(name)
        return b"payload-" + name.encode()

    def close(self):
        pass


def _breaker(cooldown=10.0):
    from repro.data.shards.peer import PeerShardSource

    clock = [0.0]
    src = PeerShardSource(
        ["http://unused:1"], cooldown_s=cooldown, clock=lambda: clock[0]
    )
    fake = _FakePeer()
    src._sources = [fake]
    src._state = src._state[:1]
    src._down_until = src._down_until[:1]
    return src, fake, clock


def test_breaker_opens_skips_then_probes_half_open():
    from repro.data.shards.peer import PeerMiss

    src, fake, clock = _breaker(cooldown=10.0)
    fake.mode = "dead"
    with pytest.raises(PeerMiss):
        src.fetch("a")  # transport failure -> circuit opens
    assert src.stats()["peers_down"] == 1
    with pytest.raises(PeerMiss):
        src.fetch("b")  # still cooling: peer NOT contacted
    assert fake.calls == 1
    clock[0] = 11.0
    fake.mode = "ok"
    assert src.fetch("c") == b"payload-c"  # the half-open probe
    st = src.stats()
    assert st["probes"] == 1
    assert st["recoveries"] == 1
    assert st["peers_down"] == 0


def test_breaker_failed_probe_reopens():
    from repro.data.shards.peer import PeerMiss

    src, fake, clock = _breaker(cooldown=5.0)
    fake.mode = "dead"
    with pytest.raises(PeerMiss):
        src.fetch("a")
    clock[0] = 6.0
    with pytest.raises(PeerMiss):
        src.fetch("b")  # probe fires and fails -> open again
    st = src.stats()
    assert st["probes"] == 1
    assert st["recoveries"] == 0
    assert st["peers_down"] == 1
    assert fake.calls == 2
    with pytest.raises(PeerMiss):
        src.fetch("c")  # cooling again: not contacted
    assert fake.calls == 2


def test_breaker_half_open_reverts_when_probe_not_attempted():
    """An expired-cooldown peer admitted as the half-open probe but never
    actually contacted (an earlier peer in the rotation served the request
    first) must go back to OPEN — not sit in HALF_OPEN forever with every
    future request skipping it."""
    from repro.data.shards.peer import _CLOSED, _OPEN, PeerShardSource

    clock = [0.0]
    src = PeerShardSource(
        ["http://unused:1", "http://unused:2"],
        cooldown_s=10.0,
        clock=lambda: clock[0],
    )
    good, flaky = _FakePeer(), _FakePeer()
    src._sources = [good, flaky]
    src._state[1] = _OPEN
    src._down_until[1] = 5.0
    clock[0] = 11.0  # cooldown expired: peer 1 is due for a probe
    # rotation starts at peer 0: good serves before the probe is attempted
    assert src.fetch("a") == b"payload-a"
    assert flaky.calls == 0
    assert src._state[1] == _OPEN  # handed back, NOT stuck in HALF_OPEN
    assert src.stats()["probes"] == 0  # an unattempted probe is not a probe
    # the next request (rotation starts at peer 1) actually probes it
    assert src.fetch("b") == b"payload-b"
    assert flaky.calls == 1
    assert src._state[1] == _CLOSED
    st = src.stats()
    assert st["probes"] == 1
    assert st["recoveries"] == 1
    assert st["peers_down"] == 0


def test_breaker_miss_is_a_healthy_answer():
    from repro.data.shards.peer import PeerMiss

    src, fake, clock = _breaker()
    fake.mode = "missing"
    with pytest.raises(PeerMiss):
        src.fetch("a")
    st = src.stats()
    assert st["peers_down"] == 0  # transport fine: circuit stays closed
    assert st["errors"] == 0
    assert st["misses"] == 1


# ---------------------------------------------------------------------------
# hedged fetches (unit: fake origin + fake peer tier)
# ---------------------------------------------------------------------------
class _FakeTier:
    """Duck-typed origin (and inner peer source) for TieredSource."""

    def __init__(self, data=b"D", delay_s=0.0, fail=False):
        self.data, self.delay_s, self.fail = data, delay_s, fail
        self.calls = 0

    def fetch(self, name):
        self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.fail:
            raise OSError("down")
        return self.data

    def stats(self):
        return {}

    def close(self):
        pass


def _peer_tier(fake):
    """A real PeerShardSource (TieredSource requires one) over a fake
    inner source — breaker machinery live, no sockets."""
    from repro.data.shards.peer import PeerShardSource

    src = PeerShardSource(["http://unused:1"], cooldown_s=60.0)
    src._sources = [fake]
    return src


def test_hedge_origin_wins_against_slow_peer():
    from repro.data.shards.peer import TieredSource

    t = TieredSource(
        _FakeTier(b"from-origin"),
        _peer_tier(_FakeTier(b"from-peer", delay_s=0.5)),
        hedge_after_s=0.05,
    )
    t0 = time.monotonic()
    assert t.fetch("x") == b"from-origin"
    assert time.monotonic() - t0 < 0.4  # did not wait out the peer
    st = t.stats()
    assert st["hedges"] == 1
    assert st["hedge_wins"] == 1
    t.close()


def test_hedge_not_launched_when_peer_is_fast():
    from repro.data.shards.peer import TieredSource

    origin = _FakeTier(b"from-origin")
    t = TieredSource(origin, _peer_tier(_FakeTier(b"from-peer")), hedge_after_s=0.5)
    assert t.fetch("x") == b"from-peer"
    st = t.stats()
    assert st["hedges"] == 0
    assert origin.calls == 0
    t.close()


def test_hedge_both_failed_raises_origin_error():
    from repro.data.shards.peer import TieredSource

    t = TieredSource(
        _FakeTier(fail=True),
        _peer_tier(_FakeTier(delay_s=0.2, fail=True)),
        hedge_after_s=0.02,
    )
    with pytest.raises(OSError):
        t.fetch("x")
    t.close()


def test_hedge_concurrency_does_not_fake_peer_slowness():
    """Many concurrent hedged fetches: executor queueing must not read as
    peer slowness.  (The old shared 8-thread pool queued later peer lookups
    past hedge_after_s — spurious hedges — and queued the hedged origin
    fetch behind the very peer ops it was meant to race.)"""
    from repro.data.shards.peer import TieredSource

    origin = _FakeTier(b"from-origin")
    t = TieredSource(
        origin,
        _peer_tier(_FakeTier(b"from-peer", delay_s=0.15)),
        hedge_after_s=0.45,
    )
    results = [None] * 40
    threads = [
        threading.Thread(target=lambda i=i: results.__setitem__(i, t.fetch(f"x{i}")))
        for i in range(40)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=10)
    assert results == [b"from-peer"] * 40
    assert t.stats()["hedges"] == 0
    assert origin.calls == 0
    t.close()


def test_disable_peers_goes_origin_only():
    from repro.data.shards.peer import TieredSource

    peer = _FakeTier(b"from-peer")
    t = TieredSource(_FakeTier(b"from-origin"), _peer_tier(peer), hedge_after_s=0.5)
    t.disable_peers()
    assert t.fetch("x") == b"from-origin"
    assert peer.calls == 0
    assert t.stats()["peers_disabled"] == 1
    t.close()


# ---------------------------------------------------------------------------
# health monitor (unit: stub pipeline + fake clock) and guard (integration)
# ---------------------------------------------------------------------------
class _StubPipeline:
    def __init__(self, names=("source", "work")):
        self.rows = [
            types.SimpleNamespace(name=n, num_in=0, num_out=0, num_failed=0)
            for n in names
        ]
        self.finished = False

    def stats(self):
        return list(self.rows)


def test_health_degrades_escalates_and_stalls():
    clock = [0.0]
    stub = _StubPipeline()
    fired = []
    actions = [
        DegradeAction("rung1", lambda: fired.append(1)),
        DegradeAction("rung2", lambda: fired.append(2)),
    ]
    mon = HealthMonitor(
        stub,
        degraded_after_s=5.0,
        stalled_after_s=30.0,
        actions=actions,
        escalate_every_s=5.0,
        clock=lambda: clock[0],
    )
    stub.rows[1].num_in = 10  # "work" holds items it never disposes of
    assert mon.observe() is StageHealth.HEALTHY  # baseline snapshot
    clock[0] = 6.0
    assert mon.observe() is StageHealth.DEGRADED
    assert fired == [1]  # first rung fires on entering DEGRADED
    clock[0] = 8.0
    mon.observe()
    assert fired == [1]  # second rung paced by escalate_every_s
    clock[0] = 12.0
    mon.observe()
    assert fired == [1, 2]
    clock[0] = 31.0
    with pytest.raises(PipelineStalled) as ei:
        mon.check()
    assert ei.value.stage == "work"
    assert ei.value.snapshot is not None


def test_health_progress_resets_to_healthy():
    clock = [0.0]
    stub = _StubPipeline()
    mon = HealthMonitor(
        stub, degraded_after_s=5.0, stalled_after_s=30.0, clock=lambda: clock[0]
    )
    stub.rows[1].num_in = 10
    mon.observe()
    clock[0] = 6.0
    assert mon.observe() is StageHealth.DEGRADED
    stub.rows[1].num_out = 4  # progress!
    assert mon.observe() is StageHealth.HEALTHY
    assert mon.stage_states()["work"] is StageHealth.HEALTHY


def test_stalled_for_reports_suspect_quiet_time_not_oldest_row():
    """stalled_for_s must be the STALLED stage's quiet time — a stage that
    legitimately finished its run ages ago must not inflate the number."""
    clock = [0.0]
    stub = _StubPipeline()
    mon = HealthMonitor(
        stub, degraded_after_s=5.0, stalled_after_s=30.0, clock=lambda: clock[0]
    )
    # the source finished its whole run at t=0 and is quiet forever after
    stub.rows[0].num_in = stub.rows[0].num_out = 10
    stub.rows[1].num_in = 10
    mon.observe()  # baseline
    for t, done in ((100.0, 4), (200.0, 8)):
        clock[0] = t
        stub.rows[1].num_out = done
        assert mon.observe() is StageHealth.HEALTHY
    clock[0] = 235.0  # "work" quiet for 35s; "source" quiet for 235s
    with pytest.raises(PipelineStalled) as ei:
        mon.check()
    assert ei.value.stage == "work"
    assert ei.value.stalled_for_s == pytest.approx(35.0)


def test_health_quiet_pipeline_blames_source():
    """No stage shows pending work but nothing moves either: the SOURCE is
    the suspect (a stuck source never enqueues anything downstream)."""
    clock = [0.0]
    stub = _StubPipeline()
    mon = HealthMonitor(
        stub, degraded_after_s=5.0, stalled_after_s=10.0, clock=lambda: clock[0]
    )
    mon.observe()
    clock[0] = 11.0
    with pytest.raises(PipelineStalled) as ei:
        mon.check()
    assert ei.value.stage == "source"


def test_health_finished_pipeline_is_healthy():
    clock = [0.0]
    stub = _StubPipeline()
    stub.rows[1].num_in = 10
    stub.finished = True
    mon = HealthMonitor(
        stub, degraded_after_s=1.0, stalled_after_s=2.0, clock=lambda: clock[0]
    )
    mon.observe()
    clock[0] = 100.0
    assert mon.observe() is StageHealth.HEALTHY


def test_guard_raises_instead_of_hanging():
    """End to end: a stage that stops mid-stream turns into a structured
    PipelineStalled at the consumer, never an indefinite block."""
    release = threading.Event()

    def fn(x):
        if x >= 4:
            release.wait(timeout=30)
        return x

    p = build(range(32), lambda b: b.pipe(fn, name="work", concurrency=2, chunk=2))
    mon = HealthMonitor(p, degraded_after_s=0.2, stalled_after_s=0.5)
    got = []
    with p.auto_stop():
        with pytest.raises(PipelineStalled) as ei:
            for item in mon.guard(tick=0.05):
                got.append(item)
        assert ei.value.stage == "work"
        release.set()
    assert got == list(range(4))


def test_guard_tick_shorter_than_interbatch_latency_drops_nothing():
    """Every health tick used to schedule a fresh sink getter and abandon
    the timed-out one mid-consume — so whenever inter-batch latency
    exceeded the tick (the exact degraded case guard exists for), batches
    and the EOF were silently eaten by orphaned getters.  A timed-out
    get_item must resume the SAME getter on the next call."""

    def fn(x):
        time.sleep(0.08)  # every item arrives slower than the tick
        return x

    p = build(range(12), lambda b: b.pipe(fn, name="work", concurrency=1), sink=1)
    mon = HealthMonitor(p, degraded_after_s=5.0, stalled_after_s=10.0)
    with p.auto_stop():
        got = list(mon.guard(tick=0.01))
    assert got == list(range(12))  # nothing leaked, EOF arrived


def test_degrade_action_is_idempotent_and_swallows_errors():
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("broken hook")

    a = DegradeAction("boom", boom)
    a.apply()
    a.apply()
    assert calls == [1]
    assert a.applied


# ---------------------------------------------------------------------------
# chaos HTTP server + Content-Length validation + retry coverage
# ---------------------------------------------------------------------------
@pytest.fixture
def shard_dir(tmp_path):
    d = tmp_path / "shards"
    d.mkdir()
    (d / "a.bin").write_bytes(bytes(range(256)) * 64)
    return d


def test_truncated_body_surfaces_as_source_unavailable(shard_dir):
    from repro.data.shards.sources import HttpShardSource, SourceUnavailable
    from repro.data.shards.testing import serve_shards

    with serve_shards(shard_dir) as srv:
        srv.truncate_next = 1
        src = HttpShardSource(srv.url)
        with pytest.raises(SourceUnavailable):
            src.fetch("a.bin")  # fresh conn: no transparent retry
        assert srv.truncations == 1
        src.close()


def test_retrying_source_repairs_truncated_transfer(shard_dir):
    from repro.data.shards.sources import HttpShardSource, RetryingSource
    from repro.data.shards.testing import serve_shards

    with serve_shards(shard_dir) as srv:
        srv.truncate_next = 2
        src = RetryingSource(HttpShardSource(srv.url), base_delay_s=0.01)
        data = src.fetch("a.bin")
        assert data == (shard_dir / "a.bin").read_bytes()  # intact, never short
        assert srv.truncations == 2
        assert src.stats()["retries"] >= 2
        src.close()


def test_content_length_validation_rejects_clean_short_body(shard_dir):
    """A server that under-delivers but closes cleanly (no socket error):
    only the explicit Content-Length check catches this."""
    from repro.data.shards.sources import HttpShardSource, SourceUnavailable

    srv = socket.create_server(("127.0.0.1", 0))
    port = srv.getsockname()[1]

    def answer():
        conn, _ = srv.accept()
        conn.recv(4096)
        conn.sendall(
            b"HTTP/1.1 200 OK\r\nContent-Length: 100\r\n\r\nshort body"
        )
        conn.close()

    t = threading.Thread(target=answer, daemon=True)
    t.start()
    src = HttpShardSource(f"http://127.0.0.1:{port}")
    with pytest.raises(SourceUnavailable):
        src.fetch("a.bin")
    t.join(timeout=5)
    srv.close()
    src.close()


def test_server_kill_severs_keepalive_connections(shard_dir):
    from repro.data.shards.sources import HttpShardSource, SourceUnavailable
    from repro.data.shards.testing import ShardHTTPServer

    srv = ShardHTTPServer(shard_dir)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    src = HttpShardSource(srv.url)
    assert src.fetch("a.bin")  # establishes a keep-alive connection
    srv.kill()
    with pytest.raises((SourceUnavailable, OSError)):
        src.fetch("a.bin")  # the reused connection must FAIL, not serve
    src.close()
    t.join(timeout=5)


def test_server_flaky_rate_is_seeded(shard_dir):
    from repro.data.shards.sources import HttpShardSource, SourceUnavailable
    from repro.data.shards.testing import serve_shards

    def failures(seed):
        with serve_shards(shard_dir, chaos_seed=seed) as srv:
            srv.flaky_rate = 0.5
            src = HttpShardSource(srv.url)
            pattern = []
            for _ in range(12):
                try:
                    src.fetch("a.bin")
                    pattern.append(0)
                except SourceUnavailable:
                    pattern.append(1)
            src.close()
            return pattern

    assert failures(7) == failures(7)  # same seed, same fault sequence


def test_server_stall_delays_response(shard_dir):
    from repro.data.shards.sources import HttpShardSource
    from repro.data.shards.testing import serve_shards

    with serve_shards(shard_dir) as srv:
        srv.stall_next = 1
        srv.stall_s = 0.3
        src = HttpShardSource(srv.url)
        t0 = time.monotonic()
        src.fetch("a.bin")
        assert time.monotonic() - t0 >= 0.3
        assert srv.stalls == 1
        src.close()


# ---------------------------------------------------------------------------
# deterministic fault injection + seeded latency simulation
# ---------------------------------------------------------------------------
def test_fault_stage_counts_reproducible_across_runs():
    def counts():
        st = FaultInjectingStage(
            lambda x: x, seed=42, slow_rate=0.2, error_rate=0.1, slow_s=0.0
        )
        for i in range(200):
            try:
                st(i)
            except ChaosError:
                pass
        return st.stats()

    assert counts() == counts()
    assert counts()["injected_slow"] > 0
    assert counts()["injected_errors"] > 0


def test_fault_stage_in_pipeline_skip_holes():
    st = FaultInjectingStage(lambda x: x, seed=1, error_rate=0.2)
    p = build(range(64), lambda b: b.pipe(st, concurrency=2, chunk=8))
    with p.auto_stop():
        out = list(p)
    assert len(out) == 64 - st.injected_errors
    assert out == sorted(out)  # holes only, order intact


def test_fault_stage_rejects_bad_rates():
    with pytest.raises(ValueError):
        FaultInjectingStage(lambda x: x, slow_rate=1.5)


def test_simulated_latency_jitter_is_seeded(monkeypatch):
    from repro.data.shards import prefetch as pf

    slept: list[float] = []
    monkeypatch.setattr(pf.time, "sleep", lambda s: slept.append(s))

    class Inner:
        def fetch(self, name):
            return b"x" * 64

    def run(seed):
        slept.clear()
        src = pf.SimulatedLatencySource(
            Inner(), latency_s=0.01, jitter_s=0.05, seed=seed
        )
        for i in range(8):
            src.fetch(f"s{i}")
        return list(slept)

    a, b = run(3), run(3)
    assert a == b  # same seed, identical jitter sequence
    assert run(4) != a
    with pytest.raises(ValueError):
        pf.SimulatedLatencySource(Inner(), jitter_s=-0.1)


# ---------------------------------------------------------------------------
# loader wiring
# ---------------------------------------------------------------------------
def test_loader_straggler_requires_chunk():
    from repro.data import build_image_loader

    class _DS:
        def __len__(self):
            return 0

        def read_bytes(self, i):
            raise IndexError(i)

    with pytest.raises(ValueError, match="chunk > 1"):
        build_image_loader(_DS(), chunk=1, straggler_after=0.5)

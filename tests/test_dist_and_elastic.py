"""Distribution-layer unit tests: sharding rules, plans, elastic dry-run.

These run in a SUBPROCESS with forced host devices so the main test process
keeps seeing 1 device (the dry-run flag must never leak into other tests).
"""

import json
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("repro.dist", reason="dist subsystem not built yet")

from repro.configs import get_config
from repro.dist.sharding import make_plan, make_rules
from repro.models.params import resolve_pspec


def test_rules_divisibility_guards():
    cfg = get_config("musicgen-medium")  # 24 heads: not divisible by 16
    rules = make_rules(cfg, 16, False, ("data",), "model")
    assert rules["heads"] is None  # 24 % 16 != 0 -> replicated attention
    assert rules["ffn"] == "model"  # 6144 % 16 == 0 -> sharded
    plan = make_plan(cfg, None)  # no mesh -> null plan
    assert plan.kv_repeat == 1


def test_resolve_pspec_dedups_axes():
    spec = resolve_pspec(("embed", "ffn"), {"embed": ("data",), "ffn": ("data", "model")})
    # "data" is taken by embed; ffn falls back to the remaining axis
    assert spec[0] == ("data",) or spec[0] == "data"
    assert spec[1] == "model" or spec[1] == ("model",)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import build_step
    from repro.runtime.elastic import elastic_dryrun, make_elastic_mesh

    # degraded pod: 4x16 devices (one host row lost from 16x16... scaled to fit 64)
    rec = elastic_dryrun("qwen3-0.6b", "train_4k", n_data=4)
    print(json.dumps({"elastic": rec["n_devices"], "gb": rec["global_batch"]}))

    # kv_repeat plan on a real mesh
    from repro.dist.sharding import make_plan
    mesh = make_elastic_mesh(4)
    plan = make_plan(get_config("yi-6b"), mesh)
    print(json.dumps({"kv_repeat": plan.kv_repeat, "shard_heads": plan.shard_heads}))
    """
)


@pytest.mark.slow
def test_elastic_dryrun_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True,
        text=True,
        timeout=600,
        cwd=".",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    lines = [l for l in out.stdout.splitlines() if l.startswith("{")]
    rec = json.loads(lines[0])
    assert rec["elastic"] == 64
    assert rec["gb"] % 4 == 0
    plan = json.loads(lines[1])
    assert plan["kv_repeat"] == 4  # yi-6b: kv=4 -> repeat 4 to divide tp=16
    assert plan["shard_heads"] is True

"""Chunked + fused stage execution: semantics parity with the per-item
path (order, per-item error holes, timeouts, backpressure, EOF tails),
fusion's per-phase stats/error attribution, the vectorized chunk mode,
queue get_many/put_many, and the chunked loader wiring (identical batches,
bounded checkpoint skip)."""

import asyncio
import itertools
import threading
import time

import numpy as np
import pytest

from repro.core import PipelineBuilder, PipelineFailure
from repro.core.queues import EOF, MonitoredQueue


def build(src, *stages, sink=3, threads=4, **bkw):
    b = PipelineBuilder().add_source(src)
    for st in stages:
        st(b)
    return b.add_sink(buffer_size=sink).build(num_threads=threads, **bkw)


# ---------------------------------------------------------------------------
# parity with the per-item path
# ---------------------------------------------------------------------------
def test_chunked_preserves_order_and_values():
    p = build(range(200), lambda b: b.pipe(lambda x: x * 2, concurrency=4, chunk=16))
    with p.auto_stop():
        assert list(p) == [x * 2 for x in range(200)]


def test_chunk_larger_than_stream_partial_tail():
    """EOF with a partial tail chunk: the tail still runs and emits."""
    p = build(range(5), lambda b: b.pipe(lambda x: x + 1, concurrency=2, chunk=64))
    with p.auto_stop():
        assert list(p) == [1, 2, 3, 4, 5]


def test_chunked_empty_source():
    p = build([], lambda b: b.pipe(lambda x: x, chunk=8))
    with p.auto_stop():
        assert list(p) == []


def test_chunked_unordered_returns_all_items():
    import random

    def jitter(x):
        time.sleep(random.random() * 0.003)
        return x

    p = build(
        range(60),
        lambda b: b.pipe(jitter, concurrency=4, chunk=8, output_order="completion"),
    )
    with p.auto_stop():
        assert sorted(list(p)) == list(range(60))


def test_chunked_multi_stage_chain_matches_per_item():
    def a(x):
        return x + 1

    def m(x):
        return x * 10

    per_item = build(range(97), lambda b: b.pipe(a), lambda b: b.pipe(m))
    chunked = build(
        range(97),
        lambda b: b.pipe(a, concurrency=3, chunk=13),
        lambda b: b.pipe(m, concurrency=2, chunk=7),
    )
    with per_item.auto_stop():
        want = list(per_item)
    with chunked.auto_stop():
        assert list(chunked) == want


# ---------------------------------------------------------------------------
# failure semantics (satellite: chunked/fused failure coverage)
# ---------------------------------------------------------------------------
def test_mid_chunk_exception_leaves_exactly_one_hole():
    def flaky(x):
        if x == 10:  # exactly one bad item, mid-chunk
            raise ValueError("bad sample 10")
        return x

    p = build(range(32), lambda b: b.pipe(flaky, concurrency=2, chunk=32, name="flaky"))
    with p.auto_stop():
        out = list(p)
    assert out == [x for x in range(32) if x != 10]
    stats = {s.name: s for s in p.stats()}["flaky"]
    assert stats.num_failed == 1
    assert "bad sample 10" in stats.last_error


def test_chunked_fail_fast_raises_and_tears_down():
    """Fail-fast inside a chunk surfaces PipelineFailure and cancels the
    in-flight chunks even with an infinite source (no hang)."""

    def boom(x):
        if x == 37:
            raise RuntimeError("boom")
        return x

    p = build(
        itertools.count(),
        lambda b: b.pipe(boom, concurrency=3, chunk=8, on_error="fail", name="boom"),
    )
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            while True:  # bounded waits: a deadlock fails the test, not CI
                p.get_item(timeout=15)
    assert ei.value.stage == "boom"
    assert isinstance(ei.value.__cause__, RuntimeError)


def test_chunked_timeout_is_a_post_hoc_per_item_failure():
    def hang(x):
        if x == 2:
            time.sleep(0.3)
        return x

    p = build(range(5), lambda b: b.pipe(hang, chunk=4, timeout=0.1, name="hang"))
    with p.auto_stop():
        assert list(p) == [0, 1, 3, 4]
    assert {s.name: s for s in p.stats()}["hang"].num_failed == 1


def test_chunked_backpressure_bounds_runahead():
    """A stalled consumer bounds in-flight work to ~concurrency x chunk
    items plus the (chunk-widened) queues — never the whole source."""
    conc, chunk = 2, 8
    completed = []
    lock = threading.Lock()

    def work(x):
        with lock:
            completed.append(x)
        return x

    p = build(
        range(10_000),
        lambda b: b.pipe(work, concurrency=conc, chunk=chunk, queue_size=1),
        sink=1,
    )
    p.start()
    time.sleep(0.4)
    try:
        # in-flight chunks + chunk-widened input/output queues + sink
        bound = (conc + 3) * chunk + 1
        assert len(completed) <= bound, f"unbounded run-ahead: {len(completed)}"
        assert completed, "pipeline made no progress at all"
    finally:
        p.stop()


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------
def test_fused_stages_report_separate_stats_rows():
    def halve(x):
        return x // 2

    def stringify(x):
        return str(x)

    b = (
        PipelineBuilder()
        .add_source(range(40))
        .pipe(halve, concurrency=2, name="halve", chunk=8)
        .pipe(stringify, concurrency=2, name="stringify", chunk=8)
        .fuse("halve", "stringify")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4)
    with p.auto_stop():
        out = list(p)
    assert out == [str(x // 2) for x in range(40)]
    stats = {s.name: s for s in p.stats()}
    assert set(stats) == {"source", "halve", "stringify"}
    assert stats["halve"].num_in == 40 and stats["halve"].num_out == 40
    assert stats["stringify"].num_in == 40 and stats["stringify"].num_out == 40
    # one runtime: the queue between the stages is gone
    assert len(p._runtimes) == 2
    assert "stringify" in p.format_stats()


def test_fused_failure_attributed_to_the_raising_phase():
    def first(x):
        if x % 5 == 0:
            raise ValueError("first rejects")
        return x

    def second(x):
        if x == 7:
            raise ValueError("second rejects")
        return x

    b = (
        PipelineBuilder()
        .add_source(range(20))
        .pipe(first, concurrency=2, name="first", chunk=4)
        .pipe(second, concurrency=2, name="second", chunk=4)
        .fuse("first", "second")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4)
    with p.auto_stop():
        out = list(p)
    assert out == [x for x in range(20) if x % 5 and x != 7]
    stats = {s.name: s for s in p.stats()}
    assert stats["first"].num_failed == 4
    assert stats["second"].num_failed == 1
    # survivors of phase 1 = items entering phase 2
    assert stats["second"].num_in == 16


def test_fused_fail_fast_names_the_phase():
    def ok(x):
        return x

    def boom(x):
        if x == 3:
            raise RuntimeError("boom")
        return x

    b = (
        PipelineBuilder()
        .add_source(range(10))
        .pipe(ok, concurrency=2, name="ok", chunk=4)
        .pipe(boom, concurrency=2, name="boom", chunk=4, on_error="fail")
        .fuse("ok", "boom")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4)
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            list(p)
    assert ei.value.stage == "boom"


def test_fusion_works_per_item_too():
    """chunk=1 fused stages still collapse into one executor call/item."""
    b = (
        PipelineBuilder()
        .add_source(range(30))
        .pipe(lambda x: x + 1, concurrency=2, name="a")
        .pipe(lambda x: x * 3, concurrency=2, name="b")
        .fuse("a", "b")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4)
    with p.auto_stop():
        assert list(p) == [(x + 1) * 3 for x in range(30)]
    assert len(p._runtimes) == 2


def test_auto_fuse_collapses_eligible_adjacent_stages():
    b = (
        PipelineBuilder()
        .add_source(range(25))
        .pipe(lambda x: x + 1, concurrency=2, name="a")
        .pipe(lambda x: x * 2, concurrency=2, name="b")
        .pipe(lambda x: x - 3, concurrency=2, name="c")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4, auto_fuse=True)
    with p.auto_stop():
        assert list(p) == [(x + 1) * 2 - 3 for x in range(25)]
    assert len(p._runtimes) == 2  # source + one fused a+b+c runtime
    assert {s.name for s in p.stats()} == {"source", "a", "b", "c"}


def test_auto_fuse_skips_ineligible_pairs():
    async def aplus(x):
        return x + 1

    b = (
        PipelineBuilder()
        .add_source(range(10))
        .pipe(aplus, concurrency=2, name="async")  # async: never fused
        .pipe(lambda x: x * 2, concurrency=2, name="sync")
        .add_sink(buffer_size=3)
    )
    p = b.build(num_threads=4, auto_fuse=True)
    with p.auto_stop():
        assert list(p) == [(x + 1) * 2 for x in range(10)]
    assert len(p._runtimes) == 3  # nothing fused


def test_fuse_validation_errors():
    def mk():
        return (
            PipelineBuilder()
            .add_source(range(4))
            .pipe(lambda x: x, name="a", concurrency=2)
            .pipe(lambda x: x, name="b", concurrency=2)
            .pipe(lambda x: x, name="c", concurrency=2)
            .add_sink()
        )

    with pytest.raises(ValueError):  # unknown stage
        mk().fuse("a", "zzz").build()
    with pytest.raises(ValueError):  # not adjacent
        mk().fuse("a", "c").build()
    with pytest.raises(ValueError):  # too few names
        mk().fuse("a")
    with pytest.raises(ValueError):  # duplicate names
        mk().fuse("a", "a")
    with pytest.raises(ValueError):  # overlapping groups
        mk().fuse("a", "b").fuse("b", "c").build()

    async def afn(x):
        return x

    with pytest.raises(ValueError):  # async phase
        (
            PipelineBuilder()
            .add_source(range(4))
            .pipe(afn, name="a")
            .pipe(lambda x: x, name="b")
            .fuse("a", "b")
            .add_sink()
            .build()
        )
    with pytest.raises(ValueError):  # concurrency-1 stage fused wider
        (
            PipelineBuilder()
            .add_source(range(4))
            .pipe(lambda x: x, name="a", concurrency=1)
            .pipe(lambda x: x, name="b", concurrency=4)
            .fuse("a", "b")
            .add_sink()
            .build()
        )


def test_chunk_requires_sync_fn():
    async def afn(x):
        return x

    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).pipe(afn, chunk=4)
    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).pipe(lambda x: x, chunk=0)


# ---------------------------------------------------------------------------
# vectorized chunk mode
# ---------------------------------------------------------------------------
def test_vectorized_stage_matches_per_item():
    def bulk(xs):
        return (np.asarray(xs) * 3).tolist()

    p = build(
        range(100),
        lambda b: b.pipe(bulk, concurrency=2, chunk=16, vectorized=True),
    )
    with p.auto_stop():
        assert list(p) == [x * 3 for x in range(100)]


def test_vectorized_failure_loses_the_whole_chunk():
    def bulk(xs):
        if 10 in xs:
            raise ValueError("chunk poisoned")
        return xs

    p = build(
        range(32),
        lambda b: b.pipe(bulk, concurrency=1, chunk=8, vectorized=True, name="bulk"),
    )
    with p.auto_stop():
        out = list(p)
    # the chunk containing 10 is gone wholesale; others untouched
    assert out == [x for x in range(32) if not (8 <= x < 16)]
    assert {s.name: s for s in p.stats()}["bulk"].num_failed == 8


def test_vectorized_length_mismatch_is_an_error():
    p = build(
        range(16),
        lambda b: b.pipe(lambda xs: xs[:-1], chunk=8, vectorized=True, name="bad"),
    )
    with p.auto_stop():
        assert list(p) == []
    stats = {s.name: s for s in p.stats()}["bad"]
    assert stats.num_failed == 16
    assert "returned" in stats.last_error


def test_vectorized_requires_chunk():
    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).pipe(lambda xs: xs, vectorized=True)


# ---------------------------------------------------------------------------
# queue primitives
# ---------------------------------------------------------------------------
def test_get_many_drains_without_passing_eof():
    async def body():
        q = MonitoredQueue(10)
        for i in range(3):
            await q.put(i)
        await q.put(EOF)
        first = await q.get_many(2)
        assert first == [0, 1]
        rest = await q.get_many(10)
        assert rest == [2, EOF]

    asyncio.run(body())


def test_get_many_blocks_only_for_the_first_item():
    async def body():
        q = MonitoredQueue(10)

        async def feeder():
            await asyncio.sleep(0.05)
            await q.put_many([1, 2, 3])

        task = asyncio.ensure_future(feeder())
        got = await q.get_many(10)
        # woken by item 1; 2/3 may or may not have landed in the same tick
        assert got[0] == 1
        await task

    asyncio.run(body())


def test_put_many_respects_capacity():
    async def body():
        q = MonitoredQueue(2)
        done = []

        async def producer():
            await q.put_many(list(range(6)))
            done.append(True)

        task = asyncio.ensure_future(producer())
        await asyncio.sleep(0.01)
        assert not done  # blocked: queue holds 2
        got = []
        while len(got) < 6:
            got.append(await q.get())
        await task
        assert got == list(range(6))

    asyncio.run(body())


# ---------------------------------------------------------------------------
# chunked loader wiring
# ---------------------------------------------------------------------------
class _FailingDataset:
    """Dataset facade that raises on one index (per-sample-hole tests)."""

    def __init__(self, ds, bad: int):
        self._ds = ds
        self.bad = bad

    def __len__(self):
        return len(self._ds)

    def read_bytes(self, i: int):
        if i == self.bad:
            raise OSError(f"synthetic read failure on {i}")
        return self._ds.read_bytes(i)


def _collect_images(pipe):
    out = []
    with pipe.auto_stop():
        for batch in pipe:
            out.append(np.asarray(batch["images"]).copy())
    return np.concatenate(out) if out else np.empty((0,))


def test_chunked_loader_batches_identical_to_per_item(tmp_path):
    pytest.importorskip("jax", reason="loader transfer stage needs jax")
    from repro.data import SyntheticImageDataset, build_image_loader

    ds = SyntheticImageDataset.materialize(tmp_path, 24, hw=(16, 16), seed=3)
    kw = dict(batch_size=8, hw=(16, 16), num_threads=6, epochs=1)
    want = _collect_images(build_image_loader(ds, chunk=1, fuse_stages=False, **kw))
    got = _collect_images(build_image_loader(ds, chunk=8, **kw))
    assert want.shape == (24, 16, 16, 3)
    np.testing.assert_array_equal(got, want)


def test_chunked_loader_failure_is_one_hole(tmp_path):
    """A failing sample inside a chunk holes exactly itself — the delivered
    stream matches the per-item path's to the byte."""
    pytest.importorskip("jax", reason="loader transfer stage needs jax")
    from repro.data import SyntheticImageDataset, build_image_loader

    base = SyntheticImageDataset.materialize(tmp_path, 24, hw=(16, 16), seed=4)
    kw = dict(batch_size=8, hw=(16, 16), num_threads=6, epochs=1)
    want = _collect_images(
        build_image_loader(_FailingDataset(base, 5), chunk=1, fuse_stages=False, **kw)
    )
    got = _collect_images(build_image_loader(_FailingDataset(base, 5), chunk=8, **kw))
    np.testing.assert_array_equal(got, want)
    # exactly one sample is missing (per-item holes, not per-chunk)
    assert got.shape[0] == 16  # 23 survivors -> 2 full batches, tail dropped


@pytest.mark.parametrize("read_conc,decode_conc", [(4, 4), (2, 8)])
def test_chunked_loader_checkpoint_skip_is_bounded(tmp_path, read_conc, decode_conc):
    """The documented bound: chunking widens the mid-stream checkpoint skip
    by at most (max(read_concurrency, decode_concurrency) + 3) x chunk
    samples on top of the sink-buffered batches — never the whole epoch.
    The max matters: fuse("read", "decode") runs the fused stage at the
    wider of the two concurrencies (the asymmetric case covers it)."""
    pytest.importorskip("jax", reason="loader transfer stage needs jax")
    from repro.data import CheckpointableSampler, SyntheticImageDataset, build_image_loader

    n, batch, chunk, sink = 512, 8, 16, 3
    ds = SyntheticImageDataset.materialize(tmp_path, n, hw=(16, 16), seed=5)
    sampler = CheckpointableSampler(n, batch_size=1, shuffle=False)
    pipe = build_image_loader(
        ds, batch_size=batch, hw=(16, 16), read_concurrency=read_conc,
        decode_concurrency=decode_conc, sink_buffer=sink, sampler=sampler,
        epochs=None, chunk=chunk,
    )
    consumed = 0
    with pipe.auto_stop():
        it = iter(pipe)
        for _ in range(4):
            next(it)
            consumed += batch
        time.sleep(0.3)  # let the pipeline run as far ahead as it can
        handed_out = sampler.state_dict()["cursor"]  # batch_size=1: samples
    skipped = handed_out - consumed
    # batch-level tail: sink buffer + assembly/handoff (2) + the transfer's
    # in-flight dispatch chunk and its chunk-widened input queue (loader
    # default transfer_chunk=2 on each side)
    transfer_chunk = 2
    bound = (max(read_conc, decode_conc) + 3) * chunk + (
        sink + 2 + 2 * transfer_chunk
    ) * batch
    assert 0 <= skipped <= bound, f"skip {skipped} exceeds documented bound {bound}"

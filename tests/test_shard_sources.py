"""Remote shard backends + crash/race hardening: HTTP source (range reads,
connection reuse, 404 vs 5xx), retry/backoff wrapper + stats plumbing,
index-first sparse fetch, writer abort-on-exception, shard-name
sanitization, cancelled-fetch join, and fsync crash-safety hooks."""

import os
import threading
import time

import numpy as np
import pytest

from repro.core.stats import StageStats
from repro.data import (
    CheckpointableSampler,
    ShardCorruption,
    ShardDataset,
    ShardPrefetcher,
    ShardReader,
    ShardWriter,
    SourceUnavailable,
    SyntheticImageDataset,
    build_image_loader,
    decode_sample,
    pack,
)
from repro.data.shards import validate_shard_name
from repro.data.shards.prefetch import SparseShardReader
from repro.data.shards.sources import (
    HttpShardSource,
    RangeNotSupported,
    RetryingSource,
)
from repro.data.shards.testing import serve_shards


@pytest.fixture()
def packed(tmp_path):
    """(files dataset, packed shard dir) — 40 samples in 5 shards of 8."""
    ds = SyntheticImageDataset.materialize(tmp_path / "src", 40, hw=(16, 16), seed=0)
    pack(ds, tmp_path / "shards", samples_per_shard=8)
    return ds, tmp_path / "shards"


# ---------------------------------------------------------------------------
# HttpShardSource
# ---------------------------------------------------------------------------
def test_http_fetch_roundtrip_and_404(packed, tmp_path):
    ds, shards = packed
    with serve_shards(shards) as srv:
        src = HttpShardSource(srv.url)
        name = "shard-00000.rpshard"
        assert src.fetch(name) == (shards / name).read_bytes()
        with pytest.raises(FileNotFoundError):
            src.fetch("no-such-shard.rpshard")
        src.close()


def test_http_fetch_range_206(packed, tmp_path):
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    with serve_shards(shards) as srv:
        src = HttpShardSource(srv.url)
        assert src.fetch_range(name, 0, 32) == raw[:32]
        assert src.fetch_range(name, 100, 57) == raw[100:157]
        assert src.range_supported is True
        assert src.fetch_range(name, 5, 0) == b""
        src.close()


def test_http_fetch_range_server_ignores_range(packed, tmp_path):
    """A server that answers 200 to a ranged request moved the WHOLE body:
    fetch_range surfaces it via RangeNotSupported (so the caller can install
    it instead of re-downloading), counts the true wire bytes, and flips
    ``range_supported`` off."""
    _, shards = packed
    name = "shard-00000.rpshard"
    raw = (shards / name).read_bytes()
    with serve_shards(shards, support_ranges=False) as srv:
        src = HttpShardSource(srv.url)
        with pytest.raises(RangeNotSupported) as ei:
            src.fetch_range(name, 100, 57)
        assert ei.value.body == raw  # the already-downloaded body, intact
        assert src.range_supported is False
        assert src.stats()["bytes_fetched"] == len(raw)  # wire truth
        src.close()


def test_http_connection_reuse(packed, tmp_path):
    """Sequential fetches from one thread ride one keep-alive connection."""
    _, shards = packed
    with serve_shards(shards) as srv:
        src = HttpShardSource(srv.url)
        for _ in range(3):
            src.fetch("shard-00000.rpshard")
            src.fetch_range("shard-00001.rpshard", 0, 32)
        assert srv.requests == 6
        assert srv.connections == 1
        src.close()


def test_http_5xx_is_source_unavailable(packed, tmp_path):
    _, shards = packed
    with serve_shards(shards) as srv:
        src = HttpShardSource(srv.url)
        srv.fail_next = 1
        with pytest.raises(SourceUnavailable):
            src.fetch("shard-00000.rpshard")
        # the connection survives the 503 (body drained): next fetch works
        assert src.fetch("shard-00000.rpshard")
        src.close()


# ---------------------------------------------------------------------------
# RetryingSource
# ---------------------------------------------------------------------------
class _FlakySource:
    """fetch fails ``n_failures`` times, then succeeds."""

    def __init__(self, n_failures, exc=SourceUnavailable("boom")):
        self.n_failures = n_failures
        self.exc = exc
        self.calls = 0

    def fetch(self, name):
        self.calls += 1
        if self.calls <= self.n_failures:
            raise self.exc
        return b"payload:" + name.encode()


def test_retrying_source_retries_then_succeeds():
    inner = _FlakySource(2)
    src = RetryingSource(inner, max_retries=4, base_delay_s=0.001, sleep=lambda s: None)
    assert src.fetch("a") == b"payload:a"
    assert inner.calls == 3
    st = src.stats()
    assert st["errors"] == 2 and st["retries"] == 2


def test_retrying_source_backoff_caps_and_jitters():
    delays = []
    inner = _FlakySource(5)
    src = RetryingSource(
        inner,
        max_retries=5,
        base_delay_s=0.1,
        max_delay_s=0.25,
        jitter=0.5,
        sleep=delays.append,
    )
    src.fetch("a")
    assert len(delays) == 5
    base = [0.1, 0.2, 0.25, 0.25, 0.25]  # doubling, capped
    for d, b in zip(delays, base):
        assert b <= d <= b * 1.5 + 1e-9  # jitter in [1, 1.5)


def test_retrying_source_gives_up_and_skips_404():
    inner = _FlakySource(100)
    src = RetryingSource(inner, max_retries=2, sleep=lambda s: None)
    with pytest.raises(SourceUnavailable):
        src.fetch("a")
    assert inner.calls == 3  # 1 attempt + 2 retries
    missing = _FlakySource(100, exc=FileNotFoundError("gone"))
    src = RetryingSource(missing, max_retries=5, sleep=lambda s: None)
    with pytest.raises(FileNotFoundError):
        src.fetch("a")
    assert missing.calls == 1  # permanent error: never retried
    assert src.stats()["retries"] == 0


def test_retrying_source_mirrors_inner_range_support(packed, tmp_path):
    assert not hasattr(RetryingSource(_FlakySource(0)), "fetch_range")
    _, shards = packed
    with serve_shards(shards) as srv:
        wrapped = RetryingSource(HttpShardSource(srv.url))
        assert hasattr(wrapped, "fetch_range")
        raw = (shards / "shard-00000.rpshard").read_bytes()
        assert wrapped.fetch_range("shard-00000.rpshard", 0, 32) == raw[:32]
        wrapped.close()


def test_retry_counters_reach_pipeline_stats(packed, tmp_path):
    """source errors/retries flow: RetryingSource → prefetcher.stats() →
    StageStats cache probe → snapshot fields → dashboard line."""
    from repro.core.stats import format_stats

    _, shards = packed
    with serve_shards(shards) as srv:
        src = RetryingSource(
            HttpShardSource(srv.url), base_delay_s=0.001, max_delay_s=0.002
        )
        pf = ShardPrefetcher(src, tmp_path / "cache", max_bytes=1 << 30)
        srv.fail_next = 2
        pf.reader("shard-00000.rpshard")  # retries through the 503s
        st = pf.stats()
        assert st["source_retries"] == 2 and st["source_errors"] == 2
        assert st["bytes_fetched"] > 0
        probe = StageStats(name="read", cache=pf)
        snap = probe.snapshot()
        assert snap.source_retries == 2 and snap.source_errors == 2
        assert snap.bytes_fetched == st["bytes_fetched"]
        assert "src_retries=2" in format_stats([snap])
        pf.close()


# ---------------------------------------------------------------------------
# index-first fetch + sparse entries
# ---------------------------------------------------------------------------
def test_index_first_downloads_strictly_fewer_bytes(packed, tmp_path):
    """A window touching 2 of 8 samples per shard: index-first (header +
    index + hinted ranges) must move strictly fewer wire bytes than
    whole-shard fetch, and serve byte-identical samples."""
    ds, shards = packed
    hinted = [0, 1]  # per-shard window
    with serve_shards(shards) as srv:
        whole = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "cw",
            index_first=False,
            max_inflight=8,
        )
        rds = ShardDataset(shards, prefetcher=whole)
        for s in range(rds.num_shards):
            base = 8 * s
            for k in hinted:
                np.testing.assert_array_equal(rds[base + k], ds[base + k])
        whole_stats = whole.stats()
        whole_bytes = whole_stats["bytes_fetched"]
        rds.close()

        sparse = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "cs",
            index_first=True,
            max_inflight=8,
        )
        rds = ShardDataset(shards, prefetcher=sparse)
        assert sparse.index_first is True
        for name in rds.shard_names:
            sparse.schedule(name, samples=hinted)
        for s in range(rds.num_shards):
            base = 8 * s
            for k in hinted:
                np.testing.assert_array_equal(rds[base + k], ds[base + k])
        st = sparse.stats()
        assert st["bytes_fetched"] < whole_bytes  # the acceptance gate
        assert st["index_fetches"] == rds.num_shards
        assert st["sparse_shards"] == rds.num_shards
        # partial-shard accounting: resident bytes are a fraction of the
        # full shards, and stats track them exactly
        assert 0 < st["bytes_cached"] < whole_stats["bytes_cached"]
        rds.close()


def test_sparse_reader_demand_fetches_unhinted_sample(packed, tmp_path):
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        pf.schedule(rds.shard_names[0], samples=[0, 1])
        reader = pf.reader(rds.shard_names[0])
        assert isinstance(reader, SparseShardReader)
        before = pf.stats()
        np.testing.assert_array_equal(rds[5], ds[5])  # never hinted
        after = pf.stats()
        assert after["range_fetches"] == before["range_fetches"] + 1
        assert after["bytes_cached"] > before["bytes_cached"]  # growth counted
        # crc still verified on the sparse path
        with pytest.raises(IndexError):
            reader.read(99)
        rds.close()


def test_sparse_whole_window_promotes_to_full_fetch(packed, tmp_path):
    """Hints covering (nearly) the whole payload skip the sparse path —
    one whole-shard GET beats index + ranged reads."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        pf.schedule(rds.shard_names[0], samples=list(range(8)))
        reader = pf.reader(rds.shard_names[0])
        assert isinstance(reader, ShardReader)  # full, on-disk entry
        assert pf.stats()["sparse_shards"] == 0
        rds.close()


def test_sparse_schedule_tops_up_cached_entry(packed, tmp_path):
    """schedule() on an already-cached sparse entry with new hints fetches
    the missing ranges in the background."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        name = rds.shard_names[0]
        pf.schedule(name, samples=[0])
        reader = pf.reader(name)
        assert isinstance(reader, SparseShardReader)
        assert reader.missing([3, 4]) == [3, 4]
        assert pf.schedule(name, samples=[3, 4]) is True  # top-up accepted
        deadline = time.monotonic() + 5
        while reader.missing([3, 4]) and time.monotonic() < deadline:
            time.sleep(0.01)
        assert reader.missing([3, 4]) == []
        np.testing.assert_array_equal(rds[3], ds[3])
        # nothing missing → nothing to do
        assert pf.schedule(name, samples=[3]) is False
        rds.close()


def test_sparse_eviction_keeps_inflight_views_valid(packed, tmp_path):
    """The sparse twin of the mmap/unlink contract: evicting a sparse entry
    drops its spans, but views already handed out stay valid (refcounted
    bytes)."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "c",
            max_bytes=1,  # floor: at most one resident entry
            index_first=True,
        )
        rds = ShardDataset(shards, prefetcher=pf)
        pf.schedule(rds.shard_names[0], samples=[0])
        view = rds.read_bytes(0)  # memoryview into shard 0's sparse span
        for i in range(8, 40):  # touch the other shards: shard 0 evicted
            rds.read_bytes(i)
        assert pf.stats()["evictions"] >= 1
        np.testing.assert_array_equal(decode_sample(view), ds[0])  # still valid
        rds.close()


def test_range_ignoring_server_installs_body_exactly_one_fetch(packed, tmp_path):
    """Against ShardHTTPServer(support_ranges=False): the whole body the
    'ranged' index read brought down must be INSTALLED and served — exactly
    one wire fetch of the shard, never download-slice-discard-refetch."""
    ds, shards = packed
    with serve_shards(shards, support_ranges=False) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        manifest_requests = srv.requests
        reader = pf.reader(rds.shard_names[0], samples=[0, 1])
        assert isinstance(reader, ShardReader)  # installed as a full disk entry
        assert srv.requests - manifest_requests == 1  # ONE wire fetch, total
        assert pf.stats()["sparse_shards"] == 0
        for k in range(8):  # every sample of shard 0 served from the install
            np.testing.assert_array_equal(rds[k], ds[k])
        assert srv.requests - manifest_requests == 1
        # range_supported flipped: the NEXT shard skips straight to one
        # whole-shard GET (no doomed index read first)
        pf.schedule(rds.shard_names[1], samples=[0, 1])
        np.testing.assert_array_equal(rds[8], ds[8])
        assert isinstance(pf.reader(rds.shard_names[1]), ShardReader)
        assert srv.requests - manifest_requests == 2
        rds.close()


def test_demand_read_installs_whole_body_from_range_ignoring_source(packed, tmp_path):
    """A source that STOPS honoring ranges mid-run (CDN tier change): a
    sparse reader's demand fetch gets the whole body back, the prefetcher
    installs it over the sparse entry, and later demand reads are served
    locally — no further wire fetches."""
    from repro.data import LocalShardSource
    from repro.data.shards import RangeNotSupported

    ds, shards = packed

    class FlipFlopSource:
        """Honors ranges for the header+index reads, then answers every
        ranged read with the whole object."""

        def __init__(self, root):
            self.inner = LocalShardSource(root)
            self.range_calls = 0
            self.whole_bodies = 0

        def fetch(self, name):
            return self.inner.fetch(name)

        def fetch_range(self, name, start, length):
            self.range_calls += 1
            if self.range_calls <= 2:  # header, then index region
                return self.inner.fetch_range(name, start, length)
            self.whole_bodies += 1
            raise RangeNotSupported(name, self.inner.fetch(name))

    src = FlipFlopSource(shards)
    pf = ShardPrefetcher(src, tmp_path / "c", index_first=True)
    rds = ShardDataset(shards, prefetcher=pf)
    name = rds.shard_names[0]
    # hinted ensure([0]) is the 3rd ranged read → whole body → installed
    reader = pf.reader(name, samples=[0])
    assert isinstance(reader, ShardReader)
    assert src.whole_bodies == 1
    assert pf.stats()["sparse_shards"] == 0
    for k in range(8):
        np.testing.assert_array_equal(rds[k], ds[k])
    assert src.whole_bodies == 1  # the one body covered everything
    rds.close()


def test_url_dataset_cleans_up_auto_cache_dir(packed, tmp_path):
    ds, shards = packed
    with serve_shards(shards) as srv:
        rds = ShardDataset(srv.url)  # no cache_dir: mkdtemp'd internally
        auto = rds._auto_cache_dir
        assert auto is not None and auto.is_dir()
        rds.read_bytes(0)
        rds.close()
        assert not auto.exists()  # removed with the dataset
        # explicit cache_dir: caller owns it, close() must leave it alone
        mine = tmp_path / "mine"
        rds = ShardDataset(srv.url, cache_dir=mine)
        rds.read_bytes(0)
        rds.close()
        assert mine.is_dir()


def test_url_dataset_bad_manifest_does_not_leak_stack(packed, tmp_path):
    """__init__ failing after the stack was built (hostile manifest) must
    close the prefetcher and remove the auto cache dir."""
    import json

    _, shards = packed
    manifest = json.loads((shards / "manifest.json").read_text())
    manifest["shards"][0]["name"] = "../evil"
    (shards / "manifest.json").write_text(json.dumps(manifest))
    before = set(os.listdir(tempfile_dir()))
    with serve_shards(shards) as srv:
        with pytest.raises(ValueError, match="unsafe shard name"):
            ShardDataset(srv.url)
    leaked = [
        d for d in set(os.listdir(tempfile_dir())) - before
        if d.startswith("repro-shard-cache-")
    ]
    assert leaked == []


def tempfile_dir():
    import tempfile

    return tempfile.gettempdir()


def test_sparse_insert_keeps_spans_nesting_free(packed, tmp_path):
    """A coalesced span that swallows an earlier single-sample span must
    replace it (no double-held bytes, no shadowed lookups forcing redundant
    demand fetches)."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        name = rds.shard_names[0]
        pf.schedule(name, samples=[5])  # lone middle sample
        reader = pf.reader(name)
        assert isinstance(reader, SparseShardReader)
        # top-up around it: [4, 6] coalesces across resident sample 5
        reader.ensure([4, 6])
        assert len(reader._spans) == 1  # the nested span was absorbed
        payload = sum(int(reader.lengths[k]) for k in (4, 5, 6))
        assert reader.nbytes == reader.index.index_nbytes + payload  # no double count
        ranges_before = pf.stats()["range_fetches"]
        for k in (4, 5, 6):  # all resident: reads must not re-fetch
            np.testing.assert_array_equal(decode_sample(reader.read(k)), ds[k])
        assert pf.stats()["range_fetches"] == ranges_before
        rds.close()


def test_concurrent_demand_dedup_over_http(packed, tmp_path):
    """Hammering one remote dataset from many threads: every shard crosses
    the wire exactly once (fetch dedup holds under the real HTTP backend)."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "c",
            max_bytes=1 << 30,
            index_first=False,
        )
        rds = ShardDataset(shards, prefetcher=pf)
        errs = []

        def hammer():
            try:
                for i in range(0, len(rds), 3):
                    np.testing.assert_array_equal(rds[i], ds[i])
            except Exception as e:  # pragma: no cover - failure path
                errs.append(e)

        threads = [threading.Thread(target=hammer) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        # manifest + one GET per shard, no duplicates despite 6 threads
        assert srv.requests == rds.num_shards + 1
        rds.close()


def test_url_root_dataset_end_to_end(packed, tmp_path):
    """ShardDataset('http://...') builds the full stack (HTTP → retry →
    prefetcher) and feeds the image loader, hints and all."""
    ds, shards = packed
    with serve_shards(shards) as srv:
        rds = ShardDataset(srv.url, cache_dir=tmp_path / "cache")
        assert len(rds) == 40
        assert rds.prefetcher is not None and rds.prefetcher.index_first
        p = build_image_loader(
            rds,
            batch_size=8,
            hw=(16, 16),
            num_threads=4,
            sampler=CheckpointableSampler(len(rds), batch_size=1, shuffle=False),
        )
        with p.auto_stop():
            batches = list(p)
        assert len(batches) == 5
        for b in batches:
            assert np.asarray(b["images"]).shape == (8, 16, 16, 3)
        stats = {s.name: s for s in p.stats()}
        assert stats["read"].num_failed == 0
        assert stats["read"].bytes_fetched > 0
        rds.close()


# ---------------------------------------------------------------------------
# satellite: closed-prefetcher demand fetch
# ---------------------------------------------------------------------------
def test_closed_prefetcher_demand_fetch_raises_documented_error(packed, tmp_path):
    """A sparse reader that outlives the prefetcher (evicted before
    close()): its demand read must surface the documented
    RuntimeError('ShardPrefetcher is closed'), not whatever socket error
    the closed backend produces."""
    _, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "c",
            max_bytes=1,  # floor: at most one resident entry
            index_first=True,
        )
        rds = ShardDataset(shards, prefetcher=pf)
        reader = pf.reader(rds.shard_names[0], samples=[0])
        assert isinstance(reader, SparseShardReader)
        pf.reader(rds.shard_names[1], samples=[0])  # evicts shard 0's entry
        assert pf.stats()["evictions"] >= 1
        pf.close()
        with pytest.raises(RuntimeError, match="ShardPrefetcher is closed"):
            reader.read(5)  # non-resident: would demand-fetch
        rds.close()


# ---------------------------------------------------------------------------
# satellite: crc verification memoized per sample
# ---------------------------------------------------------------------------
def test_crc_verified_once_per_sample(tmp_path, monkeypatch):
    """Epoch 2+ over a warm shard must not re-pay the crc32 pass; opting
    out with verify=False never pays (or memoizes) it."""
    import repro.data.shards.format as fmt

    path = tmp_path / "s.rpshard"
    with ShardWriter(path) as w:
        w.add(b"a" * 512)
        w.add(b"b" * 512)
    counts = {"n": 0}
    real_crc = fmt.zlib.crc32

    def spy(data, *a):
        counts["n"] += 1
        return real_crc(data, *a)

    monkeypatch.setattr(fmt.zlib, "crc32", spy)
    r = ShardReader(path)
    r.read(0)
    r.read(0)
    r.read(0)
    assert counts["n"] == 1  # verified exactly once
    r.read(1)
    assert counts["n"] == 2
    r.read(1, verify=False)
    assert counts["n"] == 2
    r.close()


def test_crc_failure_is_never_memoized(tmp_path):
    """A corrupt sample must raise on EVERY read (per-sample hole), not
    sneak through after the first failure."""
    path = tmp_path / "s.rpshard"
    with ShardWriter(path) as w:
        w.add(b"a" * 512)
    raw = bytearray(path.read_bytes())
    raw[40] ^= 0xFF  # flip a payload bit
    path.write_bytes(raw)
    r = ShardReader(path)
    for _ in range(3):
        with pytest.raises(ShardCorruption):
            r.read(0)
    r.close()


def test_sparse_crc_verified_once_per_sample(packed, tmp_path, monkeypatch):
    import repro.data.shards.prefetch as pfm

    _, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=True
        )
        rds = ShardDataset(shards, prefetcher=pf)
        reader = pf.reader(rds.shard_names[0], samples=[0, 1])
        assert isinstance(reader, SparseShardReader)
        counts = {"n": 0}
        real_crc = pfm.zlib.crc32

        def spy(data, *a):
            counts["n"] += 1
            return real_crc(data, *a)

        monkeypatch.setattr(pfm.zlib, "crc32", spy)
        reader.read(0)
        reader.read(0)
        assert counts["n"] == 1
        rds.close()


# ---------------------------------------------------------------------------
# satellite: ShardWriter abort / fsync
# ---------------------------------------------------------------------------
def test_writer_exception_leaves_invalid_file(tmp_path):
    """An exception inside the `with` body must NOT finalize the shard: the
    zero placeholder header stays and readers reject the file."""
    path = tmp_path / "crash.rpshard"
    with pytest.raises(RuntimeError, match="mid-stream"):
        with ShardWriter(path) as w:
            w.add(b"partial payload")
            raise RuntimeError("mid-stream failure")
    assert path.exists()
    with pytest.raises(ShardCorruption):
        ShardReader(path)


def test_writer_abort_is_explicit_and_idempotent(tmp_path):
    path = tmp_path / "ab.rpshard"
    w = ShardWriter(path)
    w.add(b"x" * 100)
    w.abort()
    w.abort()  # idempotent
    with pytest.raises(RuntimeError):
        w.add(b"more")  # closed
    with pytest.raises(ShardCorruption):
        ShardReader(path)
    # abort after close is a no-op: the finalized shard stays valid
    path2 = tmp_path / "ok.rpshard"
    w2 = ShardWriter(path2)
    w2.add(b"y" * 10)
    w2.close()
    w2.abort()
    ShardReader(path2).close()


def test_writer_close_fsyncs_before_header(tmp_path, monkeypatch):
    """The payload+index fsync must land BEFORE the header write that
    validates the file (crash between them must not leave a magic-valid
    shard with unsynced contents)."""
    events = []
    real_fsync = os.fsync

    def spy_fsync(fd):
        events.append("fsync")
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    path = tmp_path / "s.rpshard"
    w = ShardWriter(path)
    orig_seek = w._f.seek

    def spy_seek(pos, *a):
        if pos == 0:
            events.append("header_write")
        return orig_seek(pos, *a)

    w._f.seek = spy_seek
    w.add(b"z" * 64)
    w.close()
    assert "fsync" in events
    assert events.index("fsync") < events.index("header_write")
    ShardReader(path).close()


def test_cache_fetch_fsyncs_before_rename(packed, tmp_path, monkeypatch):
    """_fetch_full must fsync the staged bytes before the atomic replace —
    a crash after the rename must not leave a torn magic-valid cache file."""
    _, shards = packed
    synced = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: (synced.append(fd), real_fsync(fd))[1])
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)), tmp_path / "c", index_first=False
        )
        pf.reader("shard-00000.rpshard")
        assert synced  # the staged cache file was fsynced
        pf.close()


# ---------------------------------------------------------------------------
# satellite: shard-name sanitization
# ---------------------------------------------------------------------------
def test_validate_shard_name_rejects_traversal():
    for bad in ("../evil", "a/b", "..", ".", "", "a\\b", " pad ", "~root", "a\0b"):
        with pytest.raises(ValueError, match="unsafe shard name"):
            validate_shard_name(bad)
    assert validate_shard_name("shard-00000.rpshard") == "shard-00000.rpshard"


def test_hostile_manifest_rejected_at_parse(packed, tmp_path):
    import json

    _, shards = packed
    manifest = json.loads((shards / "manifest.json").read_text())
    manifest["shards"][0]["name"] = "../../etc/evil.rpshard"
    (shards / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="unsafe shard name"):
        ShardDataset(shards)
    with serve_shards(shards) as srv:
        with pytest.raises(ValueError, match="unsafe shard name"):
            ShardDataset(srv.url, cache_dir=tmp_path / "cache")


def test_prefetcher_rejects_traversal_names(packed, tmp_path):
    _, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(RetryingSource(HttpShardSource(srv.url)), tmp_path / "c")
        with pytest.raises(ValueError, match="unsafe shard name"):
            pf.reader("../escape.rpshard")
        with pytest.raises(ValueError, match="unsafe shard name"):
            pf.schedule("../escape.rpshard")
        assert not (tmp_path / "escape.rpshard").exists()
        pf.close()


# ---------------------------------------------------------------------------
# satellite: close() vs in-flight / queued fetches
# ---------------------------------------------------------------------------
def test_reader_joining_cancelled_fetch_gets_runtime_error(packed, tmp_path):
    """A background fetch queued (not yet started) when close() runs is
    cancelled by the pool; a reader() that joined it must see the
    documented RuntimeError, not a raw CancelledError."""
    _, shards = packed
    with serve_shards(shards) as srv:
        pf = ShardPrefetcher(
            RetryingSource(HttpShardSource(srv.url)),
            tmp_path / "c",
            max_inflight=1,
        )
        # occupy the single pool worker so the next schedule stays queued
        gate = threading.Event()
        pf._pool.submit(gate.wait)
        assert pf.schedule("shard-00000.rpshard") is True  # queued, not started
        results = []

        def join():
            try:
                results.append(pf.reader("shard-00000.rpshard"))
            except BaseException as e:
                results.append(e)

        t = threading.Thread(target=join)
        t.start()
        time.sleep(0.05)  # joiner is blocked on the queued future
        closer = threading.Thread(target=pf.close)
        closer.start()
        time.sleep(0.05)
        gate.set()  # let the pool drain so close() can finish
        closer.join(timeout=5)
        t.join(timeout=5)
        assert not t.is_alive() and not closer.is_alive()
        assert len(results) == 1
        assert isinstance(results[0], RuntimeError), results[0]
        assert "closed" in str(results[0])

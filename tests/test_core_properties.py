"""Property-based tests (hypothesis) for pipeline engine invariants.

Invariants, for any stage graph and any failure pattern:
  1. ordered pipelines are exactly ``map`` over the source (order + content);
  2. no sample is lost or duplicated: emitted + failed == consumed;
  3. aggregate∘disaggregate == identity;
  4. failure sets are exactly the items whose stage fn raised.
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import PipelineBuilder

COMMON = dict(
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow],
)


@settings(**COMMON)
@given(
    items=st.lists(st.integers(min_value=-(2**40), max_value=2**40), max_size=200),
    concurrency=st.integers(min_value=1, max_value=16),
    threads=st.integers(min_value=1, max_value=8),
    queue_size=st.integers(min_value=1, max_value=8),
)
def test_ordered_pipeline_is_map(items, concurrency, threads, queue_size):
    p = (
        PipelineBuilder()
        .add_source(items)
        .pipe(lambda x: x * 3 + 1, concurrency=concurrency, queue_size=queue_size)
        .add_sink(buffer_size=2)
        .build(num_threads=threads)
    )
    with p.auto_stop():
        assert list(p) == [x * 3 + 1 for x in items]


@settings(**COMMON)
@given(
    items=st.lists(st.integers(min_value=0, max_value=10_000), max_size=150),
    fail_mod=st.integers(min_value=2, max_value=7),
    concurrency=st.integers(min_value=1, max_value=8),
    order=st.sampled_from(["input", "completion"]),
)
def test_no_loss_no_duplication_under_failures(items, fail_mod, concurrency, order):
    def flaky(x):
        if x % fail_mod == 0:
            raise ValueError(x)
        return x

    p = (
        PipelineBuilder()
        .add_source(items)
        .pipe(flaky, concurrency=concurrency, output_order=order, name="flaky")
        .add_sink(buffer_size=4)
        .build(num_threads=4)
    )
    with p.auto_stop():
        out = list(p)
    expect = [x for x in items if x % fail_mod != 0]
    if order == "input":
        assert out == expect
    else:
        assert sorted(out) == sorted(expect)
    stats = {s.name: s for s in p.stats()}["flaky"]
    assert stats.num_failed == len(items) - len(expect)
    assert stats.num_out == len(expect)
    assert stats.num_in == len(items)


@settings(**COMMON)
@given(
    items=st.lists(st.integers(), max_size=120),
    agg=st.integers(min_value=1, max_value=17),
)
def test_aggregate_disaggregate_identity(items, agg):
    p = (
        PipelineBuilder()
        .add_source(items)
        .aggregate(agg)
        .disaggregate()
        .add_sink(buffer_size=2)
        .build(num_threads=2)
    )
    with p.auto_stop():
        assert list(p) == items


@settings(**COMMON)
@given(
    items=st.lists(st.integers(min_value=0, max_value=1000), min_size=0, max_size=100),
    agg=st.integers(min_value=1, max_value=9),
    drop_last=st.booleans(),
)
def test_aggregate_sizes(items, agg, drop_last):
    p = (
        PipelineBuilder()
        .add_source(items)
        .aggregate(agg, drop_last=drop_last)
        .add_sink(buffer_size=2)
        .build(num_threads=2)
    )
    with p.auto_stop():
        batches = list(p)
    full, rem = divmod(len(items), agg)
    expect_n = full + (0 if (drop_last or rem == 0) else 1)
    assert len(batches) == expect_n
    assert all(len(b) == agg for b in batches[: full if rem else expect_n])
    flat = [x for b in batches for x in b]
    assert flat == items[: len(flat)]

"""Behavioural tests for the SPDL pipeline engine (paper §5.5/§5.9)."""

import asyncio
import threading
import time
from concurrent.futures import TimeoutError as FuturesTimeoutError

import pytest

from repro.core import OnError, PipelineBuilder, PipelineFailure


def build(src, *stages, sink=3, threads=4, **bkw):
    b = PipelineBuilder().add_source(src)
    for st in stages:
        st(b)
    return b.add_sink(buffer_size=sink).build(num_threads=threads, **bkw)


# ---------------------------------------------------------------------------
# basic semantics
# ---------------------------------------------------------------------------
def test_identity_map_preserves_order():
    p = build(range(100), lambda b: b.pipe(lambda x: x * 2, concurrency=4))
    with p.auto_stop():
        out = list(p)
    assert out == [x * 2 for x in range(100)]


def test_multi_stage_chain():
    p = build(
        range(50),
        lambda b: b.pipe(lambda x: x + 1, concurrency=3),
        lambda b: b.pipe(lambda x: x * 10, concurrency=2),
        lambda b: b.pipe(str),
    )
    with p.auto_stop():
        out = list(p)
    assert out == [str((x + 1) * 10) for x in range(50)]


def test_async_stage_function():
    async def slow_double(x):
        await asyncio.sleep(0.001)
        return x * 2

    p = build(range(40), lambda b: b.pipe(slow_double, concurrency=8))
    with p.auto_stop():
        assert list(p) == [x * 2 for x in range(40)]


def test_async_source():
    async def agen():
        for i in range(25):
            await asyncio.sleep(0)
            yield i

    p = build(agen(), lambda b: b.pipe(lambda x: -x))
    with p.auto_stop():
        assert list(p) == [-i for i in range(25)]


def test_aggregate_batches():
    p = build(range(10), lambda b: b.aggregate(3))
    with p.auto_stop():
        out = list(p)
    assert out == [[0, 1, 2], [3, 4, 5], [6, 7, 8], [9]]


def test_aggregate_drop_last():
    p = build(range(10), lambda b: b.aggregate(3, drop_last=True))
    with p.auto_stop():
        out = list(p)
    assert out == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]


def test_disaggregate_roundtrip():
    p = build(range(20), lambda b: b.aggregate(6), lambda b: b.disaggregate())
    with p.auto_stop():
        assert list(p) == list(range(20))


def test_empty_source():
    p = build([], lambda b: b.pipe(lambda x: x))
    with p.auto_stop():
        assert list(p) == []


def test_completion_order_returns_all_items():
    import random

    def jitter(x):
        time.sleep(random.random() * 0.005)
        return x

    p = build(range(30), lambda b: b.pipe(jitter, concurrency=8, output_order="completion"))
    with p.auto_stop():
        out = list(p)
    assert sorted(out) == list(range(30))


# ---------------------------------------------------------------------------
# concurrency actually happens
# ---------------------------------------------------------------------------
def test_sync_stage_runs_concurrently_in_thread_pool():
    """time.sleep releases the GIL, so N concurrent tasks finish ~1 period."""
    n, dt = 8, 0.1

    def blocker(x):
        time.sleep(dt)
        return x

    p = build(range(n), lambda b: b.pipe(blocker, concurrency=n), threads=n, sink=n)
    t0 = time.monotonic()
    with p.auto_stop():
        out = list(p)
    elapsed = time.monotonic() - t0
    assert sorted(out) == list(range(n))
    assert elapsed < n * dt * 0.6, f"no concurrency: {elapsed:.2f}s for {n}x{dt}s tasks"


def test_stage_concurrency_is_bounded():
    active, peak = 0, 0
    lock = threading.Lock()

    def tracked(x):
        nonlocal active, peak
        with lock:
            active += 1
            peak = max(peak, active)
        time.sleep(0.01)
        with lock:
            active -= 1
        return x

    p = build(range(32), lambda b: b.pipe(tracked, concurrency=3), threads=16, sink=32)
    with p.auto_stop():
        list(p)
    assert peak <= 3, f"concurrency bound violated: peak={peak}"


def test_backpressure_blocks_upstream():
    """With a tiny sink and no consumer, the source must stall (bounded
    memory — the paper's queue-propagated congestion)."""
    produced = []

    def src():
        for i in range(10_000):
            produced.append(i)
            yield i

    p = build(src(), lambda b: b.pipe(lambda x: x, queue_size=2), sink=2)
    p.start()
    time.sleep(0.3)
    try:
        # source + in-flight + queues ≈ small constant, never thousands
        assert len(produced) < 50, f"backpressure failed: {len(produced)} produced"
    finally:
        p.stop()


def test_pipeline_processes_while_consumer_is_slow():
    """Prefetch: sink buffer should be (re)filled while consumer sleeps."""
    p = build(range(6), lambda b: b.pipe(lambda x: x), sink=3)
    with p.auto_stop():
        it = iter(p)
        first = next(it)
        time.sleep(0.2)  # let the pipeline run ahead
        assert p.sink_occupancy > 0.5
        rest = list(it)
    assert [first] + rest == list(range(6))


# ---------------------------------------------------------------------------
# robustness (paper §5.4)
# ---------------------------------------------------------------------------
def test_failures_are_skipped_and_counted():
    def flaky(x):
        if x % 3 == 0:
            raise ValueError(f"bad sample {x}")
        return x

    p = build(range(30), lambda b: b.pipe(flaky, concurrency=4, name="flaky"))
    with p.auto_stop():
        out = list(p)
    assert out == [x for x in range(30) if x % 3 != 0]
    stats = {s.name: s for s in p.stats()}
    assert stats["flaky"].num_failed == 10
    assert "bad sample" in stats["flaky"].last_error


def test_fail_fast_raises_in_consumer():
    def boom(x):
        if x == 5:
            raise RuntimeError("boom")
        return x

    p = build(range(100), lambda b: b.pipe(boom, on_error="fail", name="boom"))
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            list(p)
    assert ei.value.stage == "boom"
    assert isinstance(ei.value.__cause__, RuntimeError)


def test_timeout_is_a_skippable_failure():
    def hang(x):
        if x == 2:
            time.sleep(1.0)
        return x

    p = build(range(5), lambda b: b.pipe(hang, timeout=0.1, name="hang"))
    with p.auto_stop():
        out = list(p)
    assert out == [0, 1, 3, 4]
    assert {s.name: s for s in p.stats()}["hang"].num_failed == 1


def test_source_exception_fails_pipeline():
    def src():
        yield 1
        raise OSError("source died")

    p = build(src(), lambda b: b.pipe(lambda x: x))
    with p.auto_stop():
        with pytest.raises(OSError):
            _ = list(p)


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------
def test_stop_is_idempotent_and_joins_threads():
    p = build(iter(int, 1), lambda b: b.pipe(lambda x: x))  # infinite source
    p.start()
    time.sleep(0.05)
    p.stop()
    p.stop()
    assert not p._thread.is_alive()


def test_auto_stop_cleans_up_on_consumer_exception():
    p = build(iter(int, 1), lambda b: b.pipe(lambda x: x))
    with pytest.raises(KeyboardInterrupt):
        with p.auto_stop():
            next(iter(p))
            raise KeyboardInterrupt
    assert not p._thread.is_alive()


def test_get_item_timeout():
    def hang(x):
        time.sleep(10)
        return x

    p = build(range(3), lambda b: b.pipe(hang))
    with p.auto_stop():
        with pytest.raises(FuturesTimeoutError):
            p.get_item(timeout=0.1)


def test_iterating_twice_resumes_where_left_off():
    p = build(range(10), lambda b: b.pipe(lambda x: x))
    with p.auto_stop():
        it = iter(p)
        first_three = [next(it) for _ in range(3)]
        rest = list(p)
    assert first_three == [0, 1, 2]
    assert rest == list(range(3, 10))


# ---------------------------------------------------------------------------
# visibility (paper §5.4)
# ---------------------------------------------------------------------------
def test_stats_identify_bottleneck_stage():
    def fast(x):
        return x

    def slow(x):
        time.sleep(0.01)
        return x

    p = build(
        range(40),
        lambda b: b.pipe(fast, name="fast"),
        lambda b: b.pipe(slow, name="slow"),
    )
    with p.auto_stop():
        list(p)
    stats = {s.name: s for s in p.stats()}
    # the fast stage is backpressured by the slow one
    assert stats["fast"].put_wait > stats["slow"].put_wait
    assert stats["slow"].avg_task_time > stats["fast"].avg_task_time
    # dashboard renders
    assert "slow" in p.format_stats()


def test_queue_depths_exposed():
    p = build(range(5), lambda b: b.pipe(lambda x: x))
    with p.auto_stop():
        list(p)
        depths = p.queue_depths()
    assert all(isinstance(v, tuple) for v in depths.values())


# ---------------------------------------------------------------------------
# builder validation
# ---------------------------------------------------------------------------
def test_builder_rejects_bad_usage():
    with pytest.raises(ValueError):
        PipelineBuilder().pipe(lambda x: x)
    with pytest.raises(TypeError):
        PipelineBuilder().add_source(42)
    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).pipe(lambda x: x, concurrency=0)
    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).pipe(lambda x: x, output_order="zigzag")
    with pytest.raises(ValueError):
        PipelineBuilder().add_source([1]).build()  # no processing stage


def test_ordered_pipe_bounds_results_ahead_of_stalled_emitter():
    """Backpressure invariant: with the emitter stalled (nobody drains the
    sink), completed results parked ahead of it must stay bounded by the
    stage's concurrency (+ the handful of already-emitted items sitting in
    the output/sink queues) — never the whole source."""
    conc, queue_size, sink = 4, 1, 1
    completed = []
    lock = threading.Lock()

    def work(x):
        with lock:
            completed.append(x)
        return x

    p = build(
        range(10_000),
        lambda b: b.pipe(work, concurrency=conc, queue_size=queue_size),
        sink=sink,
    )
    p.start()
    time.sleep(0.4)
    try:
        # parked-in-task_q (<= conc) + emitter-held (1) + out_q + sink
        bound = conc + 1 + queue_size + sink
        assert len(completed) <= bound, f"unbounded run-ahead: {len(completed)}"
        assert completed, "pipeline made no progress at all"
    finally:
        p.stop()


def test_ordered_pipe_fail_fast_with_full_task_queue_no_deadlock():
    """A failing item under OnError.FAIL must tear the stage down even when
    the reader is parked on a full task_q (stalled consumer)."""

    def boom(x):
        if x == 3:
            raise RuntimeError("boom")
        time.sleep(0.01)  # keep the task_q populated behind the failure
        return x

    p = build(
        range(10_000),
        lambda b: b.pipe(boom, concurrency=2, on_error="fail", queue_size=1, name="boom"),
        sink=1,
    )
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            while True:  # bounded waits: a deadlock fails the test, not CI
                p.get_item(timeout=10)
    assert ei.value.stage == "boom"


def test_fail_fast_completion_order_infinite_source():
    """Regression (Python 3.10 TaskGroup backport): a child failure must
    interrupt a stage body that is still awaiting input — with an infinite
    source the error would otherwise never surface and teardown would hang."""

    import itertools

    def boom(x):
        if x == 5:
            raise RuntimeError("boom")
        return x

    p = build(
        itertools.count(),
        lambda b: b.pipe(
            boom, concurrency=2, output_order="completion", on_error="fail", name="boom"
        ),
    )
    with p.auto_stop():
        with pytest.raises(PipelineFailure) as ei:
            while True:
                p.get_item(timeout=15)
    assert ei.value.stage == "boom"


def test_process_pool_stage():
    """§5.8: GIL-holding stages can run in a process pool."""
    from concurrent.futures import ProcessPoolExecutor

    with ProcessPoolExecutor(max_workers=2) as ex:
        p = build(range(20), lambda b: b.pipe(_square, concurrency=2, executor=ex))
        with p.auto_stop():
            out = list(p)
    assert out == [x * x for x in range(20)]


def _square(x):  # module-level: must be picklable for the process pool
    return x * x

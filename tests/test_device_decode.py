"""The hot path to the device: chunked sink drain + on-chip fused decode.

Covers the consumer/device boundary end to end, CPU-only (no hypothesis,
no TPU — the fused kernel runs in interpret mode so ``use_pallas="auto"``
stays safe on CPU CI):

* ``Pipeline.get_items`` chunk semantics + the mixed ``get_item`` /
  ``get_items`` timeout-polling regression (lossless, EOF exactly once)
* ``to_uint8_wire`` edge cases (uint8 passthrough, loud out-of-range
  floats, 1-LSB dequant round trip)
* fused ``dequant_normalize_augment`` parity against the ref composition
  across dtypes, odd spatial shapes, and interpret mode
* ``DeviceTransfer.transfer_many`` + ``DeviceDecode`` dispatch, and the
  new counters surfacing through stats → format_stats → /metrics
"""

import time
from concurrent.futures import TimeoutError as FuturesTimeout

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="device-decode path needs jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import HealthMonitor, PipelineBuilder  # noqa: E402
from repro.core.metrics import stage_metrics_lines  # noqa: E402
from repro.core.stats import format_stats  # noqa: E402
from repro.data.transfer import DeviceDecode, DeviceTransfer, to_uint8_wire  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

MEAN = (0.485, 0.456, 0.406)
STD = (0.229, 0.224, 0.225)


def build(src, *stages, sink=3, threads=4):
    b = PipelineBuilder().add_source(src)
    for st in stages:
        st(b)
    return b.add_sink(buffer_size=sink).build(num_threads=threads)


# ---------------------------------------------------------------------------
# chunked sink drain: Pipeline.get_items
# ---------------------------------------------------------------------------
def test_get_items_drains_in_order_and_counts_chunks():
    p = build(range(23), lambda b: b.pipe(lambda x: x * 2, name="work"))
    got = []
    with p.auto_stop():
        p.start()
        while True:
            try:
                chunk = p.get_items(4)
            except StopIteration:
                break
            assert 1 <= len(chunk) <= 4
            got.extend(chunk)
        stats = p.stats()
    assert got == [x * 2 for x in range(23)]
    # the drain counter rides the terminal stage's row
    assert stats[-1].sink_drained_chunks > 0


def test_get_items_rejects_bad_n():
    p = build(range(3), lambda b: b.pipe(lambda x: x, name="work"))
    with p.auto_stop():
        p.start()
        with pytest.raises(ValueError):
            p.get_items(0)
        assert p.get_items(100) == [0, 1, 2] or True  # partial chunk is fine


def test_get_items_after_eof_raises_stopiteration_again():
    p = build(range(2), lambda b: b.pipe(lambda x: x, name="work"))
    with p.auto_stop():
        p.start()
        got = []
        while len(got) < 2:  # partial chunks are legal: latency over batching
            got.extend(p.get_items(8))
        assert got == [0, 1]
        for _ in range(3):  # EOF is sticky, never hangs, never re-yields
            with pytest.raises(StopIteration):
                p.get_items(8)
            with pytest.raises(StopIteration):
                p.get_item()


def test_mixed_get_item_get_items_timeout_polling_is_lossless():
    """The regression the shared stash exists for: a polling consumer that
    alternates get_item and get_items with timeouts shorter than the
    inter-item latency must see every item exactly once, in order, and
    exactly one EOF — a timed-out call's getter is resumed by the NEXT
    call of either flavor, and excess drained items wait in the stash."""

    def slow(x):
        time.sleep(0.05)
        return x

    p = build(range(16), lambda b: b.pipe(slow, name="work", concurrency=1), sink=2)
    got = []
    eofs = 0
    use_many = False
    with p.auto_stop():
        p.start()
        while eofs == 0:
            try:
                if use_many:
                    got.extend(p.get_items(3, timeout=0.01))
                else:
                    got.append(p.get_item(timeout=0.01))
            except FuturesTimeout:
                pass
            except StopIteration:
                eofs += 1
            use_many = not use_many
        # the stream is exhausted: both flavors keep raising StopIteration
        with pytest.raises(StopIteration):
            p.get_item(timeout=0.01)
    assert got == list(range(16))


def test_guard_chunked_drains_everything_once():
    def slow(x):
        time.sleep(0.03)
        return x

    p = build(range(12), lambda b: b.pipe(slow, name="work", concurrency=1), sink=2)
    mon = HealthMonitor(p, degraded_after_s=5.0, stalled_after_s=10.0)
    with p.auto_stop():
        got = list(mon.guard(tick=0.01, chunk=4))
    assert got == list(range(12))


# ---------------------------------------------------------------------------
# uint8 wire contract
# ---------------------------------------------------------------------------
def test_uint8_wire_uint8_passes_through_without_copy():
    a = np.arange(2 * 4 * 4 * 3, dtype=np.uint8).reshape(2, 4, 4, 3)
    assert to_uint8_wire(a) is a  # same object: zero copies on the slab path


def test_uint8_wire_rejects_out_of_range_floats():
    bad = np.full((1, 4, 4, 3), 17.0, np.float32)  # raw pixels, not [0,1]
    with pytest.raises(ValueError, match="uint8_wire"):
        to_uint8_wire(bad)
    with pytest.raises(ValueError, match="uint8_wire"):
        to_uint8_wire(np.full((4, 4, 3), -0.5, np.float64))


def test_uint8_wire_non_image_payloads_pass_through():
    labels = np.arange(8, dtype=np.int64)
    assert to_uint8_wire(labels) is labels
    scalars = np.float32(0.5)  # 0-d: not image-shaped
    assert to_uint8_wire(scalars) is scalars


def test_uint8_wire_dequant_round_trip_within_one_lsb():
    rng = np.random.default_rng(0)
    x = rng.random((3, 9, 7, 3), np.float32)  # [0, 1)
    wire = to_uint8_wire(x)
    assert wire.dtype == np.uint8
    back = wire.astype(np.float32) / 255.0  # the on-chip dequant
    assert np.max(np.abs(back - x)) <= 1.0 / 255.0  # 1 LSB of the wire


def test_uint8_wire_tolerates_epsilon_ringing():
    x = np.clip(np.random.default_rng(1).random((4, 4, 3), np.float32), 0, 1)
    x[0, 0, 0] = 1.0 + 5e-4  # resize/antialias overshoot stays legal
    assert to_uint8_wire(x).dtype == np.uint8


# ---------------------------------------------------------------------------
# fused kernel parity: pallas (interpret) vs the ref composition
# ---------------------------------------------------------------------------
def _sample(dtype, shape, seed=0):
    rng = np.random.default_rng(seed)
    if dtype == np.uint8:
        return rng.integers(0, 256, shape, dtype=np.uint8)
    return rng.random(shape, np.float32)  # [0, 1) float wire


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
@pytest.mark.parametrize(
    "shape,out_hw",
    [
        ((2, 13, 17, 3), (9, 11)),  # odd sizes, odd crop window
        ((3, 8, 8, 3), None),  # full frame, no crop
        ((1, 5, 5, 1), (5, 3)),  # single sample, single channel, width-only crop
    ],
)
def test_fused_kernel_matches_ref(dtype, shape, out_hw):
    n, h, w, c = shape
    x = _sample(dtype, shape)
    mean = jnp.asarray(MEAN[:c], jnp.float32)
    std = jnp.asarray(STD[:c], jnp.float32)
    rng = np.random.default_rng(7)
    flip = rng.integers(0, 2, n, dtype=np.int32)
    oh, ow = out_hw if out_hw is not None else (h, w)
    crop = np.stack(
        [rng.integers(0, h - oh + 1, n), rng.integers(0, w - ow + 1, n)], axis=1
    ).astype(np.int32)
    fused = ops.dequant_normalize_augment(
        x, mean, std, flip, crop, out_hw=out_hw, use_pallas="interpret"
    )
    oracle = ref.dequant_normalize_augment_ref(
        jnp.asarray(x), mean, std, flip=flip, crop=crop, out_hw=out_hw
    )
    assert fused.shape == (n, c, oh, ow)
    assert fused.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(fused, np.float32), np.asarray(oracle, np.float32), atol=0.0
    )


def test_fused_kernel_degenerates_to_plain_dequant_normalize():
    """No flip, no crop → the fused kernel IS dequant_normalize (NCHW)."""
    x = _sample(np.uint8, (2, 6, 10, 3))
    mean = jnp.asarray(MEAN, jnp.float32)
    std = jnp.asarray(STD, jnp.float32)
    fused = ops.dequant_normalize_augment(x, mean, std, use_pallas="interpret")
    plain = ref.dequant_normalize_ref(jnp.asarray(x), mean, std)
    np.testing.assert_allclose(
        np.asarray(fused, np.float32), np.asarray(plain, np.float32), atol=0.0
    )


def test_fused_kernel_auto_is_safe_on_cpu():
    """use_pallas="auto" must dispatch the ref path off-TPU — the config
    DeviceDecode ships by default cannot crash a CPU run."""
    x = _sample(np.uint8, (1, 4, 4, 3))
    out = ops.dequant_normalize_augment(
        x, jnp.asarray(MEAN, jnp.float32), jnp.asarray(STD, jnp.float32)
    )
    assert out.shape == (1, 3, 4, 4)


def test_fused_kernel_clamps_crop_offsets_like_dynamic_slice():
    x = _sample(np.uint8, (2, 8, 8, 3))
    mean = jnp.asarray(MEAN, jnp.float32)
    std = jnp.asarray(STD, jnp.float32)
    wild = np.array([[100, 100], [-5, -5]], np.int32)  # way out of bounds
    safe = np.array([[4, 4], [0, 0]], np.int32)  # what clamping yields
    a = ops.dequant_normalize_augment(
        x, mean, std, None, wild, out_hw=(4, 4), use_pallas="interpret"
    )
    b = ops.dequant_normalize_augment(
        x, mean, std, None, safe, out_hw=(4, 4), use_pallas="interpret"
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_kernel_rejects_oversized_window():
    x = _sample(np.uint8, (1, 4, 4, 3))
    with pytest.raises(ValueError, match="out_hw"):
        ops.dequant_normalize_augment(
            x, jnp.asarray(MEAN, jnp.float32), jnp.asarray(STD, jnp.float32),
            out_hw=(8, 8), use_pallas="interpret",
        )


# ---------------------------------------------------------------------------
# DeviceTransfer: chunked dispatch + on-chip decode
# ---------------------------------------------------------------------------
def _batches(k, n=2, hw=(6, 6), seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"images": rng.integers(0, 256, (n, *hw, 3), dtype=np.uint8)}
        for _ in range(k)
    ]


def test_transfer_many_dispatches_in_order():
    tr = DeviceTransfer(uint8_wire=True)
    batches = _batches(3)
    out = tr.transfer_many(list(batches))
    assert len(out) == 3
    assert tr.num_batches == 3
    for o, b in zip(out, batches):
        np.testing.assert_array_equal(np.asarray(o["images"]), b["images"])


def test_transfer_device_decode_matches_ref_and_counts():
    dd = DeviceDecode(mean=MEAN, std=STD, use_pallas=False)
    tr = DeviceTransfer(uint8_wire=True, device_decode=dd)
    batches = _batches(2, seed=3)
    out = tr.transfer_many(list(batches))
    for o, b in zip(out, batches):
        got = np.asarray(o["images"], np.float32)
        want = np.asarray(
            ref.dequant_normalize_ref(
                jnp.asarray(b["images"]),
                jnp.asarray(MEAN, jnp.float32),
                jnp.asarray(STD, jnp.float32),
            ),
            np.float32,
        )
        assert o["images"].dtype == jnp.bfloat16
        np.testing.assert_allclose(got, want, atol=0.0)
    probe = tr.stats()
    assert probe["device_decode_batches"] == 2
    assert probe["device_decode_ms"] > 0.0


def test_transfer_device_decode_augment_is_deterministic_per_seed():
    def run(seed):
        dd = DeviceDecode(
            mean=MEAN, std=STD, out_hw=(4, 4), flip=True, crop=True,
            seed=seed, use_pallas=False,
        )
        tr = DeviceTransfer(uint8_wire=True, device_decode=dd)
        return np.asarray(
            tr(_batches(1, hw=(6, 6), seed=9)[0])["images"], np.float32
        )

    a, b = run(42), run(42)
    np.testing.assert_array_equal(a, b)  # same seed → same augment draws
    assert a.shape == (2, 3, 4, 4)
    assert not np.array_equal(run(42), run(43))  # draws actually vary


def test_transfer_decode_skips_batches_without_the_field():
    dd = DeviceDecode(mean=MEAN, std=STD, use_pallas=False)
    tr = DeviceTransfer(device_decode=dd)
    out = tr({"tokens": np.arange(8, dtype=np.int32)})
    assert np.asarray(out["tokens"]).dtype == np.int32
    assert tr.stats()["device_decode_batches"] == 0


def test_hold_window_grows_with_dispatch_chunk():
    base = DeviceTransfer(consumer_window=3)
    chunked = DeviceTransfer(consumer_window=3, dispatch_chunk=4)
    assert base.hold_slabs == 5  # classic consumer_window + 2
    assert chunked.hold_slabs == 8  # + (dispatch_chunk - 1)


# ---------------------------------------------------------------------------
# counters surface: stats row → format_stats → /metrics
# ---------------------------------------------------------------------------
def test_decode_and_drain_counters_reach_dashboards():
    dd = DeviceDecode(mean=MEAN, std=STD, use_pallas=False)
    transfer = DeviceTransfer(uint8_wire=True, device_decode=dd)
    src = _batches(6, seed=1)
    p = (
        PipelineBuilder()
        .add_source(iter(src), name="batches")
        .pipe(transfer.transfer_many, concurrency=1, name="transfer",
              chunk=2, vectorized=True, cache=transfer)
        .add_sink(buffer_size=2)
        .build(num_threads=2)
    )
    with p.auto_stop():
        p.start()
        drained = []
        while True:
            try:
                drained.extend(p.get_items(3))
            except StopIteration:
                break
        snaps = p.stats()
    assert len(drained) == 6
    row = next(s for s in snaps if s.name == "transfer")
    assert row.device_decode_batches == 6
    assert row.device_decode_ms > 0.0
    assert snaps[-1].sink_drained_chunks > 0
    text = format_stats(snaps)
    assert "device-decode" in text
    assert "drained_chunks" in text
    lines = "\n".join(stage_metrics_lines(snaps))
    assert "repro_device_decode_batches_total" in lines
    assert "repro_sink_drained_chunks_total" in lines


# ---------------------------------------------------------------------------
# loader end to end: wire bytes in, normalized NCHW device batches out
# ---------------------------------------------------------------------------
def test_image_loader_device_decode_end_to_end(tmp_path):
    from repro.data import SyntheticImageDataset, build_image_loader

    hw, batch = (16, 16), 4
    ds = SyntheticImageDataset.materialize(tmp_path, 32, hw=hw, seed=11)
    dd = DeviceDecode(mean=MEAN, std=STD, use_pallas=False)
    pipe = build_image_loader(
        ds, batch_size=batch, hw=hw, epochs=1, sink_buffer=2,
        device_decode=dd, transfer_chunk=2,
    )
    got = []
    with pipe.auto_stop():
        pipe.start()
        while True:
            try:
                got.extend(pipe.get_items(2))
            except StopIteration:
                break
        snaps = pipe.stats()
    assert len(got) == 32 // batch
    for b in got:
        assert b["images"].shape == (batch, 3, *hw)  # NCHW, decoded on-chip
        assert b["images"].dtype == jnp.bfloat16
    row = next(s for s in snaps if s.name == "transfer")
    assert row.device_decode_batches == len(got)
    assert snaps[-1].sink_drained_chunks > 0

"""Zero-copy batch assembly: slab arena vs list-collate, end to end.

Measures, on the synthetic image workload (in-memory encoded samples so
batch *assembly*, not disk, is the variable), for each assembly path:

- items/sec through the full pipeline (read → decode → batch → transfer);
- slab-sized allocations per batch in steady state, counted by probing
  ``np.empty`` (the arena path must show **0** after warmup — batches are
  recycled ring buffers, list-collate allocates a fresh slab every batch);
- transient allocation churn per batch via ``tracemalloc``'s peak;
- peak RSS (``ResourceSampler``).

Results are persisted to ``BENCH_zero_copy.json`` at the repo root so the
acceptance gate (≥1.2× items/sec, 0 slab allocations after warmup) can be
checked offline.
"""

from __future__ import annotations

import contextlib
import json
import pathlib
import tempfile
import threading
import time
import tracemalloc

import numpy as np

from repro.core import ResourceSampler
from repro.data import SyntheticImageDataset, build_image_loader

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_zero_copy.json"

HW = (384, 384)  # stored == delivered: decode writes straight into the slot
BATCH = 16
N_ITEMS = 48
WARMUP_BATCHES = 4
TRIALS = 2  # interleaved A/B trials; best-of per path tolerates box noise
SLAB_BYTES = BATCH * HW[0] * HW[1] * 3  # uint8


class _CachedBytes:
    """Dataset facade serving encoded samples from RAM (hot page cache)."""

    def __init__(self, ds):
        self._blobs = [ds.read_bytes(i) for i in range(len(ds))]

    def __len__(self) -> int:
        return len(self._blobs)

    def read_bytes(self, i: int) -> bytes:
        return self._blobs[i]


@contextlib.contextmanager
def _count_slab_allocs(min_bytes: int):
    """Count ``np.empty`` calls allocating at least ``min_bytes`` (the
    collate slab); decode workers allocate from pool threads, so guard the
    counter with a lock."""
    counts = {"n": 0}
    lock = threading.Lock()
    orig = np.empty

    def probed(shape, dtype=float, *a, **kw):
        out = orig(shape, dtype, *a, **kw)
        if out.nbytes >= min_bytes:
            with lock:
                counts["n"] += 1
        return out

    np.empty = probed
    try:
        yield counts
    finally:
        np.empty = orig


def _run_path(ds, *, zero_copy: bool, measure_batches: int) -> dict:
    # Bound the stream so it reaches EOF and drains fully INSIDE the
    # auto_stop block: tearing the pipeline down while decode workers are
    # mid-flight, with tracemalloc live and multi-MB host buffers aliased
    # by device arrays churning, intermittently corrupts the heap on this
    # jaxlib/CPython combination.  A drained pipeline sidesteps the window.
    total_batches = WARMUP_BATCHES + measure_batches + 2
    batches_per_epoch = max(1, N_ITEMS // BATCH)
    epochs = -(-total_batches // batches_per_epoch)
    p = build_image_loader(
        ds,
        batch_size=BATCH,
        hw=HW,
        read_concurrency=4,
        decode_concurrency=6,
        num_threads=10,
        epochs=epochs,
        zero_copy=zero_copy,  # ring auto-sized from the consumer window
    )
    with ResourceSampler(interval=0.05) as rs, p.auto_stop():
        it = iter(p)
        for _ in range(WARMUP_BATCHES):
            next(it)
        tracemalloc.start()
        base, _ = tracemalloc.get_traced_memory()
        tracemalloc.reset_peak()
        with _count_slab_allocs(SLAB_BYTES // 2) as slabs:
            t0 = time.monotonic()
            for _ in range(measure_batches):
                next(it)
            dt = time.monotonic() - t0
        _, peak = tracemalloc.get_traced_memory()
        for _ in it:  # drain to EOF: quiesce every worker before teardown
            pass
    tracemalloc.stop()
    items = measure_batches * BATCH
    return {
        "zero_copy": zero_copy,
        "items_per_sec": items / dt,
        "batches_measured": measure_batches,
        "slab_allocs_per_batch": slabs["n"] / measure_batches,
        "traced_churn_mb_per_batch": max(0, peak - base) / 2**20 / measure_batches,
        "peak_rss_mb": rs.summary()["peak_rss_mb"],
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    measure = 3 if smoke else 24
    trials = 1 if smoke else TRIALS
    with tempfile.TemporaryDirectory() as d:
        ds = _CachedBytes(SyntheticImageDataset.materialize(d, N_ITEMS, hw=HW, seed=0))
        # Interleave the two paths so machine-load drift hits both equally;
        # keep each path's best trial (throughput noise is one-sided: a
        # loaded box only ever makes you slower).
        runs: dict[bool, list[dict]] = {False: [], True: []}
        for _ in range(trials):
            for zc in (False, True):
                runs[zc].append(_run_path(ds, zero_copy=zc, measure_batches=measure))
    listc = max(runs[False], key=lambda r: r["items_per_sec"])
    arena = max(runs[True], key=lambda r: r["items_per_sec"])

    speedup = arena["items_per_sec"] / max(listc["items_per_sec"], 1e-9)
    result = {
        "workload": {
            "hw": HW,
            "batch_size": BATCH,
            "measure_batches": measure,
            "trials": trials,
            "slab_bytes": SLAB_BYTES,
        },
        "list_collate": listc,
        "arena": arena,
        "all_trials_items_per_sec": {
            "list_collate": [r["items_per_sec"] for r in runs[False]],
            "arena": [r["items_per_sec"] for r in runs[True]],
        },
        "speedup": speedup,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for tag, r in (("list_collate", listc), ("arena", arena)):
        rows.append(
            (
                f"zero_copy_{tag}",
                1e6 / max(r["items_per_sec"], 1e-9),
                f"{r['items_per_sec']:.0f}items/s_"
                f"{r['slab_allocs_per_batch']:.2f}slab_allocs/batch_"
                f"{r['traced_churn_mb_per_batch']:.1f}MB_churn/batch",
            )
        )
    rows.append(("zero_copy_speedup", 0.0, f"x{speedup:.2f}_arena_vs_list_collate"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

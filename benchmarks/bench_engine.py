"""Engine overhead: per-item vs chunked vs fused stage execution.

PRs 1-4 made the storage path fast enough that the engine's own per-item
event-loop cost (queue hops, ``ensure_future``, semaphore, executor
dispatch — ~4-5 loop round trips per stage per item) became the ceiling.
This bench isolates that overhead and measures what chunking + fusion buy:

- ``engine_per_item``: a two-passthrough-stage pipeline on the classic
  per-item path — every item pays the full loop toll twice;
- ``engine_chunked``: the same pipeline with ``chunk=CHUNK`` — one
  executor dispatch per chunk per stage;
- ``engine_fused``: chunked AND ``fuse("s1", "s2")`` — the two stages
  collapse into one worker call per chunk, removing a queue + task layer.

All three paths are checked to produce IDENTICAL outputs (same items, same
order) on a common prefix of the stream.  The pipelines aggregate before
the sink (as every real loader does) so the consumer-side hop is amortized
equally and the engine, not ``get_item``, is what's measured.

Shard rows: re-runs the ``bench_shards.py`` local-mmap read workload *on
the chunked loader path* — indices → chunked vectorized
``read_bytes_many`` stage (one index→shard ``searchsorted`` per chunk
instead of per sample) — with ``ShardDataset(verify_crc="eager")``:
integrity checking coalesces into one whole-payload pass per shard at
open (the satellite's cache-install coalescing, applied at mmap-open for
local shards), so the measured steady-state epoch pays zero per-read crc
while corrupt samples still raise per sample.  The one-time verify cost
is reported separately (``verify_ms``) and amortizes across epochs;
``epoch_with_verify`` folds it back in for the pessimistic single-epoch
view.

Results persist to ``BENCH_engine.json``; gates recorded there:
``chunked_speedup >= 2`` (chunked+fused pipeline at least 2x the per-item
path, identical outputs) and ``shard_mmap_ratio >= 1.5`` (chunked-loader
mmap row at least 1.5x the PR-4 ``BENCH_shards.json`` ``shard_mmap``
value).  ``python -m benchmarks.bench_engine --gate`` re-checks the
chunked gate at smoke size and exits nonzero on regression (CI wires this
in).
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

import numpy as np

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = _ROOT / "BENCH_engine.json"
SHARDS_PATH = _ROOT / "BENCH_shards.json"

CHUNK = 64
CONCURRENCY = 4
AGG = 256  # sink batching: amortizes the consumer hop identically per path
GATE_CHUNKED_SPEEDUP = 2.0
GATE_SHARD_MMAP_RATIO = 1.5


def _ident(x):
    return x


def _build_overhead(n: int, *, chunk: int, fuse: bool):
    from repro.core import PipelineBuilder

    b = (
        PipelineBuilder()
        .add_source(range(n))
        .pipe(_ident, concurrency=CONCURRENCY, chunk=chunk, name="s1")
        # items are ints: let the aggregate stage drain batch-wide hops
        .pipe(_ident, concurrency=CONCURRENCY, chunk=chunk, name="s2", queue_size=AGG)
    )
    if fuse:
        b.fuse("s1", "s2")
    return (
        b.aggregate(AGG, name="agg")
        .add_sink(buffer_size=8)
        .build(num_threads=CONCURRENCY + 2)
    )


def _measure_overhead(n: int, *, chunk: int, fuse: bool) -> dict:
    p = _build_overhead(n, chunk=chunk, fuse=fuse)
    t0 = time.monotonic()
    with p.auto_stop():
        out = [x for batch in p for x in batch]
    dt = time.monotonic() - t0
    assert out == list(range(n)), "engine path changed the stream"
    return {"items_per_sec": n / dt, "items": n, "chunk": chunk, "fused": fuse}


SHARD_CHUNK = 512
SHARD_AGG = 512
SHARD_CONCURRENCY = 2
SHARD_TRIALS = 3  # best-of: n is small relative to pipeline startup


def _measure_shard_reads(shards_dir: pathlib.Path, *, smoke: bool) -> dict:
    """The bench_shards ``shard_mmap`` workload (shuffled full-epoch reads)
    driven through a chunked read pipeline instead of a bare Python loop,
    over an eager-verified dataset (coalesced whole-payload crc at open;
    the steady-state epoch pays no per-read crc)."""
    from repro.core import PipelineBuilder
    from repro.data import ShardDataset

    ds = ShardDataset(shards_dir, verify_crc="eager")
    n = len(ds)
    order = np.random.default_rng(0).permutation(n).tolist()

    # open + verify every shard once (the coalesced pass), timed separately:
    # it is a one-time cost amortized over every later epoch
    t0 = time.monotonic()
    for s in range(ds.num_shards):
        ds._reader(s)
    verify_s = time.monotonic() - t0

    def read_many(idxs: list[int]) -> list[int]:
        return [v.nbytes for v in ds.read_bytes_many(idxs)]

    best_dt = float("inf")
    for _ in range(1 if smoke else SHARD_TRIALS):
        p = (
            PipelineBuilder()
            .add_source(order, name="sampler")
            .pipe(read_many, concurrency=SHARD_CONCURRENCY, chunk=SHARD_CHUNK,
                  name="read", vectorized=True, queue_size=SHARD_AGG)
            .aggregate(SHARD_AGG, name="agg")
            .add_sink(buffer_size=8)
            .build(num_threads=SHARD_CONCURRENCY + 2)
        )
        t0 = time.monotonic()
        with p.auto_stop():
            n_bytes = sum(ln for batch in p for ln in batch)
        best_dt = min(best_dt, time.monotonic() - t0)
    ds.close()
    return {
        "items_per_sec": n / best_dt,
        "mb_per_sec": n_bytes / best_dt / 2**20,
        "items": n,
        "chunk": SHARD_CHUNK,
        "verify_ms": verify_s * 1e3,
        "epoch_with_verify_items_per_sec": n / (best_dt + verify_s),
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    n_slow = 2_000 if smoke else 20_000  # per-item path: every item ~1 loop toll
    n_fast = 20_000 if smoke else 200_000

    per_item = _measure_overhead(n_slow, chunk=1, fuse=False)
    chunked = _measure_overhead(n_fast, chunk=CHUNK, fuse=False)
    fused = _measure_overhead(n_fast, chunk=CHUNK, fuse=True)

    from repro.data import SyntheticImageDataset, pack

    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        n_items = 512 if smoke else 2048
        files_ds = SyntheticImageDataset.materialize(d / "files", n_items, hw=(64, 64), seed=0)
        pack(files_ds, d / "shards", samples_per_shard=64 if smoke else 256)
        shard_chunked = _measure_shard_reads(d / "shards", smoke=smoke)

    chunked_speedup = chunked["items_per_sec"] / max(per_item["items_per_sec"], 1e-9)
    fused_speedup = fused["items_per_sec"] / max(per_item["items_per_sec"], 1e-9)
    pr4_mmap = None
    if SHARDS_PATH.is_file():
        pr4_mmap = json.loads(SHARDS_PATH.read_text())["shard_mmap"]["items_per_sec"]
    shard_ratio = (
        shard_chunked["items_per_sec"] / pr4_mmap if pr4_mmap else None
    )

    result = {
        "workload": {
            "n_per_item": n_slow,
            "n_chunked": n_fast,
            "chunk": CHUNK,
            "concurrency": CONCURRENCY,
            "agg": AGG,
        },
        "per_item": per_item,
        "chunked": chunked,
        "fused": fused,
        "chunked_speedup": chunked_speedup,
        "fused_speedup": fused_speedup,
        "gate_chunked_speedup": GATE_CHUNKED_SPEEDUP,
        "shard_mmap_chunked": shard_chunked,
        "shard_mmap_pr4_items_per_sec": pr4_mmap,
        "shard_mmap_ratio": shard_ratio,
        "gate_shard_mmap_ratio": GATE_SHARD_MMAP_RATIO,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for tag, r in (("per_item", per_item), ("chunked", chunked), ("fused", fused)):
        rows.append(
            (
                f"engine_{tag}",
                1e6 / max(r["items_per_sec"], 1e-9),
                f"{r['items_per_sec']:.0f}items/s_chunk{r['chunk']}",
            )
        )
    rows.append(
        ("engine_chunked_speedup", 0.0, f"x{chunked_speedup:.2f}_chunked_vs_per_item")
    )
    rows.append(("engine_fused_speedup", 0.0, f"x{fused_speedup:.2f}_fused_vs_per_item"))
    rows.append(
        (
            "engine_shard_mmap_chunked",
            1e6 / max(shard_chunked["items_per_sec"], 1e-9),
            f"{shard_chunked['items_per_sec']:.0f}items/s_"
            f"{shard_chunked['mb_per_sec']:.0f}MB/s",
        )
    )
    if shard_ratio is not None and not smoke:
        # the PR-4 baseline in BENCH_shards.json is a full-size run — only a
        # full-size row is comparable against it
        rows.append(
            (
                "engine_shard_mmap_vs_pr4",
                0.0,
                f"x{shard_ratio:.2f}_chunked_loader_vs_bare_loop"
                f"_{'OK' if shard_ratio >= GATE_SHARD_MMAP_RATIO else 'BELOW_GATE'}",
            )
        )
    return rows


def check_gate() -> int:
    """CI regression tripwire: re-measure the overhead workload at smoke
    size and fail if the chunked path dropped below the recorded gate."""
    gate = GATE_CHUNKED_SPEEDUP
    if OUT_PATH.is_file():
        gate = float(
            json.loads(OUT_PATH.read_text()).get("gate_chunked_speedup", gate)
        )
    per_item = _measure_overhead(2_000, chunk=1, fuse=False)
    fused = _measure_overhead(20_000, chunk=CHUNK, fuse=True)
    speedup = fused["items_per_sec"] / max(per_item["items_per_sec"], 1e-9)
    print(
        f"engine_chunked gate: x{speedup:.2f} (chunked+fused vs per-item), "
        f"gate x{gate:.2f}"
    )
    if speedup < gate:
        print(f"REGRESSION: chunked+fused speedup x{speedup:.2f} < gate x{gate:.2f}")
        return 1
    return 0


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

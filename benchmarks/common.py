"""Shared benchmark utilities."""

from __future__ import annotations

import time


def timed(fn, *args, **kw):
    t0 = time.monotonic()
    out = fn(*args, **kw)
    return time.monotonic() - t0, out


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def consume(iterable, n: int | None = None) -> int:
    cnt = 0
    for _ in iterable:
        cnt += 1
        if n is not None and cnt >= n:
            break
    return cnt

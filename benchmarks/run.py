"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).  The roofline
rows are derived from the dry-run artifacts under experiments/dryrun (run
``python -m repro.launch.dryrun`` first to refresh them).

``--smoke`` runs each registered bench as a ~2-second CI sanity check:
modules whose ``run`` accepts a ``smoke`` flag shrink their workload; the
rest are given a 2-second soft budget and reported as ``_SMOKE_TIMEOUT``
rows (not failures) when they exceed it.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import os
import subprocess
import sys
import traceback

SMOKE_BUDGET_S = 2.0
# Modules without a smoke flag run in a kill-at-budget subprocess; the
# budget is padded by the interpreter/jax import time the in-process path
# doesn't pay.  A killed bench can't keep running behind the harness's
# back, so later rows are never contended.
SMOKE_IMPORT_GRACE_S = 45.0

#: (label, module) registry; modules are imported lazily and individually so
#: one module's missing dependency cannot take down the whole harness.
REGISTRY = [
    ("fig1/2 GIL scaling", "bench_gil_scaling"),
    ("fig5 loader throughput", "bench_loader_throughput"),
    ("table2 first batch", "bench_first_batch"),
    ("fig6/7 resources", "bench_resources"),
    ("fig8/9 e2e inference+training + ViT hot path", "bench_e2e"),
    ("table3 GIL modes", "bench_gil_modes"),
    ("appC video/decord", "bench_video"),
    ("wire format (beyond-paper)", "bench_wire_format"),
    ("zero-copy slab arena (beyond-paper)", "bench_zero_copy"),
    ("sharded record store (beyond-paper)", "bench_shards"),
    ("engine chunked+fused (beyond-paper)", "bench_engine"),
    ("fault recovery chaos (beyond-paper)", "bench_faults"),
    ("elastic shard fleet (beyond-paper)", "bench_fleet"),
    ("flight-recorder tracing (beyond-paper)", "bench_trace"),
    ("roofline (dry-run derived)", "roofline"),
]


def _run_module(mod, mod_name: str, smoke: bool):
    """Invoke the bench honoring the smoke budget.

    Returns ``("ok", rows)`` or ``("timeout", None)``.  Smoke-aware modules
    shrink their own workload in-process; the rest run in a subprocess that
    is killed at the budget (plus import grace), so an over-budget bench
    can never keep executing alongside later ones."""
    accepts_smoke = "smoke" in inspect.signature(mod.run).parameters
    if not smoke:
        return "ok", mod.run()
    if accepts_smoke:
        return "ok", mod.run(smoke=True)
    try:
        proc = subprocess.run(
            [sys.executable, "-m", f"{__package__}.{mod_name}"],
            capture_output=True,
            text=True,
            timeout=SMOKE_BUDGET_S + SMOKE_IMPORT_GRACE_S,
            env=os.environ.copy(),
        )
    except subprocess.TimeoutExpired:
        return "timeout", None
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench subprocess exited {proc.returncode}: {proc.stderr[-400:]}"
        )
    rows = []
    for line in proc.stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3:
            try:
                rows.append((parts[0], float(parts[1]), parts[2]))
            except ValueError:
                pass  # stray print, not a CSV row
    return "ok", rows


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="~2s per bench: CI sanity check, not a measurement",
    )
    args = parser.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for label, mod_name in REGISTRY:
        tag = label.replace(" ", "_")
        try:
            mod = importlib.import_module(f".{mod_name}", package=__package__)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{tag}_IMPORT_FAILED,0,{e!r}")
            continue
        try:
            status, rows = _run_module(mod, mod_name, args.smoke)
            if status == "timeout":
                print(f"{tag}_SMOKE_TIMEOUT,0,killed_over_{SMOKE_BUDGET_S}s_budget")
                continue
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{tag}_FAILED,0,{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).  The roofline
rows are derived from the dry-run artifacts under experiments/dryrun (run
``python -m repro.launch.dryrun`` first to refresh them).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        bench_e2e,
        bench_first_batch,
        bench_gil_modes,
        bench_gil_scaling,
        bench_loader_throughput,
        bench_resources,
        bench_video,
        bench_wire_format,
        roofline,
    )

    modules = [
        ("fig1/2 GIL scaling", bench_gil_scaling),
        ("fig5 loader throughput", bench_loader_throughput),
        ("table2 first batch", bench_first_batch),
        ("fig6/7 resources", bench_resources),
        ("fig8/9 e2e inference+training", bench_e2e),
        ("table3 GIL modes", bench_gil_modes),
        ("appC video/decord", bench_video),
        ("wire format (beyond-paper)", bench_wire_format),
        ("roofline (dry-run derived)", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{label.replace(' ', '_')}_FAILED,0,{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

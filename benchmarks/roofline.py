"""Roofline analysis (assignment deliverable g) from the dry-run artifacts.

For every (arch × shape × mesh) cell:

  compute_s    = dot_flops_per_device / 197 TFLOP/s        (bf16 MXU peak)
  memory_s     = tpu_bytes_per_device / 819 GB/s            (HBM)
  collective_s = Σ_kind factor·bytes / 50 GB/s              (ICI per link)

dot_flops / bytes come from the trip-count-corrected HLO census
(launch/hlo_census.py) — ``cost_analysis()`` counts loop bodies once and is
reported only as a cross-check.  ``tpu_bytes`` is the fusion-optimistic
traffic model (dots, gathers/scatters, slices, in-place DUS, collectives);
the raw CPU-scheduled byte count is an upper bound (CPU HLO barely fuses).

Collective traffic factors per device: all-reduce 2× result (ring, 2(n-1)/n),
all-gather 1× result (result IS the moved payload), reduce-scatter 16×
result (result is the shard; group size ≈16 on the dp axis — documented
approximation), all-to-all / permute 1×.

MODEL_FLOPS uses 6·N_active·tokens (train) / 2·N_active·tokens (+ attention
context term) so the MODEL/HLO ratio exposes remat recompute, causal-waste
and kv-replication overheads.
"""

from __future__ import annotations

import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 16.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def active_params(cfg) -> float:
    """Params touched per token (MoE: top-k + shared experts only)."""
    from repro.models import Model

    total = Model(cfg).param_count()
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    n_mats = 3 if cfg.act == "swiglu" else 2
    per_expert = n_mats * cfg.d_model * m.d_expert
    n_moe_layers = sum(cfg.layer_is_moe())
    routed_total = m.n_experts * per_expert * n_moe_layers
    routed_active = m.experts_per_token * per_expert * n_moe_layers
    return float(total - routed_total + routed_active)


def model_flops_per_device(cfg, shape, n_dev: int) -> float:
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_act * tokens
    else:  # decode: one token per sequence + attention over the cache
        tokens = shape.global_batch
        flops = 2.0 * n_act * tokens
    # attention context term (score+pv): 4 · tokens · S_ctx · H · hd per attn layer
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    n_attn = sum(1 for k in cfg.block_kinds() if k in ("attn", "mla"))
    if n_attn and hd:
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        sctx = shape.seq_len
        causal = 0.5 if shape.kind != "decode" else 1.0
        mult = 3.0 if shape.kind == "train" else 1.0
        flops += mult * causal * 4.0 * tokens * sctx * cfg.num_heads * hd * n_attn
    return flops / n_dev


def analyze(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    from repro.configs import SHAPES, get_config

    out = []
    for path in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok":
            out.append(rec)
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        n_dev = rec["n_devices"]
        compute_s = rec["flops_per_device"] / PEAK_FLOPS
        memory_s = rec["tpu_bytes_per_device"] / HBM_BW
        coll = rec["collectives"]["per_kind"]
        coll_s = sum(COLL_FACTOR[k] * v["bytes"] for k, v in coll.items()) / LINK_BW
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops_per_device(cfg, shape, n_dev)
        ratio = mf / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
        bound_s = max(terms.values())
        rec.update(
            compute_s=compute_s,
            memory_s=memory_s,
            collective_s=coll_s,
            dominant=dominant,
            model_flops_per_device=mf,
            useful_flops_ratio=ratio,
            roofline_fraction=(mf / PEAK_FLOPS) / bound_s if bound_s else 0.0,
            advice=_advice(dominant, rec, ratio),
        )
        out.append(rec)
    return out


def _advice(dominant: str, rec: dict, ratio: float) -> str:
    if dominant == "compute":
        if ratio < 0.5:
            return (
                "compute-bound but <50% useful: cut remat recompute (policy), "
                "causal-block skipping (Pallas flash), or kv-replication waste"
            )
        return "compute-bound and mostly useful flops: increase arithmetic intensity won't help; this is healthy"
    if dominant == "memory":
        return "HBM-bound: fuse elementwise chains, keep params bf16, widen batch per device to amortize weight reads"
    return "collective-bound: reshard to cut all-gathers (FSDP prefetch), overlap via microbatch pipelining, or move the axis with less traffic to the slower links"


def markdown_table(recs: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline_frac | peak_GB/dev |\n|---|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in recs:
        if r.get("status") == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | skip | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['memory']['peak_bytes_est'] / 2**30:.1f} |"
        )
    return "\n".join(lines)


def run(dryrun_dir: str = "experiments/dryrun") -> list[tuple[str, float, str]]:
    recs = analyze(dryrun_dir)
    ok = [r for r in recs if r.get("status") == "ok"]
    rows = []
    for r in ok:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            (
                name,
                bound * 1e6,
                f"dom={r['dominant']};frac={r['roofline_fraction']:.2f};useful={r['useful_flops_ratio']:.2f}",
            )
        )
    pathlib.Path("experiments").mkdir(exist_ok=True)
    pathlib.Path("experiments/roofline.md").write_text(markdown_table(recs))
    return rows


if __name__ == "__main__":
    # CSV rows, not the markdown table: run.py --smoke drives this module
    # as a subprocess and parses "name,us,derived" lines — markdown output
    # would silently parse to zero rows (run() still writes the md table
    # to experiments/roofline.md).
    import sys

    for r in run(*sys.argv[1:2]):
        print(",".join(map(str, r)))

"""Paper Appendix C + Table 4: "video" (multi-frame clip) loading vs the
Decord-like eager baseline.

Clips are (T, H, W, 3) encoded arrays.  Table 4 reproduces the init-time
scaling of eager loaders with dataset size; the throughput comparison shows
the streaming pipeline matches the eager loader while staying robust to
malformed clips (the eager loader dies on the first one — asserted)."""

from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.data.baselines import DecordLikeLoader
from repro.data.codec import encode_sample
from repro.data.dataset import ArrayDataset
from repro.data.loader import build_image_loader


def _materialize_clips(root, n, t=4, hw=(64, 64), corrupt_every=0):
    import pathlib

    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(0)
    names = []
    for i in range(n):
        clip = rng.integers(0, 256, (t, *hw, 3), dtype=np.uint8)
        data = encode_sample(clip)
        if corrupt_every and i % corrupt_every == corrupt_every - 1:
            data = b"XXXX" + data[4:]
        name = f"{i:05d}.rpr"
        (root / name).write_bytes(data)
        names.append(name)
    (root / "index.txt").write_text("\n".join(names))
    return ArrayDataset(root)


def run() -> list[tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        # Table 4: eager-init scaling with dataset size
        inits = []
        for n in (16, 32, 64):
            ds = _materialize_clips(f"{d}/t4_{n}", n)
            dl = DecordLikeLoader(ds, batch_size=4, hw=(32, 32))
            inits.append(dl.init_s)
            rows.append((f"table4_decordlike_init_n{n}", dl.init_s * 1e6, f"{dl.init_s * 1e3:.1f}ms"))
        rows.append(
            ("table4_init_scaling", 0.0, f"x{inits[-1] / max(inits[0], 1e-9):.1f}_from_16_to_64")
        )

        # throughput: streaming pipeline vs eager
        ds = _materialize_clips(f"{d}/clips", 48)
        # clips are (T, H, W, 3): not image-shaped, so use the list-collate
        # fallback (the slab arena requires fixed (H, W, C) slots)
        pipe = build_image_loader(
            ds, batch_size=4, hw=(32, 32), decode_concurrency=4, zero_copy=False
        )
        with pipe.auto_stop():
            t0 = time.monotonic()
            cnt = sum(1 for _ in pipe)
            dt = time.monotonic() - t0
        rows.append((f"appC_spdl_clips", 1e6 * dt / max(cnt, 1), f"{cnt * 4 / dt:.0f}clips/s"))

        # robustness: corrupt clip kills the eager loader, not the pipeline
        ds_bad = _materialize_clips(f"{d}/bad", 24, corrupt_every=6)
        try:
            DecordLikeLoader(ds_bad, batch_size=4)
            eager = "no_error(UNEXPECTED)"
        except ValueError:
            eager = "init_raises(faithful_to_decord)"
        pipe = build_image_loader(ds_bad, batch_size=4, hw=(32, 32), zero_copy=False)
        with pipe.auto_stop():
            good = sum(1 for _ in pipe)
        rows.append(("appC_robustness", 0.0, f"eager={eager};spdl_served_{good}_batches"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Paper Fig 1/2: thread- vs process-pool scaling of media decode, and the
GIL-contention mechanism.

Three decode variants over the same encoded samples:
  - ``zstd+numpy``  : releases the GIL (SPDL-style C-extension path)
  - ``pure-python`` : holds the GIL (Pillow-like interpreter work)
  - ``simulated-io``: sleeps (network-style, always releases)

NOTE: this container has ONE CPU core, so CPU-bound *parallel speedup* is
physically capped at 1×; what the sweep still demonstrates is the paper's
Fig 2 contention effect — pure-python decode *degrades* as threads are
added (GIL churn), while GIL-releasing decode does not — and the IO-bound
stage scales with threads even on one core.
"""

from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor

import numpy as np

from repro.data.codec import decode_sample, encode_sample, py_decode, resize_nearest

N_SAMPLES = 48
HW = (128, 128)


def _samples():
    rng = np.random.default_rng(0)
    return [
        encode_sample(rng.integers(0, 256, (*HW, 3), dtype=np.uint8))
        for _ in range(N_SAMPLES)
    ]


def _decode_zstd(data: bytes) -> np.ndarray:
    return resize_nearest(decode_sample(data), (64, 64))


def _decode_py(data: bytes) -> np.ndarray:
    return resize_nearest(py_decode(data), (64, 64))


def _decode_io(data: bytes) -> np.ndarray:
    time.sleep(0.004)
    return decode_sample(data)


def _throughput(executor_cls, fn, samples, workers: int) -> float:
    with executor_cls(max_workers=workers) as ex:
        t0 = time.monotonic()
        list(ex.map(fn, samples))
        dt = time.monotonic() - t0
    return len(samples) / dt


def run() -> list[tuple[str, float, str]]:
    samples = _samples()
    rows = []
    for label, fn in [("zstd", _decode_zstd), ("pure_py", _decode_py), ("sim_io", _decode_io)]:
        base = _throughput(ThreadPoolExecutor, fn, samples, 1)
        for w in (1, 2, 4, 8):
            fps = _throughput(ThreadPoolExecutor, fn, samples, w)
            rows.append(
                (f"fig1_thread_{label}_w{w}", 1e6 / fps, f"{fps:.0f}fps;x{fps / base:.2f}_vs_w1")
            )
    # process pool for the GIL-holding variant (the paper's workaround)
    for w in (1, 2):
        fps = _throughput(ProcessPoolExecutor, _mp_decode, samples, w)
        rows.append((f"fig1_process_pure_py_w{w}", 1e6 / fps, f"{fps:.0f}fps"))
    return rows


def _mp_decode(data: bytes) -> int:  # picklable process-pool task
    return _decode_py(data).shape[0]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

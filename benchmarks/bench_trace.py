"""Flight-recorder overhead + trace-capture validation (observability PR).

Tracing must be free when off and near-free when on, or nobody ships it
enabled and the flight recorder never records the incident.  Two claims,
both measured here and gated in CI:

- ``traced_ratio >= 0.95``: the bench_engine passthrough workload (two
  chunked stages + aggregate — the engine-overhead-dominated worst case
  for tracing, since real loaders amortize spans over decode work) runs at
  >= 0.95x its untraced throughput with a live ``Tracer`` capturing every
  span.
- ``disabled_overhead_frac <= 0.01``: with no tracer installed every span
  site costs one attribute check on the ``NULL_TRACER`` singleton.  The
  check is microbenched directly and scaled by the per-item path's site
  count (6: 2 stage spans + 4 queue wait branches — the worst case; the
  chunked path amortizes its 2 checks over a whole chunk), then compared
  against the measured ``chunk=1`` per-item engine cost.

Capture validation (the acceptance criterion's round-trip check): a small
chunked shard pipeline — SimulatedLatencySource behind a prefetcher cache,
zero-copy decode, DeviceTransfer — runs under ``tracing(...)``; the
captured trace must survive a Chrome Trace JSON round-trip and contain
spans from >= 4 subsystems (stage, queue, shard, transfer).

Results persist to ``BENCH_trace.json``; ``python -m benchmarks.bench_trace
--gate`` re-checks all three at smoke size and exits nonzero on regression
(CI wires this in).
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = _ROOT / "BENCH_trace.json"

CHUNK = 64
CONCURRENCY = 4
AGG = 256
TRIALS = 5  # best-of, interleaved: thread scheduling noise swamps one run
GATE_TRACED_RATIO = 0.95
GATE_DISABLED_FRAC = 0.01
#: tracer-check sites an item crosses on the PER-ITEM engine path (2 stage
#: spans + 4 queue wait branches) — the worst case: the chunked path pays
#: its 2 checks once per chunk, not per item
CHECKS_PER_ITEM = 6
REQUIRED_CATEGORIES = {"stage", "queue", "shard", "transfer"}


def _ident(x):
    return x


def _measure(n: int, tracer, chunk: int = CHUNK) -> float:
    """items/s of the bench_engine passthrough workload, built with
    ``trace=tracer`` (None = the disabled NULL fast path)."""
    from repro.core import PipelineBuilder

    p = (
        PipelineBuilder()
        .add_source(range(n))
        .pipe(_ident, concurrency=CONCURRENCY, chunk=chunk, name="s1")
        .pipe(_ident, concurrency=CONCURRENCY, chunk=chunk, name="s2",
              queue_size=AGG)
        .aggregate(AGG, name="agg")
        .add_sink(buffer_size=8)
        .build(num_threads=CONCURRENCY + 2, trace=tracer)
    )
    t0 = time.monotonic()
    with p.auto_stop():
        out = [x for batch in p for x in batch]
    dt = time.monotonic() - t0
    assert out == list(range(n)), "traced engine path changed the stream"
    return n / dt


def _measure_ratio(n: int, trials: int) -> dict:
    """Best-of-``trials`` traced vs untraced throughput on the same
    workload, trials interleaved so machine-load drift hits both sides
    equally.  A fresh Tracer per trial so ring growth never compounds."""
    from repro.core import Tracer

    untraced, traced_best, events = 0.0, 0.0, 0
    for _ in range(trials):
        untraced = max(untraced, _measure(n, None))
        tr = Tracer()
        rate = _measure(n, tr)
        if rate > traced_best:
            traced_best, events = rate, len(tr)
    return {
        "items": n,
        "untraced_items_per_sec": untraced,
        "traced_items_per_sec": traced_best,
        "traced_ratio": traced_best / max(untraced, 1e-9),
        "traced_events": events,
    }


def _measure_disabled(n: int) -> dict:
    """Cost of the NULL fast path: one ``tracer.enabled`` attribute check
    per span site, microbenched and scaled by CHECKS_PER_ITEM against the
    measured ``chunk=1`` per-item engine cost (the path where an item
    actually crosses that many sites)."""
    from repro.core import NULL_TRACER

    per_item_rate = _measure(n, None, chunk=1)

    iters = 1_000_000

    def loop(check: bool) -> float:
        t = NULL_TRACER
        best = float("inf")
        for _ in range(3):
            t0 = time.monotonic()
            if check:
                for _ in range(iters):
                    if t.enabled:  # the per-site disabled cost
                        pass
            else:
                for _ in range(iters):
                    pass
            best = min(best, time.monotonic() - t0)
        return best

    check_ns = max(0.0, (loop(True) - loop(False)) / iters * 1e9)
    item_ns = 1e9 / max(per_item_rate, 1e-9)
    frac = CHECKS_PER_ITEM * check_ns / item_ns
    return {
        "check_ns": check_ns,
        "checks_per_item": CHECKS_PER_ITEM,
        "per_item_path_items_per_sec": per_item_rate,
        "item_ns": item_ns,
        "disabled_overhead_frac": frac,
    }


def _capture_trace(smoke: bool) -> dict:
    """Chunked shard pipeline (simulated-latency remote + prefetcher cache +
    device transfer) under ``tracing(...)``; validates the Chrome JSON
    round-trip and the >= 4-subsystem coverage."""
    from repro.core import tracing
    from repro.data import (
        CheckpointableSampler,
        LocalShardSource,
        ShardDataset,
        ShardPrefetcher,
        SimulatedLatencySource,
        SyntheticImageDataset,
        build_image_loader,
        pack,
    )

    n_items = 48 if smoke else 192
    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        files = SyntheticImageDataset.materialize(
            d / "files", n_items, hw=(64, 64), seed=0
        )
        pack(files, d / "shards", samples_per_shard=12)
        prefetcher = ShardPrefetcher(
            SimulatedLatencySource(
                LocalShardSource(d / "shards"), latency_s=0.002
            ),
            d / "cache",
            max_bytes=1 << 30,
        )
        ds = ShardDataset(d / "shards", prefetcher=prefetcher)
        with tracing() as tracer:
            pipe = build_image_loader(
                ds, batch_size=8, hw=(56, 56), chunk=8,
                sampler=CheckpointableSampler(
                    len(ds), batch_size=1, seed=0,
                    shard_sizes=ds.shard_sizes, shard_window=24,
                ),
                trace=tracer,
            )
            with pipe.auto_stop():
                n_img = sum(b["images"].shape[0] for b in pipe)
            doc = tracer.to_chrome()
        ds.close()

    # the round-trip the acceptance criterion names: what we export must
    # parse back as Chrome Trace JSON with the spans intact
    parsed = json.loads(json.dumps(doc, default=repr))
    events = parsed["traceEvents"]
    cats = {e.get("cat") for e in events if e.get("ph") != "M"} - {None}
    missing = REQUIRED_CATEGORIES - cats
    if missing:
        raise AssertionError(f"trace missing subsystem categories: {missing}")
    threads = {e["tid"] for e in events}
    return {
        "images": n_img,
        "events": len(events),
        "categories": sorted(cats),
        "threads": len(threads),
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    n = 20_000 if smoke else 200_000
    ratio = _measure_ratio(n, 1 if smoke else TRIALS)
    disabled = _measure_disabled(2_000 if smoke else 20_000)
    capture = _capture_trace(smoke)

    result = {
        "workload": {"n": n, "chunk": CHUNK, "concurrency": CONCURRENCY,
                     "agg": AGG},
        "overhead": ratio,
        "disabled": disabled,
        "capture": capture,
        "gate_traced_ratio": GATE_TRACED_RATIO,
        "gate_disabled_frac": GATE_DISABLED_FRAC,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    return [
        (
            "trace_untraced",
            1e6 / max(ratio["untraced_items_per_sec"], 1e-9),
            f"{ratio['untraced_items_per_sec']:.0f}items/s",
        ),
        (
            "trace_enabled",
            1e6 / max(ratio["traced_items_per_sec"], 1e-9),
            f"{ratio['traced_items_per_sec']:.0f}items/s_"
            f"{ratio['traced_events']}events",
        ),
        (
            "trace_enabled_ratio",
            0.0,
            f"x{ratio['traced_ratio']:.3f}_traced_vs_untraced_"
            f"{'OK' if ratio['traced_ratio'] >= GATE_TRACED_RATIO else 'BELOW_GATE'}",
        ),
        (
            "trace_disabled_check",
            disabled["check_ns"] / 1e3,
            f"{disabled['disabled_overhead_frac'] * 100:.3f}%_of_item_cost_"
            f"{'OK' if disabled['disabled_overhead_frac'] <= GATE_DISABLED_FRAC else 'ABOVE_GATE'}",
        ),
        (
            "trace_capture",
            0.0,
            f"{capture['events']}events_{len(capture['categories'])}cats_"
            f"{capture['threads']}threads",
        ),
    ]


def check_gate() -> int:
    """CI regression tripwire: smoke-size re-measure of all three claims."""
    gate_ratio, gate_frac = GATE_TRACED_RATIO, GATE_DISABLED_FRAC
    if OUT_PATH.is_file():
        rec = json.loads(OUT_PATH.read_text())
        gate_ratio = float(rec.get("gate_traced_ratio", gate_ratio))
        gate_frac = float(rec.get("gate_disabled_frac", gate_frac))

    # 100k items (~1s/run): at smoke size pipeline startup is a large,
    # noisy fraction of the measurement and the ratio bounces +-5%
    ratio = _measure_ratio(100_000, TRIALS)
    disabled = _measure_disabled(2_000)
    capture = _capture_trace(smoke=True)

    print(
        f"trace gate: traced x{ratio['traced_ratio']:.3f} (gate "
        f">={gate_ratio}), disabled "
        f"{disabled['disabled_overhead_frac'] * 100:.3f}% (gate "
        f"<={gate_frac * 100:.0f}%), capture {capture['events']} events "
        f"across {capture['categories']}"
    )
    status = 0
    if ratio["traced_ratio"] < gate_ratio:
        print(
            f"REGRESSION: traced throughput x{ratio['traced_ratio']:.3f} "
            f"< gate x{gate_ratio}"
        )
        status = 1
    if disabled["disabled_overhead_frac"] > gate_frac:
        print(
            f"REGRESSION: disabled fast path "
            f"{disabled['disabled_overhead_frac'] * 100:.3f}% > gate "
            f"{gate_frac * 100:.0f}% of per-item cost"
        )
        status = 1
    return status


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

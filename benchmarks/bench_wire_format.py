"""Beyond-paper: host→device wire-format bytes (uint8 + on-chip dequant vs
f32/bf16 on the host).

The paper minimizes host-side copies; we extend the idea across the wire:
transfer uint8 and run kernels/dequant_normalize on-chip.  This bench
measures actual bytes through the DeviceTransfer stage and the end-to-end
batch latency for each format.
"""

from __future__ import annotations

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticImageDataset
from repro.data.codec import decode_sample, resize_nearest
from repro.data.transfer import DeviceTransfer
from repro.kernels.ops import dequant_normalize

N, HW = 48, (112, 112)
MEAN = jnp.array([0.485, 0.456, 0.406], jnp.float32)
STD = jnp.array([0.229, 0.224, 0.225], jnp.float32)


def run() -> list[tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ds = SyntheticImageDataset.materialize(d, N, hw=(128, 128), seed=0)
        imgs = np.stack([resize_nearest(decode_sample(ds.read_bytes(i)), HW) for i in range(N)])

        # uint8 wire + on-chip dequant (ours)
        tr = DeviceTransfer()
        t0 = time.monotonic()
        out = tr({"images": imgs})
        x = dequant_normalize(out["images"], MEAN, STD)
        x.block_until_ready()
        dt8 = time.monotonic() - t0
        rows.append(("wire_uint8_dequant_onchip", dt8 * 1e6 / N, f"{tr.bytes_moved / 2**20:.1f}MB_moved"))

        # f32 host-side normalize (the conventional loader)
        tr32 = DeviceTransfer()
        t0 = time.monotonic()
        host = (imgs.astype(np.float32) / 255.0 - np.array([0.485, 0.456, 0.406], np.float32)) / np.array(
            [0.229, 0.224, 0.225], np.float32
        )
        out = tr32({"images": np.ascontiguousarray(host.transpose(0, 3, 1, 2))})
        out["images"].block_until_ready()
        dt32 = time.monotonic() - t0
        rows.append(("wire_f32_host_normalize", dt32 * 1e6 / N, f"{tr32.bytes_moved / 2**20:.1f}MB_moved"))

        ratio = tr32.bytes_moved / max(tr.bytes_moved, 1)
        rows.append(("wire_bytes_reduction", 0.0, f"x{ratio:.1f}_fewer_h2d_bytes_with_uint8"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Performance hillclimbing (assignment §Perf): hypothesis → change →
re-lower → measure → validate, on the three most interesting cells.

Cells (chosen from the baseline roofline table):
  A. qwen1.5-110b × train_4k × single  — worst roofline fraction among the
     large trainers; memory-dominated.
  B. jamba-1.5-large-398b × train_4k × multi — the only collective-dominated
     cell (FSDP all-gathers of 50 GB/device expert weights per microbatch).
  C. mamba2-780m × prefill_32k × single — most representative of the paper's
     technique (the loader-fed inference path; SSD kernel owns the compute).

Variants re-lower the REAL step (measured on the compiled artifact); the
``*_kernel_adj`` variants additionally swap the measured jnp-fallback
attention/SSD HBM traffic for the Pallas kernels' analytic traffic (the
kernels are validated in interpret mode; on TPU they replace the fallback
via kernels/ops.py, so this is the deploy configuration, not a hypothesis).

Run: PYTHONPATH=src:. python -m benchmarks.hillclimb   (expects 512-dev flag
set by the module itself; takes several minutes).
"""

from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

import dataclasses
import json
import pathlib
import time

PEAK, HBM, LINK = 197e12, 819e9, 50e9
COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 16.0, "all-to-all": 1.0, "collective-permute": 1.0}


def lower_and_census(cfg, shape_name: str, mesh_kind: str, rules_override=None):
    from repro.configs import SHAPES
    from repro.launch.hlo_census import census
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shape = SHAPES[shape_name]
    kw = {"rules_override": rules_override} if rules_override else {}
    bundle = build_step(cfg, mesh, shape, **kw)
    t0 = time.time()
    with mesh:
        compiled = bundle.jitted.lower(*bundle.in_specs).compile()
    c = census(compiled.as_text())
    ma = compiled.memory_analysis()
    return {
        "compile_s": round(time.time() - t0, 1),
        "flops": c["dot_flops"],
        "tpu_bytes": c["tpu_bytes"],
        "coll": c["collectives"],
        "peak_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30,
        "n_dev": mesh.devices.size,
    }


def terms(rec: dict, extra_bytes: float = 0.0) -> dict:
    compute = rec["flops"] / PEAK
    memory = (rec["tpu_bytes"] + extra_bytes) / HBM
    coll = sum(COLL_FACTOR[k] * v["bytes"] for k, v in rec["coll"].items()) / LINK
    t = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    t["dominant"] = max(t, key=t.get).replace("_s", "")
    t["bound_s"] = max(compute, memory, coll)
    return t


# -- analytic kernel traffic (hillclimb "kernel_adj" variants) ---------------


def flash_fallback_vs_kernel_bytes(cfg, shape, n_dev: int, passes: float) -> tuple[float, float]:
    """Per-device HBM bytes of the jnp double-chunked fallback vs the Pallas
    flash kernel, for all attention layers of the step."""
    tp = 16
    h_loc = max(1, cfg.num_heads // tp)
    hd = cfg.resolved_head_dim
    kv_eff = max(1, (cfg.num_kv_heads or cfg.num_heads)) * 2 // 1  # kv_repeat≈2 upper bound
    b_loc = max(1, shape.global_batch // (n_dev // tp))
    s = shape.seq_len
    qc = kc = 1024
    nq, nk = s // qc, s // kc
    n_attn = sum(1 for k in cfg.block_kinds() if k in ("attn", "mla"))
    per_pair = (
        b_loc * h_loc * (qc * hd * 2 + kc * hd * 2)  # q,k reads (bf16)
        + b_loc * h_loc * qc * kc * 4  # scores write (fp32 dot result)
        + b_loc * h_loc * qc * kc * 2  # probs read by pv dot (bf16)
        + b_loc * h_loc * (kc * hd * 2 + qc * hd * 4)  # v read + acc write
        + 2 * b_loc * (kc * hd * 2) * 2  # k,v chunk dynamic-slice r/w
    )
    fallback = nq * nk * per_pair * n_attn * passes
    flash = (
        b_loc * h_loc * (s * hd * 2)  # q read
        + nq * b_loc * h_loc * 2 * (s * hd * 2)  # k,v read once per q block
        + b_loc * h_loc * s * hd * 2  # out write
    ) * n_attn * passes * 0.55  # causal block skipping ≈ halves kv reads
    return fallback, flash


def ssd_fallback_vs_kernel_bytes(cfg, shape, n_dev: int, passes: float) -> tuple[float, float]:
    s = cfg.ssd
    tp = 16
    d_in = s.d_inner(cfg.d_model)
    h_loc = max(1, s.n_heads(cfg.d_model) // tp)
    p, n, q = s.head_dim, s.d_state, s.chunk
    b_loc = max(1, shape.global_batch // (n_dev // tp))
    l = shape.seq_len
    nc = l // q
    n_ssd = sum(1 for k in cfg.block_kinds() if k == "ssd")
    # fallback (fp32 internal): per chunk dots: CBᵀ (Q²), y_diag, y_off, s_c
    per_chunk = b_loc * h_loc * (
        2 * q * q * 4           # scores write + read
        + 2 * q * n * 4 * 2     # B,C reads (twice: scores + states)
        + 2 * q * p * 4 * 2     # x reads, y writes
        + 2 * p * n * 4         # state r/w per chunk (HBM in fallback scan)
    )
    fallback = nc * per_chunk * n_ssd * passes
    # kernel: x,dt,B,C streamed once; y written once; state stays in VMEM
    kernel = (
        b_loc * (l * h_loc * p * 2 * 2 + l * h_loc * 4 + 2 * l * h_loc * n * 2)
    ) * n_ssd * passes
    return fallback, kernel


def run_cells() -> list[dict]:
    from repro.configs import SHAPES, get_config

    out_dir = pathlib.Path("experiments/perf")
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []

    # ---------------- Cell A: qwen1.5-110b train_4k single -----------------
    cfg = get_config("qwen1.5-110b")
    shape = SHAPES["train_4k"]
    base = lower_and_census(cfg, "train_4k", "single")
    results.append({"cell": "A qwen1.5-110b/train_4k/single", "variant": "baseline(paper-faithful)",
                    **base, **terms(base)})

    fb, fl = flash_fallback_vs_kernel_bytes(cfg, shape, base["n_dev"], passes=4.0)
    adj = dict(base)
    adj["tpu_bytes"] = base["tpu_bytes"] - fb + fl
    results.append({"cell": "A qwen1.5-110b/train_4k/single", "variant": "pallas_flash(kernel_adj)",
                    **adj, **terms(adj)})

    cfg2 = dataclasses.replace(cfg, remat_policy="dots")
    v2 = lower_and_census(cfg2, "train_4k", "single")
    fb2, fl2 = flash_fallback_vs_kernel_bytes(cfg2, shape, v2["n_dev"], passes=3.0)
    v2adj = dict(v2)
    v2adj["tpu_bytes"] = v2["tpu_bytes"] - fb2 + fl2
    results.append({"cell": "A qwen1.5-110b/train_4k/single", "variant": "remat_dots+flash",
                    **v2adj, **terms(v2adj)})

    # ---------------- Cell B: jamba train_4k multi --------------------------
    cfg = get_config("jamba-1.5-large-398b")
    base = lower_and_census(cfg, "train_4k", "multi")
    results.append({"cell": "B jamba-398b/train_4k/multi", "variant": "baseline(paper-faithful)",
                    **base, **terms(base)})

    # 2-D expert sharding: expert_ffn over dp, expert d_model unsharded
    ov = {"expert_ffn": ("pod", "data"), "expert_embed": None}
    v1 = lower_and_census(cfg, "train_4k", "multi", rules_override=ov)
    results.append({"cell": "B jamba-398b/train_4k/multi", "variant": "ep2d_expert_shard",
                    **v1, **terms(v1)})

    shape = SHAPES["train_4k"]
    fb, fl = flash_fallback_vs_kernel_bytes(cfg, shape, v1["n_dev"], passes=4.0)
    fbs, fls = ssd_fallback_vs_kernel_bytes(cfg, shape, v1["n_dev"], passes=4.0)
    v2 = dict(v1)
    v2["tpu_bytes"] = v1["tpu_bytes"] - fb - fbs + fl + fls
    results.append({"cell": "B jamba-398b/train_4k/multi", "variant": "ep2d+kernels(adj)",
                    **v2, **terms(v2)})

    # ---------------- Cell C: mamba2 prefill_32k single ---------------------
    cfg = get_config("mamba2-780m")
    shape = SHAPES["prefill_32k"]
    base = lower_and_census(cfg, "prefill_32k", "single")
    results.append({"cell": "C mamba2-780m/prefill_32k/single", "variant": "baseline(paper-faithful)",
                    **base, **terms(base)})

    fbs, fls = ssd_fallback_vs_kernel_bytes(cfg, shape, base["n_dev"], passes=1.0)
    adj = dict(base)
    adj["tpu_bytes"] = base["tpu_bytes"] - fbs + fls
    results.append({"cell": "C mamba2-780m/prefill_32k/single", "variant": "pallas_ssd(kernel_adj)",
                    **adj, **terms(adj)})

    cfg2 = dataclasses.replace(cfg, ssd=dataclasses.replace(cfg.ssd, chunk=128))
    v2 = lower_and_census(cfg2, "prefill_32k", "single")
    fbs2, fls2 = ssd_fallback_vs_kernel_bytes(cfg2, shape, v2["n_dev"], passes=1.0)
    v2a = dict(v2)
    v2a["tpu_bytes"] = v2["tpu_bytes"] - fbs2 + fls2
    results.append({"cell": "C mamba2-780m/prefill_32k/single", "variant": "chunk128+ssd_kernel",
                    **v2a, **terms(v2a)})

    (out_dir / "hillclimb.json").write_text(json.dumps(results, indent=2, default=float))
    return results


def main() -> None:
    results = run_cells()
    print(f"{'cell':<36}{'variant':<28}{'compute_s':>10}{'memory_s':>10}{'coll_s':>10}{'bound_s':>10}  dominant")
    for r in results:
        print(
            f"{r['cell']:<36}{r['variant']:<28}{r['compute_s']:>10.3f}{r['memory_s']:>10.3f}"
            f"{r['collective_s']:>10.3f}{r['bound_s']:>10.3f}  {r['dominant']}"
        )


if __name__ == "__main__":
    main()

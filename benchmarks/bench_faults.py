"""Fault recovery under chaos: stragglers, dying peers, truncated bodies.

PR 6's robustness layer is only real if it is *gated*: this bench injects
the faults the paper's production setting actually sees and measures that
the pipeline recovers instead of collapsing or corrupting.

Scenarios (rows):

* ``faults_bimodal_*`` — a chunked pipeline whose stage has a bimodal
  latency distribution (``FaultInjectingStage``: most items fast, a seeded
  few paying a long tail).  Three runs: clean (no tail), the straggler
  slow lane ON, and the slow lane OFF.  The gated claim: the slow lane
  sustains ≥ ``GATE_SLOWLANE_RATIO`` of clean throughput while the
  lane-off baseline demonstrably collapses (≤ ``GATE_BASELINE_MAX``) —
  one slow item holding its whole chunk hostage is exactly the failure
  chunked execution introduced.
* ``faults_peer_death`` — a shard fleet where the warm peer is killed
  mid-run: the circuit breaker benches it (with half-open probes after
  cooldown), every fetch falls through to the origin, and the run
  completes with zero hangs and zero corrupt payloads.
* ``faults_peer_hedge`` — the peer is alive but bandwidth-starved: the
  hedged ``TieredSource`` stops waiting out the slow tier and races the
  origin (first success wins), so throughput tracks the fast tier.
* ``faults_truncated`` — the origin drops connections mid-body: the
  Content-Length validation surfaces each as a retryable transport error,
  the retry layer covers it, and the payload that lands is byte-identical
  (never a short install).

Gates recorded in ``BENCH_faults.json``; ``--gate`` re-checks them at
smoke size and exits nonzero on regression (CI wires this in).
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = _ROOT / "BENCH_faults.json"

# -- bimodal workload shape -------------------------------------------------
BASE_S = 0.004  # fast-mode per-item latency
CHUNK = 16
CONCURRENCY = 4
SLOW_RATE = 0.02  # tail probability
SLOW_S = 0.4  # tail latency (100x the fast mode)
STRAGGLER_AFTER = 0.02  # 5x the fast mode, 1/20th the tail
STRAGGLER_RUNAHEAD = 96  # chunks of hole-fill cover (> SLOW_S * rate / CHUNK)
STRAGGLER_WORKERS = 32
AGG = 64

GATE_SLOWLANE_RATIO = 0.8  # slow lane keeps >= 80% of clean throughput
GATE_BASELINE_MAX = 0.6  # lane-off baseline demonstrably collapses

SEED = 1234


def _sleep_stage(x):
    time.sleep(BASE_S)
    return x


def _run_bimodal(n: int, *, slow_rate: float, slow_s: float, slowlane: bool) -> dict:
    from repro.core import FaultInjectingStage, PipelineBuilder

    stage = FaultInjectingStage(
        _sleep_stage, seed=SEED, slow_rate=slow_rate, slow_s=slow_s
    )
    b = (
        PipelineBuilder()
        .add_source(range(n))
        .pipe(
            stage,
            name="work",
            concurrency=CONCURRENCY,
            chunk=CHUNK,
            queue_size=AGG,
            straggler_after=STRAGGLER_AFTER if slowlane else None,
            straggler_runahead=STRAGGLER_RUNAHEAD,
        )
        .aggregate(AGG, name="agg")
        .add_sink(buffer_size=8)
    )
    p = b.build(num_threads=CONCURRENCY + 2, straggler_workers=STRAGGLER_WORKERS)
    t0 = time.monotonic()
    with p.auto_stop():
        out = [x for batch in p for x in batch]
    dt = time.monotonic() - t0
    assert out == list(range(n)), "fault run reordered or dropped items"
    row = next(s for s in p.stats() if s.name == "work")
    return {
        "items_per_sec": n / dt,
        "wall_s": dt,
        "items": n,
        "stragglers": row.stragglers,
        "straggler_shed": row.straggler_shed,
        "injected_slow": stage.injected_slow,
    }


def _bimodal(n: int, slow_s: float) -> dict:
    clean = _run_bimodal(n, slow_rate=0.0, slow_s=0.0, slowlane=False)
    lane = _run_bimodal(n, slow_rate=SLOW_RATE, slow_s=slow_s, slowlane=True)
    base = _run_bimodal(n, slow_rate=SLOW_RATE, slow_s=slow_s, slowlane=False)
    return {
        "clean": clean,
        "slowlane": lane,
        "baseline": base,
        "slowlane_ratio": lane["items_per_sec"] / clean["items_per_sec"],
        "baseline_ratio": base["items_per_sec"] / clean["items_per_sec"],
    }


# -- shard-fleet scenarios --------------------------------------------------
def _make_shards(root: pathlib.Path, *, n_items: int):
    from repro.data import SyntheticImageDataset, pack

    files = SyntheticImageDataset.materialize(root / "files", n_items, hw=(32, 32), seed=0)
    pack(files, root / "shards", samples_per_shard=32)
    shards = sorted((root / "shards").glob("*.rpshard"))
    return root / "shards", [s.name for s in shards]


def _peer_death(shards_dir: pathlib.Path, names: list[str]) -> dict:
    """Kill the warm peer mid-run: breaker opens (+ half-open probes), the
    origin covers, the run completes — zero hangs, zero corrupt bytes."""
    import threading

    from repro.data.shards.peer import PeerShardSource, TieredSource
    from repro.data.shards.sources import HttpShardSource, RetryingSource
    from repro.data.shards.testing import ShardHTTPServer

    origin = ShardHTTPServer(shards_dir)
    peer = ShardHTTPServer(shards_dir)  # models another rank's warm cache
    threads = []
    for srv in (origin, peer):
        t = threading.Thread(target=srv.serve_forever, daemon=True)
        t.start()
        threads.append(t)
    kill_at = max(1, len(names) // 3)
    try:
        tiered = TieredSource(
            RetryingSource(HttpShardSource(origin.url), base_delay_s=0.01),
            PeerShardSource([peer.url], timeout=1.0, cooldown_s=0.1),
        )
        mismatches = 0
        completed = 0
        t0 = time.monotonic()
        for i, name in enumerate(names):
            if i == kill_at:
                peer.kill()
            data = tiered.fetch(name)
            if data != (shards_dir / name).read_bytes():
                mismatches += 1
            completed += 1
            time.sleep(0.12)  # let cooldowns expire: exercise half-open probes
        wall = time.monotonic() - t0
        st = tiered.stats()
        tiered.close()
        return {
            "completed": completed,
            "total": len(names),
            "mismatches": mismatches,
            "wall_s": wall,
            "peer_hits": st["peer_hits"],
            "peer_errors": st["peer_errors"],
            "peer_probes": st["peer_probes"],
            "peers_down": st["peers_down"],
            "origin_fetches": st["origin_fetches"],
        }
    finally:
        origin.shutdown()
        origin.server_close()
        for t in threads:
            t.join(timeout=5)


def _peer_hedge(shards_dir: pathlib.Path, names: list[str]) -> dict:
    """Peer alive but bandwidth-starved: the hedge launches an origin fetch
    after ``hedge_after_s`` and takes whichever lands first."""
    import threading

    from repro.data.shards.peer import PeerShardSource, TieredSource
    from repro.data.shards.sources import HttpShardSource, RetryingSource
    from repro.data.shards.testing import ShardHTTPServer

    origin = ShardHTTPServer(shards_dir)
    peer = ShardHTTPServer(shards_dir)
    peer.slow_bps = 100_000  # ~1s+ per ~100KB shard through the peer
    threads = []
    for srv in (origin, peer):
        t = threading.Thread(target=srv.serve_forever, daemon=True)
        t.start()
        threads.append(t)
    try:
        tiered = TieredSource(
            RetryingSource(HttpShardSource(origin.url), base_delay_s=0.01),
            PeerShardSource([peer.url], timeout=10.0, cooldown_s=1.0),
            hedge_after_s=0.05,
        )
        mismatches = 0
        t0 = time.monotonic()
        for name in names:
            data = tiered.fetch(name)
            if data != (shards_dir / name).read_bytes():
                mismatches += 1
        wall = time.monotonic() - t0
        st = tiered.stats()
        tiered.close()
        nbytes = sum((shards_dir / n).stat().st_size for n in names)
        return {
            "completed": len(names),
            "mismatches": mismatches,
            "wall_s": wall,
            "hedges": st["hedges"],
            "hedge_wins": st["hedge_wins"],
            # what waiting out the slow peer would have cost
            "peer_only_floor_s": nbytes / peer.slow_bps,
        }
    finally:
        origin.shutdown()
        origin.server_close()
        peer.shutdown()
        peer.server_close()
        for t in threads:
            t.join(timeout=5)


def _truncated(shards_dir: pathlib.Path, names: list[str]) -> dict:
    """Origin drops connections mid-body: every fetch must land intact
    (retried), never install short."""
    import threading

    from repro.data.shards.sources import HttpShardSource, RetryingSource
    from repro.data.shards.testing import ShardHTTPServer

    origin = ShardHTTPServer(shards_dir)
    t = threading.Thread(target=origin.serve_forever, daemon=True)
    t.start()
    try:
        src = RetryingSource(
            HttpShardSource(origin.url), max_retries=6, base_delay_s=0.01
        )
        mismatches = 0
        t0 = time.monotonic()
        for i, name in enumerate(names):
            if i % 2 == 0:
                with origin.lock:
                    origin.truncate_next = 1  # this fetch dies mid-body once
            data = src.fetch(name)
            if data != (shards_dir / name).read_bytes():
                mismatches += 1
        wall = time.monotonic() - t0
        stats = src.stats()
        src.close()
        return {
            "completed": len(names),
            "mismatches": mismatches,
            "wall_s": wall,
            "truncations": origin.truncations,
            "retries": stats["retries"],
        }
    finally:
        origin.shutdown()
        origin.server_close()
        t.join(timeout=5)


# -- harness ---------------------------------------------------------------
def _scenarios(*, smoke: bool) -> dict:
    n = 600 if smoke else 2400
    slow_s = 0.25 if smoke else SLOW_S
    bimodal = _bimodal(n, slow_s)
    with tempfile.TemporaryDirectory() as d:
        shards_dir, names = _make_shards(
            pathlib.Path(d), n_items=128 if smoke else 384
        )
        peer_death = _peer_death(shards_dir, names)
        hedge = _peer_hedge(shards_dir, names)
        truncated = _truncated(shards_dir, names)
    return {
        "workload": {
            "n": n,
            "base_s": BASE_S,
            "chunk": CHUNK,
            "concurrency": CONCURRENCY,
            "slow_rate": SLOW_RATE,
            "slow_s": slow_s,
            "straggler_after": STRAGGLER_AFTER,
            "straggler_runahead": STRAGGLER_RUNAHEAD,
            "straggler_workers": STRAGGLER_WORKERS,
        },
        "bimodal": bimodal,
        "peer_death": peer_death,
        "peer_hedge": hedge,
        "truncated": truncated,
        "gate_slowlane_ratio": GATE_SLOWLANE_RATIO,
        "gate_baseline_ratio_max": GATE_BASELINE_MAX,
    }


def _check(result: dict) -> list[str]:
    """The recovery gates; returns a list of violations (empty = pass)."""
    bad = []
    bi = result["bimodal"]
    if bi["slowlane_ratio"] < result["gate_slowlane_ratio"]:
        bad.append(
            f"slow lane sustained x{bi['slowlane_ratio']:.2f} of clean "
            f"throughput < gate x{result['gate_slowlane_ratio']:.2f}"
        )
    if bi["baseline_ratio"] > result["gate_baseline_ratio_max"]:
        bad.append(
            f"lane-off baseline kept x{bi['baseline_ratio']:.2f} of clean "
            f"throughput — the bimodal tail is not actually collapsing it "
            f"(expected <= x{result['gate_baseline_ratio_max']:.2f})"
        )
    if bi["slowlane"]["stragglers"] == 0:
        bad.append("slow lane detached zero stragglers — fault injection inert")
    pd = result["peer_death"]
    if pd["completed"] != pd["total"] or pd["mismatches"]:
        bad.append(f"peer death: {pd}")
    if pd["peer_errors"] < 1 or pd["peers_down"] != 1:
        bad.append(f"peer death: breaker never tripped: {pd}")
    if pd["peer_probes"] < 1:
        bad.append(f"peer death: no half-open probe issued: {pd}")
    he = result["peer_hedge"]
    if he["mismatches"] or he["hedge_wins"] < 1:
        bad.append(f"peer hedge: {he}")
    if he["wall_s"] >= he["peer_only_floor_s"]:
        bad.append(
            f"peer hedge: wall {he['wall_s']:.2f}s did not beat the "
            f"peer-only floor {he['peer_only_floor_s']:.2f}s"
        )
    tr = result["truncated"]
    if tr["mismatches"] or tr["truncations"] < 1 or tr["retries"] < 1:
        bad.append(f"truncated transfer: {tr}")
    return bad


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    result = _scenarios(smoke=smoke)
    violations = _check(result)
    result["violations"] = violations
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    bi = result["bimodal"]
    rows = [
        (
            "faults_bimodal_clean",
            1e6 / max(bi["clean"]["items_per_sec"], 1e-9),
            f"{bi['clean']['items_per_sec']:.0f}items/s",
        ),
        (
            "faults_bimodal_slowlane",
            1e6 / max(bi["slowlane"]["items_per_sec"], 1e-9),
            f"x{bi['slowlane_ratio']:.2f}_of_clean_"
            f"{bi['slowlane']['stragglers']}detached_"
            f"{'OK' if bi['slowlane_ratio'] >= GATE_SLOWLANE_RATIO else 'BELOW_GATE'}",
        ),
        (
            "faults_bimodal_baseline",
            1e6 / max(bi["baseline"]["items_per_sec"], 1e-9),
            f"x{bi['baseline_ratio']:.2f}_of_clean_lane_off_collapse",
        ),
        (
            "faults_peer_death",
            result["peer_death"]["wall_s"] * 1e6 / result["peer_death"]["total"],
            f"{result['peer_death']['completed']}/{result['peer_death']['total']}ok_"
            f"{result['peer_death']['mismatches']}corrupt_"
            f"{result['peer_death']['peer_probes']}probes",
        ),
        (
            "faults_peer_hedge",
            result["peer_hedge"]["wall_s"] * 1e6 / result["peer_hedge"]["completed"],
            f"{result['peer_hedge']['hedge_wins']}hedge_wins_"
            f"vs_{result['peer_hedge']['peer_only_floor_s']:.1f}s_peer_floor",
        ),
        (
            "faults_truncated",
            result["truncated"]["wall_s"] * 1e6 / result["truncated"]["completed"],
            f"{result['truncated']['truncations']}truncations_"
            f"{result['truncated']['mismatches']}corrupt_"
            f"{result['truncated']['retries']}retries",
        ),
    ]
    if violations:
        raise RuntimeError("fault gates violated: " + "; ".join(violations))
    return rows


def check_gate() -> int:
    """CI regression tripwire: re-run every chaos scenario at smoke size
    and fail on any recovery-gate violation."""
    result = _scenarios(smoke=True)
    bi = result["bimodal"]
    print(
        f"bimodal: slowlane x{bi['slowlane_ratio']:.2f} "
        f"(gate >= x{GATE_SLOWLANE_RATIO:.2f}), "
        f"baseline x{bi['baseline_ratio']:.2f} "
        f"(gate <= x{GATE_BASELINE_MAX:.2f}), "
        f"{bi['slowlane']['stragglers']} stragglers detached"
    )
    print(f"peer_death: {result['peer_death']}")
    print(f"peer_hedge: {result['peer_hedge']}")
    print(f"truncated: {result['truncated']}")
    violations = _check(result)
    for v in violations:
        print(f"REGRESSION: {v}")
    return 1 if violations else 0


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

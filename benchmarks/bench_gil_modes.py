"""Paper Table 3 / Fig 10: Python-version & GIL-mode sensitivity.

The container has exactly one interpreter (CPython 3.13, GIL enabled), so
the 3.12/3.13/3.13t sweep cannot be run.  What we CAN measure is the
mechanism the paper attributes the win to: whether a worker thread's
GIL-releasing work overlaps a GIL-holding main thread.  We run a
pure-python spin on the main thread while a worker does (a) zstd decode
(releases) vs (b) pure-python decode (holds), and report the slowdown each
inflicts on the main thread — the Fig 2 "operations get slower as threads
are added" effect, isolated.
"""

from __future__ import annotations

import sys
import threading
import time

import numpy as np

from repro.data.codec import decode_sample, encode_sample, py_decode


def _main_thread_spin(n: int = 250_000) -> float:
    t0 = time.monotonic()
    acc = 0
    for i in range(n):
        acc = (acc + i * i) % 1000003
    return time.monotonic() - t0


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    sample = encode_sample(rng.integers(0, 256, (512, 512, 3), dtype=np.uint8))
    rows = []
    gil = getattr(sys, "_is_gil_enabled", lambda: True)()
    rows.append(("table3_python", 0.0, f"{sys.version_info.major}.{sys.version_info.minor};gil_enabled={gil}"))

    base = min(_main_thread_spin() for _ in range(3))

    for label, fn in [("zstd_release", decode_sample), ("pure_py_hold", py_decode)]:
        stop = threading.Event()

        def worker():
            while not stop.is_set():
                fn(sample)

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        time.sleep(0.05)
        dt = min(_main_thread_spin() for _ in range(3))
        stop.set()
        th.join()
        rows.append(
            (
                f"table3_main_thread_vs_{label}",
                dt * 1e6,
                f"slowdown_x{dt / base:.2f}_vs_idle",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

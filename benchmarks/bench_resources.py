"""Paper Fig 6/7: CPU utilization and memory of SPDL vs process loading.

The paper's headline: SPDL uses 38% less CPU (no IPC serialization burning
system time) and ~50 GB less memory (no per-worker dataset duplication).
Here we sample /proc/self while iterating each loader.  MPLoader child
memory is not visible in parent RSS, so for the memory comparison we report
the parent RSS + an exact accounting of the duplicated dataset bytes
(world_size × pickled dataset size) the way the paper's Fig 7 attributes it.
"""

from __future__ import annotations

import pickle
import tempfile

from repro.core import ResourceSampler
from repro.data import SyntheticImageDataset, build_image_loader
from repro.data.baselines import MPLoader

N, HW, BS = 256, (128, 128), 8


def run() -> list[tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ds = SyntheticImageDataset.materialize(d, N, hw=HW, seed=0)

        pipe = build_image_loader(ds, batch_size=BS, hw=(64, 64), decode_concurrency=4)
        with ResourceSampler(0.02) as rs:
            with pipe.auto_stop():
                for _ in pipe:
                    pass
        s = rs.summary()
        rows.append(
            ("fig6_spdl_cpu", s["cpu_util"] * 1e6, f"cpu={s['cpu_util']:.2f};rss={s['peak_rss_mb']:.0f}MB")
        )

        loader = MPLoader(ds, batch_size=BS, hw=(64, 64), num_workers=2)
        with ResourceSampler(0.02) as rs:
            for _ in loader:
                pass
        s = rs.summary()
        dup_mb = 2 * len(pickle.dumps(ds)) / 2**20  # per-worker dataset copies
        rows.append(
            (
                "fig7_mploader_cpu",
                s["cpu_util"] * 1e6,
                f"cpu={s['cpu_util']:.2f};rss={s['peak_rss_mb']:.0f}MB+{dup_mb:.1f}MB_worker_dup",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Paper Fig 8/9: end-to-end throughput with model inference and training,
plus the dummy-loader MAX bound (Fig 9's key claim: SPDL ≈ MAX, i.e. the
loader never starves the accelerator step) — and the hot-path-to-device
proof: a ViT-B/16-shaped synthetic training step fed by the full image
loader (uint8 wire + chunked sink drain + on-chip fused decode).

The image section records two acceptance gates in ``BENCH_e2e.json``:

* **zero starvation** — accumulated ``get_items`` wait across the
  measured steps is ≤ 1% of wall time (the step never waits on data);
* **host CPU** — draining an epoch through the uint8-wire + device-decode
  path costs ≥ 1.5× less process CPU time than the host-decode baseline
  (same loader, float decode tail on the consumer thread), because the
  host never touches a pixel float.

``python -m benchmarks.bench_e2e --gate`` re-checks both at reduced size
and exits nonzero on regression (CI).  ``--smoke`` shrinks everything.
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import tempfile
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    SyntheticImageDataset,
    SyntheticTokenDataset,
    build_image_loader,
    build_lm_loader,
)
from repro.data.transfer import DeviceDecode

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_e2e.json"

SEQ_LEN, LM_BATCH = 64, 8
STEPS = 20

# -- ViT-B/16-shaped image workload (true /16 patching; width/depth scaled
# -- for a CPU box — the tokens-per-image and data path are the real thing)
IMG_HW = (224, 224)
PATCH = 16
D_MODEL = 128
DEPTH = 2
HEADS = 4
N_CLASSES = 10
IMG_BATCH = 8
IMG_N = 64  # dataset size → 8 batches/epoch
IMG_STEPS = 24
CPU_EPOCHS = 3  # epochs per CPU-time drain: widen past /proc's 10ms ticks
MEAN = (0.485, 0.456, 0.406)
STD = (0.229, 0.224, 0.225)
GATE_STARVATION_MAX = 0.01
GATE_CPU_SPEEDUP_MIN = 1.5


def _mk():
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import build_train_step
    from repro.optim import init_opt_state

    shape = ShapeConfig("bench_train", seq_len=SEQ_LEN, global_batch=LM_BATCH, kind="train")
    cfg = get_smoke_config("olmo-1b")
    # donate=False: the bench reuses (params, opt) across loops
    bundle = build_train_step(cfg, None, shape, donate=False)
    params = bundle.model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(bundle.opt_cfg, params)
    ds = SyntheticTokenDataset(400, vocab=cfg.vocab_size, min_len=32, max_len=160)
    return cfg, bundle, params, opt, ds


def _loop(bundle, params, opt, batches) -> float:
    t0 = time.monotonic()
    n = 0
    for batch in batches:
        params, opt, metrics = bundle.jitted(params, opt, batch)
        n += 1
    jax.block_until_ready(metrics["loss"])
    return n * LM_BATCH * SEQ_LEN / (time.monotonic() - t0)


def _lm_rows(steps: int) -> list[tuple[str, float, str]]:
    try:
        cfg, bundle, params, opt, ds = _mk()
    except (ImportError, ModuleNotFoundError) as e:
        # the LM model stack is optional here; the image section below is
        # self-contained and still runs (and carries the gates)
        return [("fig8/9_lm_skipped", 0.0, f"model_stack_unavailable:{type(e).__name__}")]
    rows = []

    # -- MAX: dummy loader (one batch reused; zero loading cost) ----------
    rng = np.random.default_rng(0)
    fake = {
        "tokens": rng.integers(0, cfg.vocab_size, (LM_BATCH, SEQ_LEN)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (LM_BATCH, SEQ_LEN)).astype(np.int32),
        "positions": np.tile(np.arange(SEQ_LEN, dtype=np.int32), (LM_BATCH, 1)),
        "segment_ids": np.zeros((LM_BATCH, SEQ_LEN), np.int32),
    }
    _loop(bundle, params, opt, [fake] * 3)  # warmup/compile
    tps_max = _loop(bundle, params, opt, [fake] * steps)
    rows.append(("fig9_train_MAX_dummy", 1e6 / tps_max, f"{tps_max:.0f}tok/s"))

    # -- SPDL-fed training --------------------------------------------------
    pipe, _ = build_lm_loader(ds, seq_len=SEQ_LEN, batch_size=LM_BATCH, num_threads=4)
    with pipe.auto_stop():
        it = iter(pipe)
        batches = [next(it) for _ in range(steps)]  # prefetch check below uses live feed
        tps_spdl = _loop(bundle, params, opt, batches)
    rows.append(
        ("fig9_train_spdl", 1e6 / tps_spdl, f"{tps_spdl:.0f}tok/s;{tps_spdl / tps_max:.0%}_of_MAX")
    )

    # live-fed (loader concurrent with steps, the honest fig9 measurement)
    pipe2, _ = build_lm_loader(ds, seq_len=SEQ_LEN, batch_size=LM_BATCH, num_threads=4)
    with pipe2.auto_stop():
        it = iter(pipe2)
        t0 = time.monotonic()
        for _ in range(steps):
            batch = next(it)
            params, opt, m = bundle.jitted(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = time.monotonic() - t0
    tps_live = steps * LM_BATCH * SEQ_LEN / dt
    rows.append(
        ("fig9_train_spdl_live", 1e6 / tps_live, f"{tps_live:.0f}tok/s;{tps_live / tps_max:.0%}_of_MAX")
    )

    # -- Fig 8: inference (prefill) fed by the pipeline ---------------------
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import build_prefill_step

    pshape = ShapeConfig("bench_infer", 64, 8, "prefill")
    pb = build_prefill_step(cfg, None, pshape)
    pipe3, _ = build_lm_loader(ds, seq_len=64, batch_size=8, num_threads=4)
    infer_steps = max(4, steps // 2)
    with pipe3.auto_stop():
        it = iter(pipe3)
        first = next(it)
        jax.block_until_ready(pb.jitted(params, {"tokens": first["tokens"]})[0])  # compile
        t0 = time.monotonic()
        for _ in range(infer_steps):
            batch = next(it)
            logits, _ = pb.jitted(params, {"tokens": batch["tokens"]})
        jax.block_until_ready(logits)
        dt = time.monotonic() - t0
    fps = infer_steps * 8 / dt
    rows.append(("fig8_infer_spdl", 1e6 / fps, f"{fps:.1f}seq/s"))
    return rows


# ---------------------------------------------------------------------------
# ViT-shaped synthetic step (self-contained: params are a plain pytree)
# ---------------------------------------------------------------------------
def _vit_init(key, hw: tuple[int, int]):
    n_tok = (hw[0] // PATCH) * (hw[1] // PATCH)
    in_dim = 3 * PATCH * PATCH
    ks = iter(jax.random.split(key, 3 + 8 * DEPTH))
    g = lambda shape, s: (jax.random.normal(next(ks), shape, jnp.float32) * s)
    params = {
        "proj": g((in_dim, D_MODEL), in_dim**-0.5),
        "pos": g((n_tok, D_MODEL), 0.02),
        "head": g((D_MODEL, N_CLASSES), D_MODEL**-0.5),
        "blocks": [
            {
                "ln1": jnp.ones((D_MODEL,)),
                "ln2": jnp.ones((D_MODEL,)),
                "qkv": g((D_MODEL, 3 * D_MODEL), D_MODEL**-0.5),
                "attn_o": g((D_MODEL, D_MODEL), D_MODEL**-0.5),
                "mlp_up": g((D_MODEL, 4 * D_MODEL), D_MODEL**-0.5),
                "mlp_dn": g((4 * D_MODEL, D_MODEL), (4 * D_MODEL) ** -0.5),
            }
            for _ in range(DEPTH)
        ],
    }
    return params


def _vit_apply(params, x):  # x: (B, 3, H, W) — the device-decode output layout
    b, c, h, w = x.shape
    nh, nw = h // PATCH, w // PATCH
    x = x.astype(jnp.float32)
    x = x.reshape(b, c, nh, PATCH, nw, PATCH)
    x = x.transpose(0, 2, 4, 1, 3, 5).reshape(b, nh * nw, c * PATCH * PATCH)
    hdn = x @ params["proj"] + params["pos"]

    def ln(v, gamma):
        mu = v.mean(-1, keepdims=True)
        var = ((v - mu) ** 2).mean(-1, keepdims=True)
        return (v - mu) * jax.lax.rsqrt(var + 1e-6) * gamma

    for blk in params["blocks"]:
        y = ln(hdn, blk["ln1"])
        qkv = (y @ blk["qkv"]).reshape(b, -1, 3, HEADS, D_MODEL // HEADS)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (D_MODEL // HEADS) ** -0.5
        a = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, -1, D_MODEL)
        hdn = hdn + y @ blk["attn_o"]
        y = ln(hdn, blk["ln2"])
        hdn = hdn + jax.nn.gelu(y @ blk["mlp_up"]) @ blk["mlp_dn"]
    return hdn.mean(1) @ params["head"]


def _make_vit_step():
    @jax.jit
    def step(params, x, labels):
        def loss_fn(p):
            logits = _vit_apply(p, x)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree_util.tree_map(lambda a, g: a - 1e-3 * g, params, grads)
        return params, loss

    return step


def _proc_cpu_s() -> float:
    """Process CPU seconds (utime + stime, all threads) from /proc."""
    parts = open("/proc/self/stat").read().split()
    return (int(parts[13]) + int(parts[14])) / os.sysconf("SC_CLK_TCK")


def _host_decode_tail(images: np.ndarray) -> jax.Array:
    """The baseline the fused kernel replaces: the classic host-side float
    decode tail — uint8 → f32 /255, per-channel normalize, NCHW transpose,
    contiguous copy — then the (4× fatter) device_put."""
    x = images.astype(np.float32) / 255.0
    x -= np.asarray(MEAN, np.float32)
    x /= np.asarray(STD, np.float32)
    x = np.ascontiguousarray(x.transpose(0, 3, 1, 2))
    return jax.device_put(x)


def _image_loader(ds, hw, *, device_decode: bool, epochs):
    dd = (
        DeviceDecode(mean=MEAN, std=STD, use_pallas="auto")
        if device_decode
        else None
    )
    return build_image_loader(
        ds,
        batch_size=IMG_BATCH,
        hw=hw,
        epochs=epochs,
        num_threads=6,
        read_concurrency=3,
        decode_concurrency=3,
        sink_buffer=3,
        uint8_wire=True,
        device_decode=dd,
        transfer_chunk=2,
    )


def _measure_starvation(ds, hw, steps: int) -> dict:
    """Live-fed ViT training: the loader runs concurrently with the step;
    the gate is the accumulated time the step spent waiting in get_items
    after warmup (≤ 1% of wall = the loader never starves the step)."""
    params = _vit_init(jax.random.PRNGKey(0), hw)
    step = _make_vit_step()
    labels = jnp.asarray(np.arange(IMG_BATCH) % N_CLASSES, jnp.int32)
    pipe = _image_loader(ds, hw, device_decode=True, epochs=None)
    stash: deque = deque()
    wait = 0.0

    def next_batch():
        nonlocal wait
        if not stash:
            t0 = time.monotonic()
            stash.extend(pipe.get_items(2))
            wait += time.monotonic() - t0
        return stash.popleft()

    with pipe.auto_stop():
        pipe.start()
        for _ in range(2):  # compile + fill the sink
            params, loss = step(params, next_batch()["images"], labels)
        jax.block_until_ready(loss)
        wait = 0.0
        t0 = time.monotonic()
        for _ in range(steps):
            params, loss = step(params, next_batch()["images"], labels)
        jax.block_until_ready(loss)
        wall = time.monotonic() - t0
        snaps = pipe.stats()
    return {
        "steps": steps,
        "wall_s": wall,
        "step_wait_s": wait,
        "starvation_frac": wait / wall,
        "sink_drained_chunks": snaps[-1].sink_drained_chunks,
        "device_decode_batches": next(
            s.device_decode_batches for s in snaps if s.name == "transfer"
        ),
    }


def _measure_cpu_epoch(ds, hw, *, device_decode: bool, epochs: int = CPU_EPOCHS) -> dict:
    """Process CPU time to drain ``epochs`` of ready-to-train batches.

    device_decode=True: uint8 wire + fused on-chip decode (zero host float
    math).  False: the same loader, host-side float decode tail per batch
    (what every host-decode pipeline pays)."""
    # warm compile caches outside the measured window
    warm = np.zeros((IMG_BATCH, *hw, 3), np.uint8)
    if device_decode:
        from repro.kernels.ops import dequant_normalize_augment

        jax.block_until_ready(
            dequant_normalize_augment(
                jnp.asarray(warm),
                jnp.asarray(MEAN, jnp.float32),
                jnp.asarray(STD, jnp.float32),
            )
        )
    else:
        jax.block_until_ready(_host_decode_tail(warm))

    pipe = _image_loader(ds, hw, device_decode=device_decode, epochs=epochs)
    batches = 0
    t0 = time.monotonic()
    c0 = _proc_cpu_s()
    with pipe.auto_stop():
        pipe.start()
        while True:
            try:
                chunk = pipe.get_items(2)
            except StopIteration:
                break
            for b in chunk:
                out = (
                    b["images"]
                    if device_decode
                    else _host_decode_tail(np.asarray(b["images"]))
                )
                jax.block_until_ready(out)  # the decode must actually run
                batches += 1
    cpu = _proc_cpu_s() - c0
    wall = time.monotonic() - t0
    return {"batches": batches, "cpu_s": cpu, "wall_s": wall,
            "cpu_s_per_batch": cpu / max(batches, 1)}


def _image_section(smoke: bool) -> dict:
    hw = (64, 64) if smoke else IMG_HW
    n = 16 if smoke else IMG_N
    steps = 4 if smoke else IMG_STEPS
    epochs = 1 if smoke else CPU_EPOCHS
    with tempfile.TemporaryDirectory() as d:
        ds = SyntheticImageDataset.materialize(d, n, hw=hw, seed=0)
        starv = _measure_starvation(ds, hw, steps)
        # interleave-free A/B: each drain is a fresh bounded pipeline
        host = _measure_cpu_epoch(ds, hw, device_decode=False, epochs=epochs)
        wire = _measure_cpu_epoch(ds, hw, device_decode=True, epochs=epochs)
    speedup = host["cpu_s"] / max(wire["cpu_s"], 1e-9)
    return {
        "workload": {
            "hw": list(hw), "patch": PATCH, "tokens_per_image": (hw[0] // PATCH) * (hw[1] // PATCH),
            "d_model": D_MODEL, "depth": DEPTH, "heads": HEADS,
            "batch_size": IMG_BATCH, "dataset_items": n, "steps": steps,
        },
        "starvation": starv,
        "cpu_epoch_host_decode": host,
        "cpu_epoch_device_decode": wire,
        "host_cpu_speedup": speedup,
        "gates": {
            "starvation_frac_max": GATE_STARVATION_MAX,
            "starvation_frac": starv["starvation_frac"],
            "starvation_ok": starv["starvation_frac"] <= GATE_STARVATION_MAX,
            "host_cpu_speedup_min": GATE_CPU_SPEEDUP_MIN,
            "host_cpu_speedup": speedup,
            "host_cpu_ok": speedup >= GATE_CPU_SPEEDUP_MIN,
        },
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = _lm_rows(4 if smoke else STEPS)
    img = _image_section(smoke)
    g = img["gates"]
    rows.append(
        (
            "e2e_vit_starvation",
            img["starvation"]["step_wait_s"] * 1e6 / max(img["starvation"]["steps"], 1),
            f"{g['starvation_frac']:.3%}_of_wall;gate<= {GATE_STARVATION_MAX:.0%}",
        )
    )
    rows.append(
        (
            "e2e_vit_host_cpu",
            img["cpu_epoch_device_decode"]["cpu_s_per_batch"] * 1e6,
            f"x{g['host_cpu_speedup']:.2f}_less_host_cpu;gate>=x{GATE_CPU_SPEEDUP_MIN:.1f}",
        )
    )
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(img, indent=2) + "\n")
    return rows


def check_gate() -> int:
    """CI gate: both image-section gates at reduced size, nonzero on fail."""
    global IMG_STEPS
    IMG_STEPS = 12  # CI-budget sized; full hw/dataset keeps the signal real
    img = _image_section(smoke=False)
    g = img["gates"]
    print(
        f"e2e gate: starvation {g['starvation_frac']:.3%} "
        f"(<= {GATE_STARVATION_MAX:.0%}), host-CPU x{g['host_cpu_speedup']:.2f} "
        f"(>= x{GATE_CPU_SPEEDUP_MIN:.1f})"
    )
    ok = True
    if not g["starvation_ok"]:
        print(f"REGRESSION: step wait {g['starvation_frac']:.3%} of wall exceeds gate")
        ok = False
    if not g["host_cpu_ok"]:
        print(f"REGRESSION: host-CPU speedup x{g['host_cpu_speedup']:.2f} below gate")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

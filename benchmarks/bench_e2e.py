"""Paper Fig 8/9: end-to-end throughput with model inference and training,
plus the dummy-loader MAX bound (Fig 9's key claim: SPDL ≈ MAX, i.e. the
loader never starves the accelerator step)."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokenDataset, build_lm_loader
from repro.launch.steps import build_prefill_step, build_train_step
from repro.optim import init_opt_state

SHAPE = ShapeConfig("bench_train", seq_len=64, global_batch=8, kind="train")
STEPS = 20


def _mk():
    cfg = get_smoke_config("olmo-1b")
    # donate=False: the bench reuses (params, opt) across loops
    bundle = build_train_step(cfg, None, SHAPE, donate=False)
    params = bundle.model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(bundle.opt_cfg, params)
    ds = SyntheticTokenDataset(400, vocab=cfg.vocab_size, min_len=32, max_len=160)
    return cfg, bundle, params, opt, ds


def _loop(bundle, params, opt, batches) -> float:
    t0 = time.monotonic()
    n = 0
    for batch in batches:
        params, opt, metrics = bundle.jitted(params, opt, batch)
        n += 1
    jax.block_until_ready(metrics["loss"])
    return n * SHAPE.global_batch * SHAPE.seq_len / (time.monotonic() - t0)


def run() -> list[tuple[str, float, str]]:
    cfg, bundle, params, opt, ds = _mk()
    rows = []

    # -- MAX: dummy loader (one batch reused; zero loading cost) ----------
    rng = np.random.default_rng(0)
    fake = {
        "tokens": rng.integers(0, cfg.vocab_size, (SHAPE.global_batch, SHAPE.seq_len)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (SHAPE.global_batch, SHAPE.seq_len)).astype(np.int32),
        "positions": np.tile(np.arange(SHAPE.seq_len, dtype=np.int32), (SHAPE.global_batch, 1)),
        "segment_ids": np.zeros((SHAPE.global_batch, SHAPE.seq_len), np.int32),
    }
    _loop(bundle, params, opt, [fake] * 3)  # warmup/compile
    tps_max = _loop(bundle, params, opt, [fake] * STEPS)
    rows.append(("fig9_train_MAX_dummy", 1e6 / tps_max, f"{tps_max:.0f}tok/s"))

    # -- SPDL-fed training --------------------------------------------------
    pipe, _ = build_lm_loader(ds, seq_len=SHAPE.seq_len, batch_size=SHAPE.global_batch, num_threads=4)
    with pipe.auto_stop():
        it = iter(pipe)
        batches = [next(it) for _ in range(STEPS)]  # prefetch check below uses live feed
        tps_spdl = _loop(bundle, params, opt, batches)
    rows.append(
        ("fig9_train_spdl", 1e6 / tps_spdl, f"{tps_spdl:.0f}tok/s;{tps_spdl / tps_max:.0%}_of_MAX")
    )

    # live-fed (loader concurrent with steps, the honest fig9 measurement)
    pipe2, _ = build_lm_loader(ds, seq_len=SHAPE.seq_len, batch_size=SHAPE.global_batch, num_threads=4)
    with pipe2.auto_stop():
        it = iter(pipe2)
        t0 = time.monotonic()
        for _ in range(STEPS):
            batch = next(it)
            params, opt, m = bundle.jitted(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = time.monotonic() - t0
    tps_live = STEPS * SHAPE.global_batch * SHAPE.seq_len / dt
    rows.append(
        ("fig9_train_spdl_live", 1e6 / tps_live, f"{tps_live:.0f}tok/s;{tps_live / tps_max:.0%}_of_MAX")
    )

    # -- Fig 8: inference (prefill) fed by the pipeline ---------------------
    pshape = ShapeConfig("bench_infer", 64, 8, "prefill")
    pb = build_prefill_step(cfg, None, pshape)
    pipe3, _ = build_lm_loader(ds, seq_len=64, batch_size=8, num_threads=4)
    with pipe3.auto_stop():
        it = iter(pipe3)
        first = next(it)
        jax.block_until_ready(pb.jitted(params, {"tokens": first["tokens"]})[0])  # compile
        t0 = time.monotonic()
        for _ in range(10):
            batch = next(it)
            logits, _ = pb.jitted(params, {"tokens": batch["tokens"]})
        jax.block_until_ready(logits)
        dt = time.monotonic() - t0
    fps = 10 * 8 / dt
    rows.append(("fig8_infer_spdl", 1e6 / fps, f"{fps:.1f}seq/s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Elastic shard fleet under churn: membership, bounded remap, warm
restart, and admission control — the robustness gates for the
membership/placement layer (``membership.py``).

Scenarios (rows):

* ``fleet_churn`` — 4 warm peers behind a consistent-hash ring; one is
  killed mid-epoch (and swept from membership), then a replacement
  joins.  The gated claims: the churn epoch sustains
  ≥ ``GATE_CHURN_RATIO`` of clean-epoch throughput, and each membership
  change remaps ≤ 2/N of the keyspace (measured over a probe keyspace,
  not just the handful of bench shards).
* ``fleet_warm_restart`` — a rank reads an epoch through a
  ``persist_state=True`` prefetcher, "crashes" (close), and restarts
  over the same cache dir.  The restarted rank must serve
  ≥ ``GATE_WARM_REUSE`` of the epoch's bytes from the persisted
  manifest/spans with **zero** re-fetch of already-resident ranges.
* ``fleet_admission`` — two tenants against one admission-controlled
  origin: the quota'd tenant must converge on its byte-rate quota
  (± ``GATE_QUOTA_TOL``) while the unmetered tenant keeps
  ≥ ``GATE_NEIGHBOR_RATIO`` of its solo throughput — no noisy-neighbor
  collapse.

Gates recorded in ``BENCH_fleet.json``; ``--gate`` re-checks them at
smoke size and exits nonzero on regression (CI's ``fleet-churn`` job).
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import threading
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = _ROOT / "BENCH_fleet.json"

N_PEERS = 4
GATE_CHURN_RATIO = 0.8  # churn epoch keeps >= 80% of clean throughput
GATE_REMAP_MAX = 2 / N_PEERS  # keys remapped per membership change
GATE_WARM_REUSE = 0.9  # fraction of epoch bytes served from persisted state
GATE_QUOTA_TOL = 0.10  # throttled tenant lands on quota +- 10%
GATE_NEIGHBOR_RATIO = 0.9  # unmetered tenant keeps >= 90% of solo rate

#: probe keyspace for remap-fraction measurement (the bench's handful of
#: shards is too coarse to resolve a 2/N bound)
PROBE_KEYS = [f"probe-{i:05d}.rpshard" for i in range(400)]


def _make_shards(root: pathlib.Path, *, n_items: int):
    from repro.data import SyntheticImageDataset, pack

    files = SyntheticImageDataset.materialize(
        root / "files", n_items, hw=(32, 32), seed=0
    )
    pack(files, root / "shards", samples_per_shard=32)
    shards = sorted((root / "shards").glob("*.rpshard"))
    return root / "shards", [s.name for s in shards]


def _serve(shards_dir: pathlib.Path, **kw):
    import threading as _t

    from repro.data.shards.testing import ShardHTTPServer

    srv = ShardHTTPServer(shards_dir, **kw)
    thread = _t.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    return srv, thread


def _owner_snapshot(ring) -> dict[str, str]:
    return {k: ring.owners(k, 1)[0] for k in PROBE_KEYS}


def _remap_fraction(before: dict[str, str], after: dict[str, str]) -> float:
    return sum(1 for k in PROBE_KEYS if before[k] != after[k]) / len(PROBE_KEYS)


# -- scenario 1: churn ------------------------------------------------------
def _churn(shards_dir: pathlib.Path, names: list[str], *, rounds: int) -> dict:
    """Kill 1 of N warm peers mid-epoch, sweep it from membership, admit a
    replacement — sustained throughput + bounded remap, zero corruption."""
    from repro.data.shards.peer import PeerShardSource, TieredSource
    from repro.data.shards.sources import HttpShardSource, RetryingSource

    raw = {n: (shards_dir / n).read_bytes() for n in names}
    epoch = [n for _ in range(rounds) for n in names]

    def run_epoch(churn: bool) -> dict:
        servers, threads = [], []
        for _ in range(N_PEERS):
            s, t = _serve(shards_dir)
            servers.append(s)
            threads.append(t)
        origin, origin_t = _serve(shards_dir)
        threads.append(origin_t)
        ps = PeerShardSource(
            [s.url for s in servers],
            placement="ring",
            replicas=1,
            timeout=2.0,
            cooldown_s=0.2,
        )
        tiered = TieredSource(
            RetryingSource(HttpShardSource(origin.url), base_delay_s=0.01), ps
        )
        # the victim must actually own keys in this epoch, or the crash is
        # invisible to the consumer: kill the primary owner of most shards
        owner_counts: dict[str, int] = {}
        for n in names:
            o = ps._ring.owners(n, 1)[0]
            owner_counts[o] = owner_counts.get(o, 0) + 1
        victim_url = max(owner_counts, key=owner_counts.get)
        victim = next(s for s in servers if s.url == victim_url)
        survivors = [s for s in servers if s is not victim]
        kill_at = len(epoch) // 3
        # a full pass of the keyspace between crash and sweep: the dead
        # peer is guaranteed to be routed to while still in the ring, so
        # the breaker (not luck) covers the registry-lag window
        sweep_at = kill_at + len(names)
        rejoin_at = 2 * len(epoch) // 3
        remap_fractions: list[float] = []
        mismatches = 0
        replacement = None
        kill_thread = None
        try:
            t0 = time.monotonic()
            for i, name in enumerate(epoch):
                if churn and i == kill_at:
                    # crash, not graceful leave — and the victim's shutdown
                    # runs off-thread (a crashing rank does not block its
                    # consumers' read loops)
                    kill_thread = threading.Thread(target=victim.kill)
                    kill_thread.start()
                if churn and i == sweep_at:
                    # dead_after_s elapsed: the registry view drops the peer
                    before = _owner_snapshot(ps._ring)
                    ps.sync_membership([s.url for s in survivors])
                    remap_fractions.append(
                        _remap_fraction(before, _owner_snapshot(ps._ring))
                    )
                if churn and i == rejoin_at:
                    replacement, rt = _serve(shards_dir)
                    threads.append(rt)
                    before = _owner_snapshot(ps._ring)
                    ps.sync_membership(
                        [s.url for s in survivors] + [replacement.url]
                    )
                    remap_fractions.append(
                        _remap_fraction(before, _owner_snapshot(ps._ring))
                    )
                if tiered.fetch(name) != raw[name]:
                    mismatches += 1
            wall = time.monotonic() - t0
            st = tiered.stats()
            return {
                "wall_s": wall,
                "fetches": len(epoch),
                "items_per_sec": len(epoch) / wall,
                "mismatches": mismatches,
                "remap_fractions": remap_fractions,
                "membership_changes": ps.stats()["membership_changes"],
                "ring_remaps": st["ring_remaps"],
                "peer_hits": st["peer_hits"],
                "peer_errors": st["peer_errors"],
                "origin_fetches": st["origin_fetches"],
            }
        finally:
            tiered.close()
            if kill_thread is not None:
                kill_thread.join(timeout=10)
            for s in survivors + ([origin] + ([replacement] if replacement else [])):
                s.shutdown()
                s.server_close()
            if not churn:
                victim.shutdown()
                victim.server_close()
            for t in threads:
                t.join(timeout=5)

    clean = run_epoch(churn=False)
    churned = run_epoch(churn=True)
    return {
        "clean": clean,
        "churn": churned,
        "churn_ratio": churned["items_per_sec"] / clean["items_per_sec"],
        "max_remap_fraction": max(churned["remap_fractions"], default=0.0),
    }


# -- scenario 2: warm restart ----------------------------------------------
def _warm_restart(shards_dir: pathlib.Path, names: list[str]) -> dict:
    """Epoch, crash, restart over the same cache: the second epoch must be
    served from persisted state, not the wire."""
    from repro.data import ShardPrefetcher
    from repro.data.shards.sources import HttpShardSource, RetryingSource

    origin, thread = _serve(shards_dir)
    epoch_bytes = sum((shards_dir / n).stat().st_size for n in names)
    try:
        with tempfile.TemporaryDirectory() as cache:
            pf1 = ShardPrefetcher(
                RetryingSource(HttpShardSource(origin.url), base_delay_s=0.01),
                cache,
                index_first=False,
                persist_state=True,
            )
            t0 = time.monotonic()
            for n in names:
                pf1.reader(n)
            cold_s = time.monotonic() - t0
            pf1.close()  # the "crash" (state persisted on the way down)
            wire_before = origin.bytes_served

            pf2 = ShardPrefetcher(
                RetryingSource(HttpShardSource(origin.url), base_delay_s=0.01),
                cache,
                index_first=False,
                persist_state=True,
            )
            t0 = time.monotonic()
            mismatches = 0
            for n in names:
                r = pf2.reader(n)
                if bytes(r.raw(0, r.nbytes)) != (shards_dir / n).read_bytes():
                    mismatches += 1
            warm_s = time.monotonic() - t0
            reused = pf2.warm_restart_bytes_reused
            refetched = origin.bytes_served - wire_before
            pf2.close()
    finally:
        origin.shutdown()
        origin.server_close()
        thread.join(timeout=5)
    return {
        "epoch_bytes": epoch_bytes,
        "bytes_reused": reused,
        "reuse_fraction": reused / epoch_bytes,
        "bytes_refetched": refetched,
        "mismatches": mismatches,
        "cold_epoch_s": cold_s,
        "warm_epoch_s": warm_s,
        "speedup": cold_s / max(warm_s, 1e-9),
    }


# -- scenario 3: admission --------------------------------------------------
def _admission(shards_dir: pathlib.Path, names: list[str], *, run_s: float) -> dict:
    """A quota'd tenant converges on its byte rate; an unmetered tenant
    keeps its solo throughput next to the throttled one."""
    from repro.data import AdmissionController
    from repro.data.shards.membership import TENANT_HEADER
    from repro.data.shards.sources import HttpShardSource, RetryingSource

    shard_size = (shards_dir / names[0]).stat().st_size
    quota_bps = 4.0 * shard_size  # ~4 shards/s sustained
    # two bodies of burst: one is the free opener, the second is headroom
    # so refill credit earned during round-trips is banked, not clipped at
    # the cap (a one-body burst would silently tax every cycle by its RTT)
    burst = 2.0 * shard_size

    def polite_run(origin_url: str, duration: float) -> float:
        # paced, in-quota consumer (~65 req/s): running it flat-out would
        # saturate the fixture server and measure ITS queueing, not the
        # admission layer's isolation
        src = HttpShardSource(origin_url, headers={TENANT_HEADER: "polite"})
        fetched = 0
        i = 0
        t0 = time.monotonic()
        while time.monotonic() - t0 < duration:
            fetched += len(src.fetch(names[i % len(names)]))
            i += 1
            time.sleep(0.015)
        src.close()
        return fetched / (time.monotonic() - t0)

    # solo baseline: the polite tenant alone on the admission-gated origin
    adm = AdmissionController(max_inflight=16)
    adm.set_quota("capped", quota_bps, burst)
    origin, thread = _serve(shards_dir, admission=adm)
    try:
        solo_bps = polite_run(origin.url, run_s * 0.5)

        # contended: the capped tenant hammers while the polite one reads
        capped = {"bytes": 0, "admits": []}

        def capped_loop():
            src = RetryingSource(
                HttpShardSource(origin.url, headers={TENANT_HEADER: "capped"}),
                max_retries=8,
                base_delay_s=0.005,
                jitter=0.0,
            )
            t0 = time.monotonic()
            i = 0
            while time.monotonic() - t0 < run_s:
                try:
                    capped["bytes"] += len(src.fetch(names[i % len(names)]))
                    capped["admits"].append(time.monotonic())
                except OSError:
                    pass  # budget exhausted mid-window: keep hammering
                i += 1
            src.close()

        t = threading.Thread(target=capped_loop)
        t.start()
        contended_bps = polite_run(origin.url, run_s)
        t.join()
    finally:
        origin.shutdown()
        origin.server_close()
        thread.join(timeout=5)

    # Steady-state rate, admit-to-admit: the window opens at the LAST free
    # (burst) admit — the bucket is empty right after it, so every later
    # admit is quota-paced — and measuring between admits removes the
    # window-edge quantization a wall-clock window would add (+-1 body
    # over a short run is +-10% by itself).
    admits = capped["admits"]
    first = int(burst // shard_size) - 1  # index of the last burst admit
    steady = admits[first:]
    if len(steady) >= 2:
        achieved_bps = (len(steady) - 1) * shard_size / (steady[-1] - steady[0])
    else:
        achieved_bps = 0.0
    st = adm.stats()
    return {
        "quota_bps": quota_bps,
        "burst_bytes": burst,
        "capped_admits": len(admits),
        "capped_bytes": capped["bytes"],
        "capped_achieved_bps": achieved_bps,
        "capped_quota_error": achieved_bps / quota_bps - 1.0,
        "polite_solo_bps": solo_bps,
        "polite_contended_bps": contended_bps,
        "neighbor_ratio": contended_bps / solo_bps,
        "quota_rejections": st["quota_rejections"],
        "inflight_rejections": st["inflight_rejections"],
    }


# -- harness ---------------------------------------------------------------
def _scenarios(*, smoke: bool) -> dict:
    with tempfile.TemporaryDirectory() as d:
        shards_dir, names = _make_shards(
            pathlib.Path(d), n_items=192 if smoke else 512
        )
        churn = _churn(shards_dir, names, rounds=20 if smoke else 12)
        warm = _warm_restart(shards_dir, names)
        admission = _admission(shards_dir, names, run_s=2.5 if smoke else 5.0)
    return {
        "n_peers": N_PEERS,
        "churn": churn,
        "warm_restart": warm,
        "admission": admission,
        "gate_churn_ratio": GATE_CHURN_RATIO,
        "gate_remap_max": GATE_REMAP_MAX,
        "gate_warm_reuse": GATE_WARM_REUSE,
        "gate_quota_tol": GATE_QUOTA_TOL,
        "gate_neighbor_ratio": GATE_NEIGHBOR_RATIO,
    }


def _check(result: dict) -> list[str]:
    """The fleet gates; returns a list of violations (empty = pass)."""
    bad = []
    ch = result["churn"]
    if ch["churn"]["mismatches"] or ch["clean"]["mismatches"]:
        bad.append(f"churn corruption: {ch}")
    if ch["churn_ratio"] < result["gate_churn_ratio"]:
        bad.append(
            f"churn epoch sustained x{ch['churn_ratio']:.2f} of clean "
            f"throughput < gate x{result['gate_churn_ratio']:.2f}"
        )
    if not ch["churn"]["remap_fractions"]:
        bad.append("churn never changed membership — scenario inert")
    if ch["max_remap_fraction"] > result["gate_remap_max"]:
        bad.append(
            f"membership change remapped {ch['max_remap_fraction']:.2f} of "
            f"the keyspace > gate {result['gate_remap_max']:.2f} (2/N)"
        )
    if ch["churn"]["peer_errors"] < 1:
        bad.append("churn: killed peer never tripped the breaker")
    wm = result["warm_restart"]
    if wm["mismatches"]:
        bad.append(f"warm restart corruption: {wm}")
    if wm["reuse_fraction"] < result["gate_warm_reuse"]:
        bad.append(
            f"warm restart reused {wm['reuse_fraction']:.2f} of epoch bytes "
            f"< gate {result['gate_warm_reuse']:.2f}"
        )
    if wm["bytes_refetched"] > 0:
        bad.append(
            f"warm restart re-fetched {wm['bytes_refetched']} resident bytes "
            f"(must be 0)"
        )
    ad = result["admission"]
    if abs(ad["capped_quota_error"]) > result["gate_quota_tol"]:
        bad.append(
            f"capped tenant landed {ad['capped_quota_error']:+.1%} off its "
            f"quota (gate +-{result['gate_quota_tol']:.0%})"
        )
    if ad["neighbor_ratio"] < result["gate_neighbor_ratio"]:
        bad.append(
            f"polite tenant kept x{ad['neighbor_ratio']:.2f} of solo "
            f"throughput < gate x{result['gate_neighbor_ratio']:.2f}"
        )
    if ad["quota_rejections"] < 1:
        bad.append("admission never rejected — quota scenario inert")
    return bad


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    result = _scenarios(smoke=smoke)
    violations = _check(result)
    result["violations"] = violations
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    ch, wm, ad = result["churn"], result["warm_restart"], result["admission"]
    rows = [
        (
            "fleet_churn",
            ch["churn"]["wall_s"] * 1e6 / ch["churn"]["fetches"],
            f"x{ch['churn_ratio']:.2f}_of_clean_"
            f"remap{ch['max_remap_fraction']:.2f}_"
            f"{'OK' if ch['churn_ratio'] >= GATE_CHURN_RATIO else 'BELOW_GATE'}",
        ),
        (
            "fleet_warm_restart",
            wm["warm_epoch_s"] * 1e6,
            f"{wm['reuse_fraction']:.0%}reused_{wm['bytes_refetched']}refetched_"
            f"x{wm['speedup']:.1f}_vs_cold",
        ),
        (
            "fleet_admission",
            1e6 / max(ad["capped_achieved_bps"], 1e-9),
            f"{ad['capped_quota_error']:+.1%}_off_quota_"
            f"neighbor_x{ad['neighbor_ratio']:.2f}",
        ),
    ]
    if violations:
        raise RuntimeError("fleet gates violated: " + "; ".join(violations))
    return rows


def check_gate() -> int:
    """CI regression tripwire: re-run every fleet scenario at smoke size
    and fail on any gate violation."""
    result = _scenarios(smoke=True)
    ch, wm, ad = result["churn"], result["warm_restart"], result["admission"]
    print(
        f"churn: x{ch['churn_ratio']:.2f} of clean "
        f"(gate >= x{GATE_CHURN_RATIO:.2f}), max remap "
        f"{ch['max_remap_fraction']:.2f} (gate <= {GATE_REMAP_MAX:.2f})"
    )
    print(
        f"warm_restart: {wm['reuse_fraction']:.0%} reused "
        f"(gate >= {GATE_WARM_REUSE:.0%}), "
        f"{wm['bytes_refetched']} bytes refetched (gate == 0)"
    )
    print(
        f"admission: capped {ad['capped_quota_error']:+.1%} off quota "
        f"(gate +-{GATE_QUOTA_TOL:.0%}), neighbor x{ad['neighbor_ratio']:.2f} "
        f"(gate >= x{GATE_NEIGHBOR_RATIO:.2f}), "
        f"{ad['quota_rejections']} quota rejections"
    )
    violations = _check(result)
    for v in violations:
        print(f"REGRESSION: {v}")
    return 1 if violations else 0


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

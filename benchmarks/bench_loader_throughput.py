"""Paper Fig 5: loader throughput without downstream load —
SPDL pipeline vs multiprocessing loader vs Decord-like eager loader."""

from __future__ import annotations

import tempfile
import time

from repro.data import SyntheticImageDataset, build_image_loader
from repro.data.baselines import DecordLikeLoader, MPLoader

N, HW, BS = 96, (96, 96), 8


def run() -> list[tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ds = SyntheticImageDataset.materialize(d, N, hw=HW, seed=0)
        n_batches = N // BS

        for conc in (1, 4):
            pipe = build_image_loader(
                ds, batch_size=BS, hw=(64, 64),
                read_concurrency=conc, decode_concurrency=conc, num_threads=max(4, conc),
            )
            with pipe.auto_stop():
                t0 = time.monotonic()
                cnt = sum(1 for _ in pipe)
                dt = time.monotonic() - t0
            fps = cnt * BS / dt
            rows.append((f"fig5_spdl_c{conc}", 1e6 / fps, f"{fps:.0f}fps;{cnt}batches"))

        for workers in (1, 2):
            mp_loader = MPLoader(ds, batch_size=BS, hw=(64, 64), num_workers=workers)
            t0 = time.monotonic()
            cnt = sum(1 for _ in mp_loader)
            dt = time.monotonic() - t0
            fps = cnt * BS / dt
            rows.append((f"fig5_mploader_w{workers}", 1e6 / fps, f"{fps:.0f}fps"))

        dl = DecordLikeLoader(ds, batch_size=BS, hw=(64, 64))
        t0 = time.monotonic()
        cnt = sum(1 for _ in dl)
        dt = time.monotonic() - t0
        fps = cnt * BS / dt
        rows.append(
            ("fig5_decordlike", 1e6 / fps, f"{fps:.0f}fps;init={dl.init_s:.2f}s_eager")
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Paper Table 2: time until the FIRST batch is available.

Process loaders pay interpreter spawn + dataset pickling per worker (the
paper measured 58-277 s on ImageNet); the thread-based pipeline starts in
milliseconds because nothing is copied anywhere.
"""

from __future__ import annotations

import tempfile
import time

from repro.data import SyntheticImageDataset, build_image_loader
from repro.data.baselines import MPLoader

N, HW, BS = 32, (96, 96), 8


def run() -> list[tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ds = SyntheticImageDataset.materialize(d, N, hw=HW, seed=0)

        for conc in (1, 4):
            pipe = build_image_loader(
                ds, batch_size=BS, hw=(64, 64), read_concurrency=conc,
                decode_concurrency=conc, num_threads=max(4, conc),
            )
            t0 = time.monotonic()
            with pipe.auto_stop():
                pipe.get_item()
                dt = time.monotonic() - t0
            rows.append((f"table2_spdl_first_batch_c{conc}", dt * 1e6, f"{dt * 1e3:.1f}ms"))

        for workers in (1, 2, 4):
            loader = MPLoader(ds, batch_size=BS, hw=(64, 64), num_workers=workers)
            t0 = time.monotonic()
            it = iter(loader)
            next(it)
            dt = time.monotonic() - t0
            for _ in it:  # drain so workers exit cleanly
                pass
            rows.append((f"table2_mploader_first_batch_w{workers}", dt * 1e6, f"{dt * 1e3:.1f}ms"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

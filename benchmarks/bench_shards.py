"""Sharded record store: per-file vs packed-shard read throughput, the
remote path cold (empty local cache, simulated object-store latency) vs
warm (every shard cache-resident), and the **real HTTP backend** — cold
whole-shard fetch vs index-first ranged fetch vs warm cache — against a
local ``http.server`` fixture.

Measured on ``read_bytes`` only — storage is the variable here, decode is
bench_zero_copy's job:

- ``per_file``: the seed ``ArrayDataset`` path, one ``open()+read()+close``
  per sample;
- ``shard_mmap``: ``ShardDataset`` over packed shards, one mmap slice (+
  crc pass) per sample — also reported with crc verification off;
- ``remote_cold`` / ``remote_warm``: ``ShardDataset`` fronted by a
  ``ShardPrefetcher`` over a ``SimulatedLatencySource`` — first epoch pays
  the fetches, second epoch is all cache hits;
- ``http_whole`` / ``http_index_first`` / ``http_warm``: real
  ``HttpShardSource`` (range reads, keep-alive) through ``RetryingSource``
  — a sampler window touching only a quarter of each shard's samples, so
  index-first fetch (header + index + just the hinted ranges) must move
  strictly fewer wire bytes than committing to whole shards; the warm pass
  re-reads the cache and should land within ~10% of plain local shard
  reads.

Results persist to ``BENCH_shards.json`` at the repo root; gates:
``speedup_cold >= 2`` (packed shards at least 2x per-file items/s cold),
``http_index_first_bytes < http_whole_bytes`` (strict), and
``http_warm_vs_local`` ≈ 1 (±10%).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.data import (
    HttpShardSource,
    LocalShardSource,
    RetryingSource,
    ShardDataset,
    ShardPrefetcher,
    SimulatedLatencySource,
    SyntheticImageDataset,
    pack,
)
from repro.data.shards.testing import serve_shards

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_shards.json"

N_ITEMS = 2048
HW = (64, 64)
SAMPLES_PER_SHARD = 256
REMOTE_LATENCY_S = 0.005


def _read_throughput(ds, order: np.ndarray) -> dict:
    t0 = time.monotonic()
    n_bytes = 0
    for i in order:
        n_bytes += len(ds.read_bytes(int(i)))
    dt = time.monotonic() - t0
    return {
        "items_per_sec": len(order) / dt,
        "mb_per_sec": n_bytes / dt / 2**20,
        "items": len(order),
    }


def _http_section(shards_dir: pathlib.Path, cache_root: pathlib.Path) -> dict:
    """Real HTTP backend: whole-shard vs index-first wire bytes for a
    sampler window touching the first quarter of each shard, plus the warm
    pass vs plain local shard reads."""
    local_ds = ShardDataset(shards_dir)
    # the "sampler window": first quarter of every shard (subset reads are
    # where index-first fetch earns its keep)
    subset: list[int] = []
    hints: list[tuple[str, list[int]]] = []
    start = 0
    for name, size in zip(local_ds.shard_names, local_ds.shard_sizes):
        quarter = max(1, size // 4)
        subset.extend(range(start, start + quarter))
        hints.append((name, list(range(quarter))))
        start += size
    order = np.array(subset)

    results: dict = {}
    with serve_shards(shards_dir) as srv:
        # schedule bursts cover every shard at once here (the loaders'
        # lookahead would spread them out), so size the fetch pool to match
        inflight = max(2, local_ds.num_shards)
        # -- cold, whole-shard fetch (no ranged reads used) ------------------
        src_whole = RetryingSource(HttpShardSource(srv.url))
        pf_whole = ShardPrefetcher(
            src_whole,
            cache_root / "whole",
            max_bytes=1 << 32,
            index_first=False,
            max_inflight=inflight,
        )
        ds_whole = ShardDataset(shards_dir, prefetcher=pf_whole)
        for name, _ in hints:
            pf_whole.schedule(name)
        cold_whole = _read_throughput(ds_whole, order)
        whole_stats = pf_whole.stats()

        # -- warm: every touched shard cache-resident ------------------------
        # (the cold pass above warmed the cache's pages; give the local
        # baseline the same first-touch warm-up, then interleave best-of-3
        # so the warm-vs-local ratio survives this-box scheduling noise —
        # the comparison is mmap-vs-mmap, not page-cache-vs-page-faults)
        _read_throughput(local_ds, order)
        warm, local = None, None
        for _ in range(3):
            w = _read_throughput(ds_whole, order)
            l = _read_throughput(local_ds, order)
            if warm is None or w["items_per_sec"] > warm["items_per_sec"]:
                warm = w
            if local is None or l["items_per_sec"] > local["items_per_sec"]:
                local = l
        ds_whole.close()

        # -- cold, index-first fetch (header + index + hinted ranges) --------
        src_idx = RetryingSource(HttpShardSource(srv.url))
        pf_idx = ShardPrefetcher(
            src_idx,
            cache_root / "idx",
            max_bytes=1 << 32,
            index_first=True,
            max_inflight=inflight,
        )
        ds_idx = ShardDataset(shards_dir, prefetcher=pf_idx)
        for name, locals_ in hints:
            pf_idx.schedule(name, samples=locals_)
        cold_idx = _read_throughput(ds_idx, order)
        idx_stats = pf_idx.stats()
        ds_idx.close()

        results = {
            "http_whole": {**cold_whole, "bytes_fetched": whole_stats["bytes_fetched"]},
            "http_index_first": {
                **cold_idx,
                "bytes_fetched": idx_stats["bytes_fetched"],
                "index_fetches": idx_stats["index_fetches"],
                "range_fetches": idx_stats["range_fetches"],
                "sparse_shards": idx_stats["sparse_shards"],
            },
            "http_warm": warm,
            "local_subset": local,
            "http_index_first_saves_bytes": bool(
                idx_stats["bytes_fetched"] < whole_stats["bytes_fetched"]
            ),
            "http_bytes_ratio": idx_stats["bytes_fetched"]
            / max(whole_stats["bytes_fetched"], 1),
            "http_warm_vs_local": warm["items_per_sec"]
            / max(local["items_per_sec"], 1e-9),
            "server_requests": srv.requests,
            "server_bytes": srv.bytes_served,
        }
    local_ds.close()
    shutil.rmtree(cache_root, ignore_errors=True)
    return results


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    n = 256 if smoke else N_ITEMS
    per_shard = 64 if smoke else SAMPLES_PER_SHARD
    latency = 0.002 if smoke else REMOTE_LATENCY_S
    rng = np.random.default_rng(0)
    order = rng.permutation(n)

    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        files_ds = SyntheticImageDataset.materialize(d / "files", n, hw=HW, seed=0)
        pack(files_ds, d / "shards", samples_per_shard=per_shard)

        per_file = _read_throughput(files_ds, order)

        shard_ds = ShardDataset(d / "shards")  # fresh mapping: cold mmap
        shard = _read_throughput(shard_ds, order)
        shard_ds.close()
        shard_ds = ShardDataset(d / "shards", verify_crc=False)
        shard_nocrc = _read_throughput(shard_ds, order)
        shard_ds.close()

        src = SimulatedLatencySource(
            LocalShardSource(d / "shards"), latency_s=latency
        )
        pf = ShardPrefetcher(src, d / "cache", max_bytes=1 << 32, max_inflight=2)
        remote_ds = ShardDataset(d / "shards", prefetcher=pf)
        # shard-local visit order: remote reads are shard-sequential in
        # practice (the shard-aware sampler exists to make them so)
        remote_cold = _read_throughput(remote_ds, np.arange(n))
        cold_stats = pf.stats()
        remote_warm = _read_throughput(remote_ds, np.arange(n))
        warm_stats = pf.stats()
        remote_ds.close()
        shutil.rmtree(d / "cache", ignore_errors=True)

        http = _http_section(d / "shards", d / "http_caches")

    speedup_cold = shard["items_per_sec"] / max(per_file["items_per_sec"], 1e-9)
    warm_speedup = remote_warm["items_per_sec"] / max(
        remote_cold["items_per_sec"], 1e-9
    )
    result = {
        "workload": {
            "n_items": n,
            "hw": HW,
            "samples_per_shard": per_shard,
            "remote_latency_s": latency,
        },
        "per_file": per_file,
        "shard_mmap": shard,
        "shard_mmap_nocrc": shard_nocrc,
        "remote_cold": {**remote_cold, "cache": cold_stats},
        "remote_warm": {
            **remote_warm,
            "cache": {
                k: warm_stats[k] - cold_stats[k] if k in ("hits", "misses") else warm_stats[k]
                for k in warm_stats
            },
        },
        "speedup_cold": speedup_cold,
        "remote_warm_over_cold": warm_speedup,
        **http,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for tag, r in (
        ("per_file", per_file),
        ("shard_mmap", shard),
        ("shard_mmap_nocrc", shard_nocrc),
        ("remote_cold", remote_cold),
        ("remote_warm", remote_warm),
        ("http_whole", http["http_whole"]),
        ("http_index_first", http["http_index_first"]),
        ("http_warm", http["http_warm"]),
    ):
        rows.append(
            (
                f"shards_{tag}",
                1e6 / max(r["items_per_sec"], 1e-9),
                f"{r['items_per_sec']:.0f}items/s_{r['mb_per_sec']:.0f}MB/s",
            )
        )
    rows.append(("shards_speedup_cold", 0.0, f"x{speedup_cold:.2f}_shard_vs_per_file"))
    rows.append(
        ("shards_warm_cache", 0.0, f"x{warm_speedup:.2f}_warm_vs_cold_remote")
    )
    rows.append(
        (
            "shards_http_index_first_bytes",
            0.0,
            f"x{http['http_bytes_ratio']:.2f}_of_whole_shard_wire_bytes"
            f"_{'SAVES' if http['http_index_first_saves_bytes'] else 'NO_SAVING'}",
        )
    )
    rows.append(
        (
            "shards_http_warm_vs_local",
            0.0,
            f"x{http['http_warm_vs_local']:.2f}_warm_cache_vs_local_mmap",
        )
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Sharded record store: per-file vs packed-shard read throughput, and the
remote path cold (empty local cache, simulated object-store latency) vs
warm (every shard cache-resident).

Measured on ``read_bytes`` only — storage is the variable here, decode is
bench_zero_copy's job:

- ``per_file``: the seed ``ArrayDataset`` path, one ``open()+read()+close``
  per sample;
- ``shard_mmap``: ``ShardDataset`` over packed shards, one mmap slice (+
  crc pass) per sample — also reported with crc verification off;
- ``remote_cold`` / ``remote_warm``: ``ShardDataset`` fronted by a
  ``ShardPrefetcher`` over a ``SimulatedLatencySource`` — first epoch pays
  the fetches, second epoch is all cache hits.

Results persist to ``BENCH_shards.json`` at the repo root; the acceptance
gate is ``speedup_cold >= 2`` (packed shards at least 2x the per-file
items/s on the cold pass).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.data import (
    LocalShardSource,
    ShardDataset,
    ShardPrefetcher,
    SimulatedLatencySource,
    SyntheticImageDataset,
    pack,
)

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_shards.json"

N_ITEMS = 2048
HW = (64, 64)
SAMPLES_PER_SHARD = 256
REMOTE_LATENCY_S = 0.005


def _read_throughput(ds, order: np.ndarray) -> dict:
    t0 = time.monotonic()
    n_bytes = 0
    for i in order:
        n_bytes += len(ds.read_bytes(int(i)))
    dt = time.monotonic() - t0
    return {
        "items_per_sec": len(order) / dt,
        "mb_per_sec": n_bytes / dt / 2**20,
        "items": len(order),
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    n = 256 if smoke else N_ITEMS
    per_shard = 64 if smoke else SAMPLES_PER_SHARD
    latency = 0.002 if smoke else REMOTE_LATENCY_S
    rng = np.random.default_rng(0)
    order = rng.permutation(n)

    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        files_ds = SyntheticImageDataset.materialize(d / "files", n, hw=HW, seed=0)
        pack(files_ds, d / "shards", samples_per_shard=per_shard)

        per_file = _read_throughput(files_ds, order)

        shard_ds = ShardDataset(d / "shards")  # fresh mapping: cold mmap
        shard = _read_throughput(shard_ds, order)
        shard_ds.close()
        shard_ds = ShardDataset(d / "shards", verify_crc=False)
        shard_nocrc = _read_throughput(shard_ds, order)
        shard_ds.close()

        src = SimulatedLatencySource(
            LocalShardSource(d / "shards"), latency_s=latency
        )
        pf = ShardPrefetcher(src, d / "cache", max_bytes=1 << 32, max_inflight=2)
        remote_ds = ShardDataset(d / "shards", prefetcher=pf)
        # shard-local visit order: remote reads are shard-sequential in
        # practice (the shard-aware sampler exists to make them so)
        remote_cold = _read_throughput(remote_ds, np.arange(n))
        cold_stats = pf.stats()
        remote_warm = _read_throughput(remote_ds, np.arange(n))
        warm_stats = pf.stats()
        remote_ds.close()
        shutil.rmtree(d / "cache", ignore_errors=True)

    speedup_cold = shard["items_per_sec"] / max(per_file["items_per_sec"], 1e-9)
    warm_speedup = remote_warm["items_per_sec"] / max(
        remote_cold["items_per_sec"], 1e-9
    )
    result = {
        "workload": {
            "n_items": n,
            "hw": HW,
            "samples_per_shard": per_shard,
            "remote_latency_s": latency,
        },
        "per_file": per_file,
        "shard_mmap": shard,
        "shard_mmap_nocrc": shard_nocrc,
        "remote_cold": {**remote_cold, "cache": cold_stats},
        "remote_warm": {
            **remote_warm,
            "cache": {
                k: warm_stats[k] - cold_stats[k] if k in ("hits", "misses") else warm_stats[k]
                for k in warm_stats
            },
        },
        "speedup_cold": speedup_cold,
        "remote_warm_over_cold": warm_speedup,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for tag, r in (
        ("per_file", per_file),
        ("shard_mmap", shard),
        ("shard_mmap_nocrc", shard_nocrc),
        ("remote_cold", remote_cold),
        ("remote_warm", remote_warm),
    ):
        rows.append(
            (
                f"shards_{tag}",
                1e6 / max(r["items_per_sec"], 1e-9),
                f"{r['items_per_sec']:.0f}items/s_{r['mb_per_sec']:.0f}MB/s",
            )
        )
    rows.append(("shards_speedup_cold", 0.0, f"x{speedup_cold:.2f}_shard_vs_per_file"))
    rows.append(
        ("shards_warm_cache", 0.0, f"x{warm_speedup:.2f}_warm_vs_cold_remote")
    )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))

"""Sharded record store: per-file vs packed-shard read throughput, the
remote path cold (empty local cache, simulated object-store latency) vs
warm (every shard cache-resident), and the **real HTTP backend** — cold
whole-shard fetch vs index-first ranged fetch vs warm cache — against a
local ``http.server`` fixture.

Measured on ``read_bytes`` only — storage is the variable here, decode is
bench_zero_copy's job:

- ``per_file``: the seed ``ArrayDataset`` path, one ``open()+read()+close``
  per sample;
- ``shard_mmap``: ``ShardDataset`` over packed shards, one mmap slice (+
  crc pass) per sample — also reported with crc verification off;
- ``remote_cold`` / ``remote_warm``: ``ShardDataset`` fronted by a
  ``ShardPrefetcher`` over a ``SimulatedLatencySource`` — first epoch pays
  the fetches, second epoch is all cache hits;
- ``http_whole`` / ``http_index_first`` / ``http_warm``: real
  ``HttpShardSource`` (range reads, keep-alive) through ``RetryingSource``
  — a sampler window touching only a quarter of each shard's samples, so
  index-first fetch (header + index + just the hinted ranges) must move
  strictly fewer wire bytes than committing to whole shards; the warm pass
  re-reads the cache and should land within ~10% of plain local shard
  reads;
- ``origin_cold`` / ``peer_warm``: the peer exchange tier — rank A pays
  the origin cold, then serves its warm cache over a ``PeerShardServer``;
  rank B reads every shard through a ``TieredSource`` and must touch the
  origin ZERO times (asserted via the origin server's request counter);
- ``projection``: columnar (format v2) shards holding
  image + caption + metadata fields (image ≈ 40% of the payload), read
  image-only over HTTP two ways — full fetch (whole shards cross the
  wire) vs projection pushdown (``fields=("image",)`` rides the prefetch
  hints, so only the image column's ranges are fetched).  The wire-byte
  ratio must come in at or under ``gate_projection_wire_ratio`` (0.5).

``shard_mmap_epoch2`` re-reads the same warm mapping: per-sample crc
verification is memoized on first read, so epoch 2 is pure pointer math
(it should land at or above the ``verify_crc=False`` rate).

Results persist to ``BENCH_shards.json`` at the repo root; gates:
``speedup_cold >= 2`` (packed shards at least 2x per-file items/s cold),
``http_index_first_bytes < http_whole_bytes`` (strict),
``http_warm_vs_local`` ≈ 1 (±10%), ``peer_zero_origin`` (no origin
shard requests during rank B's peer-served pass), and
``projection_wire_ratio <= 0.5`` (image-only reads of a three-field
corpus move at most half the full-fetch wire bytes).

``python -m benchmarks.bench_shards --gate`` re-checks the projection
gate at smoke size and exits nonzero on regression (CI wires this in).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.data import (
    HttpShardSource,
    LocalShardSource,
    PeerShardServer,
    PeerShardSource,
    RetryingSource,
    ShardDataset,
    ShardPrefetcher,
    SimulatedLatencySource,
    SyntheticImageDataset,
    TieredSource,
    pack,
)
from repro.data.shards import ShardWriterV2, write_manifest
from repro.data.shards.testing import serve_shards

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_shards.json"

N_ITEMS = 2048
HW = (64, 64)
SAMPLES_PER_SHARD = 256
REMOTE_LATENCY_S = 0.005
# projection: image-only reads of an image+caption+metadata corpus must
# move at most this fraction of the full-fetch wire bytes
PROJECTION_GATE = 0.5
PROJECTION_FIELDS = {"image": 4000, "caption": 3000, "metadata": 3000}


def _read_throughput(ds, order: np.ndarray) -> dict:
    t0 = time.monotonic()
    n_bytes = 0
    for i in order:
        n_bytes += len(ds.read_bytes(int(i)))
    dt = time.monotonic() - t0
    return {
        "items_per_sec": len(order) / dt,
        "mb_per_sec": n_bytes / dt / 2**20,
        "items": len(order),
    }


def _http_section(shards_dir: pathlib.Path, cache_root: pathlib.Path) -> dict:
    """Real HTTP backend: whole-shard vs index-first wire bytes for a
    sampler window touching the first quarter of each shard, plus the warm
    pass vs plain local shard reads."""
    local_ds = ShardDataset(shards_dir)
    # the "sampler window": first quarter of every shard (subset reads are
    # where index-first fetch earns its keep)
    subset: list[int] = []
    hints: list[tuple[str, list[int]]] = []
    start = 0
    for name, size in zip(local_ds.shard_names, local_ds.shard_sizes):
        quarter = max(1, size // 4)
        subset.extend(range(start, start + quarter))
        hints.append((name, list(range(quarter))))
        start += size
    order = np.array(subset)

    results: dict = {}
    with serve_shards(shards_dir) as srv:
        # schedule bursts cover every shard at once here (the loaders'
        # lookahead would spread them out), so size the fetch pool to match
        inflight = max(2, local_ds.num_shards)
        # -- cold, whole-shard fetch (no ranged reads used) ------------------
        src_whole = RetryingSource(HttpShardSource(srv.url))
        pf_whole = ShardPrefetcher(
            src_whole,
            cache_root / "whole",
            max_bytes=1 << 32,
            index_first=False,
            max_inflight=inflight,
        )
        ds_whole = ShardDataset(shards_dir, prefetcher=pf_whole)
        for name, _ in hints:
            pf_whole.schedule(name)
        cold_whole = _read_throughput(ds_whole, order)
        whole_stats = pf_whole.stats()

        # -- warm: every touched shard cache-resident ------------------------
        # (the cold pass above warmed the cache's pages; give the local
        # baseline the same first-touch warm-up, then interleave best-of-3
        # so the warm-vs-local ratio survives this-box scheduling noise —
        # the comparison is mmap-vs-mmap, not page-cache-vs-page-faults)
        _read_throughput(local_ds, order)
        warm, local = None, None
        for _ in range(3):
            w = _read_throughput(ds_whole, order)
            l = _read_throughput(local_ds, order)
            if warm is None or w["items_per_sec"] > warm["items_per_sec"]:
                warm = w
            if local is None or l["items_per_sec"] > local["items_per_sec"]:
                local = l
        ds_whole.close()

        # -- cold, index-first fetch (header + index + hinted ranges) --------
        src_idx = RetryingSource(HttpShardSource(srv.url))
        pf_idx = ShardPrefetcher(
            src_idx,
            cache_root / "idx",
            max_bytes=1 << 32,
            index_first=True,
            max_inflight=inflight,
        )
        ds_idx = ShardDataset(shards_dir, prefetcher=pf_idx)
        for name, locals_ in hints:
            pf_idx.schedule(name, samples=locals_)
        cold_idx = _read_throughput(ds_idx, order)
        idx_stats = pf_idx.stats()
        ds_idx.close()

        results = {
            "http_whole": {**cold_whole, "bytes_fetched": whole_stats["bytes_fetched"]},
            "http_index_first": {
                **cold_idx,
                "bytes_fetched": idx_stats["bytes_fetched"],
                "index_fetches": idx_stats["index_fetches"],
                "range_fetches": idx_stats["range_fetches"],
                "sparse_shards": idx_stats["sparse_shards"],
            },
            "http_warm": warm,
            "local_subset": local,
            "http_index_first_saves_bytes": bool(
                idx_stats["bytes_fetched"] < whole_stats["bytes_fetched"]
            ),
            "http_bytes_ratio": idx_stats["bytes_fetched"]
            / max(whole_stats["bytes_fetched"], 1),
            "http_warm_vs_local": warm["items_per_sec"]
            / max(local["items_per_sec"], 1e-9),
            "server_requests": srv.requests,
            "server_bytes": srv.bytes_served,
        }
    local_ds.close()
    shutil.rmtree(cache_root, ignore_errors=True)
    return results


def _peer_section(shards_dir: pathlib.Path, cache_root: pathlib.Path) -> dict:
    """Peer exchange: rank A pulls every shard cold from the origin, then
    rank B reads the same data entirely from A's warm cache — zero origin
    requests — through the origin → retry → peers → prefetcher stack."""
    local_ds = ShardDataset(shards_dir)
    order = np.arange(len(local_ds))
    with serve_shards(shards_dir) as origin:
        inflight = max(2, local_ds.num_shards)
        pf_a = ShardPrefetcher(
            RetryingSource(HttpShardSource(origin.url)),
            cache_root / "rank_a",
            max_bytes=1 << 32,
            index_first=False,
            max_inflight=inflight,
        )
        ds_a = ShardDataset(shards_dir, prefetcher=pf_a)
        for name in ds_a.shard_names:
            pf_a.schedule(name)
        origin_cold = _read_throughput(ds_a, order)
        with PeerShardServer(pf_a) as peer:
            tiered = TieredSource(
                RetryingSource(HttpShardSource(origin.url)),
                PeerShardSource([peer.url]),
            )
            pf_b = ShardPrefetcher(
                tiered,
                cache_root / "rank_b",
                max_bytes=1 << 32,
                index_first=False,
                max_inflight=inflight,
            )
            ds_b = ShardDataset(shards_dir, prefetcher=pf_b)
            origin_requests_before = origin.requests
            for name in ds_b.shard_names:
                pf_b.schedule(name)
            peer_warm = _read_throughput(ds_b, order)
            origin_delta = origin.requests - origin_requests_before
            tstats = tiered.stats()
            results = {
                "origin_cold": origin_cold,
                "peer_warm": peer_warm,
                "peer_hits": tstats["peer_hits"],
                "peer_bytes": tstats["peer_bytes"],
                "origin_bytes": tstats["origin_bytes"],
                "peer_server": peer.stats(),
                "origin_requests_during_peer_pass": origin_delta,
                "peer_zero_origin": bool(origin_delta == 0),
                "peer_warm_over_origin_cold": peer_warm["items_per_sec"]
                / max(origin_cold["items_per_sec"], 1e-9),
            }
            ds_b.close()
        ds_a.close()
    local_ds.close()
    shutil.rmtree(cache_root, ignore_errors=True)
    return results


def _projection_corpus(root: pathlib.Path, n: int, per_shard: int) -> None:
    """Columnar v2 shards: image + caption + metadata per sample (image is
    40% of the payload — the fraction an image-only read should approach)."""
    rng = np.random.default_rng(1)
    root.mkdir(parents=True, exist_ok=True)
    shards: list[dict] = []
    done = 0
    while done < n:
        count = min(per_shard, n - done)
        name = f"shard-{len(shards):05d}.rpshard"
        with ShardWriterV2(root / name) as w:
            for _ in range(count):
                w.add(
                    {
                        f: rng.integers(0, 256, size, dtype=np.uint8).tobytes()
                        for f, size in PROJECTION_FIELDS.items()
                    }
                )
        shards.append(
            {"name": name, "n": count, "bytes": (root / name).stat().st_size}
        )
        done += count
    write_manifest(
        root,
        shards,
        {"format_version": 2, "fields": list(PROJECTION_FIELDS)},
    )


def _field_throughput(ds, order: np.ndarray, field: str = "image") -> dict:
    t0 = time.monotonic()
    n_bytes = 0
    for i in order:
        n_bytes += len(ds.read_fields(int(i), (field,))[field])
    dt = time.monotonic() - t0
    return {
        "items_per_sec": len(order) / dt,
        "mb_per_sec": n_bytes / dt / 2**20,
        "items": len(order),
    }


def _projection_section(*, smoke: bool = False) -> dict:
    """Image-only reads over HTTP: full fetch vs projection pushdown."""
    n = 64 if smoke else 512
    per_shard = 16 if smoke else 64
    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        root = d / "corpus"
        _projection_corpus(root, n, per_shard)
        meta = ShardDataset(root)
        shard_names, shard_sizes = meta.shard_names, meta.shard_sizes
        meta.close()
        order = np.arange(n)
        inflight = max(2, len(shard_names))
        with serve_shards(root) as srv:
            # -- full fetch: whole shards cross the wire, image read locally
            pf_full = ShardPrefetcher(
                RetryingSource(HttpShardSource(srv.url)),
                d / "cache_full",
                max_bytes=1 << 32,
                index_first=False,
                max_inflight=inflight,
            )
            ds_full = ShardDataset(root, prefetcher=pf_full)
            for name in shard_names:
                pf_full.schedule(name)
            full = _field_throughput(ds_full, order)
            full_wire = pf_full.stats()["bytes_fetched"]
            ds_full.close()

            # -- projection pushdown: only the image column's ranges fetched
            pf_proj = ShardPrefetcher(
                RetryingSource(HttpShardSource(srv.url)),
                d / "cache_proj",
                max_bytes=1 << 32,
                index_first=True,
                max_inflight=inflight,
            )
            ds_proj = ShardDataset(
                root, prefetcher=pf_proj, fields=("image",)
            )
            for name, size in zip(shard_names, shard_sizes):
                pf_proj.schedule(name, samples=list(range(size)), fields=("image",))
            projected = _field_throughput(ds_proj, order)
            proj_stats = pf_proj.stats()
            ds_proj.close()
    ratio = proj_stats["bytes_fetched"] / max(full_wire, 1)
    return {
        "full_fetch": {**full, "bytes_fetched": full_wire},
        "projected": {
            **projected,
            "bytes_fetched": proj_stats["bytes_fetched"],
            "bytes_skipped": proj_stats["bytes_skipped"],
            "fields_requested": proj_stats["fields_requested"],
            "sparse_shards": proj_stats["sparse_shards"],
        },
        "wire_ratio": ratio,
        "meets_gate": bool(ratio <= PROJECTION_GATE),
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    n = 256 if smoke else N_ITEMS
    per_shard = 64 if smoke else SAMPLES_PER_SHARD
    latency = 0.002 if smoke else REMOTE_LATENCY_S
    rng = np.random.default_rng(0)
    order = rng.permutation(n)

    with tempfile.TemporaryDirectory() as d:
        d = pathlib.Path(d)
        files_ds = SyntheticImageDataset.materialize(d / "files", n, hw=HW, seed=0)
        pack(files_ds, d / "shards", samples_per_shard=per_shard)

        per_file = _read_throughput(files_ds, order)

        shard_ds = ShardDataset(d / "shards")  # fresh mapping: cold mmap
        shard = _read_throughput(shard_ds, order)
        # epoch 2 over the same warm mapping: crc verification is memoized
        # per sample, so this pass pays no checksum work at all
        shard_epoch2 = _read_throughput(shard_ds, order)
        shard_ds.close()
        shard_ds = ShardDataset(d / "shards", verify_crc=False)
        shard_nocrc = _read_throughput(shard_ds, order)
        shard_ds.close()

        src = SimulatedLatencySource(
            LocalShardSource(d / "shards"), latency_s=latency
        )
        pf = ShardPrefetcher(src, d / "cache", max_bytes=1 << 32, max_inflight=2)
        remote_ds = ShardDataset(d / "shards", prefetcher=pf)
        # shard-local visit order: remote reads are shard-sequential in
        # practice (the shard-aware sampler exists to make them so)
        remote_cold = _read_throughput(remote_ds, np.arange(n))
        cold_stats = pf.stats()
        remote_warm = _read_throughput(remote_ds, np.arange(n))
        warm_stats = pf.stats()
        remote_ds.close()
        shutil.rmtree(d / "cache", ignore_errors=True)

        http = _http_section(d / "shards", d / "http_caches")
        peer = _peer_section(d / "shards", d / "peer_caches")
    projection = _projection_section(smoke=smoke)

    speedup_cold = shard["items_per_sec"] / max(per_file["items_per_sec"], 1e-9)
    warm_speedup = remote_warm["items_per_sec"] / max(
        remote_cold["items_per_sec"], 1e-9
    )
    result = {
        "workload": {
            "n_items": n,
            "hw": HW,
            "samples_per_shard": per_shard,
            "remote_latency_s": latency,
        },
        "per_file": per_file,
        "shard_mmap": shard,
        "shard_mmap_epoch2": shard_epoch2,
        "shard_mmap_nocrc": shard_nocrc,
        "remote_cold": {**remote_cold, "cache": cold_stats},
        "remote_warm": {
            **remote_warm,
            "cache": {
                k: warm_stats[k] - cold_stats[k] if k in ("hits", "misses") else warm_stats[k]
                for k in warm_stats
            },
        },
        "speedup_cold": speedup_cold,
        "remote_warm_over_cold": warm_speedup,
        **http,
        **peer,
        "projection": projection,
        "projection_wire_ratio": projection["wire_ratio"],
        "gate_projection_wire_ratio": PROJECTION_GATE,
    }
    if not smoke:  # persist only full runs; smoke numbers are noise
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    rows = []
    for tag, r in (
        ("per_file", per_file),
        ("shard_mmap", shard),
        ("shard_mmap_epoch2", shard_epoch2),
        ("shard_mmap_nocrc", shard_nocrc),
        ("remote_cold", remote_cold),
        ("remote_warm", remote_warm),
        ("http_whole", http["http_whole"]),
        ("http_index_first", http["http_index_first"]),
        ("http_warm", http["http_warm"]),
        ("origin_cold", peer["origin_cold"]),
        ("peer_warm", peer["peer_warm"]),
        ("projection_full_fetch", projection["full_fetch"]),
        ("projection_pushdown", projection["projected"]),
    ):
        rows.append(
            (
                f"shards_{tag}",
                1e6 / max(r["items_per_sec"], 1e-9),
                f"{r['items_per_sec']:.0f}items/s_{r['mb_per_sec']:.0f}MB/s",
            )
        )
    rows.append(("shards_speedup_cold", 0.0, f"x{speedup_cold:.2f}_shard_vs_per_file"))
    rows.append(
        ("shards_warm_cache", 0.0, f"x{warm_speedup:.2f}_warm_vs_cold_remote")
    )
    rows.append(
        (
            "shards_http_index_first_bytes",
            0.0,
            f"x{http['http_bytes_ratio']:.2f}_of_whole_shard_wire_bytes"
            f"_{'SAVES' if http['http_index_first_saves_bytes'] else 'NO_SAVING'}",
        )
    )
    rows.append(
        (
            "shards_http_warm_vs_local",
            0.0,
            f"x{http['http_warm_vs_local']:.2f}_warm_cache_vs_local_mmap",
        )
    )
    rows.append(
        (
            "shards_peer_exchange",
            0.0,
            f"x{peer['peer_warm_over_origin_cold']:.2f}_peer_warm_vs_origin_cold"
            f"_{'ZERO_ORIGIN' if peer['peer_zero_origin'] else 'ORIGIN_LEAK'}",
        )
    )
    rows.append(
        (
            "shards_projection_wire_bytes",
            0.0,
            f"x{projection['wire_ratio']:.2f}_of_full_fetch_wire_bytes"
            f"_{'MEETS_GATE' if projection['meets_gate'] else 'OVER_GATE'}",
        )
    )
    return rows


def check_gate() -> int:
    """CI regression tripwire: re-measure the projection workload at smoke
    size and fail if the wire-byte ratio rose above the recorded gate."""
    gate = PROJECTION_GATE
    if OUT_PATH.is_file():
        gate = float(
            json.loads(OUT_PATH.read_text()).get("gate_projection_wire_ratio", gate)
        )
    projection = _projection_section(smoke=True)
    ratio = projection["wire_ratio"]
    print(
        f"shards_projection gate: x{ratio:.2f} of full-fetch wire bytes, "
        f"gate x{gate:.2f}"
    )
    if ratio > gate:
        print(f"REGRESSION: projection wire ratio x{ratio:.2f} > gate x{gate:.2f}")
        return 1
    return 0


if __name__ == "__main__":
    if "--gate" in sys.argv:
        sys.exit(check_gate())
    for r in run("--smoke" in sys.argv):
        print(",".join(map(str, r)))

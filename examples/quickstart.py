"""Quickstart: build an SPDL pipeline from plain functions (paper Listing 1).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import asyncio
import time

import numpy as np

from repro.core import PipelineBuilder
from repro.data.codec import decode_sample, encode_sample, resize_nearest
from repro.data.transfer import DeviceTransfer


def source():
    """Yield 'URLs' (here: encoded in-memory samples)."""
    rng = np.random.default_rng(0)
    for i in range(64):
        yield encode_sample(rng.integers(0, 256, (128, 128, 3), dtype=np.uint8))


async def download(data: bytes) -> bytes:
    await asyncio.sleep(0.002)  # network latency (coroutine: never holds the GIL)
    return data


def decode(data: bytes) -> np.ndarray:
    return resize_nearest(decode_sample(data), (64, 64))  # zstd+numpy release the GIL


transfer = DeviceTransfer()


def batch_transfer(imgs: list[np.ndarray]):
    return transfer({"images": np.stack(imgs)})


pipeline = (
    PipelineBuilder()
    .add_source(source())
    .pipe(download, concurrency=8, name="download")
    .pipe(decode, concurrency=4, name="decode")
    .aggregate(16)
    .pipe(batch_transfer, concurrency=1, name="transfer")
    .add_sink(buffer_size=3)
    .build(num_threads=8)
)

if __name__ == "__main__":
    t0 = time.monotonic()
    with pipeline.auto_stop():
        for i, batch in enumerate(pipeline):
            print(f"batch {i}: images {batch['images'].shape} on {batch['images'].device}")
    print(f"done in {time.monotonic() - t0:.2f}s")
    print("\nper-stage visibility (paper §5.4):")
    print(pipeline.format_stats())

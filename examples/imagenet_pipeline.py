"""The paper's benchmark scenario end-to-end: 'ImageNet'-style directory →
SPDL pipeline (read → decode → batch → uint8 device transfer) with the
visibility dashboard, vs the multiprocessing baseline.

Run: PYTHONPATH=src python examples/imagenet_pipeline.py
"""

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticImageDataset, build_image_loader
from repro.data.baselines import MPLoader
from repro.kernels.ops import dequant_normalize

MEAN = jnp.array([0.485, 0.456, 0.406], jnp.float32)
STD = jnp.array([0.229, 0.224, 0.225], jnp.float32)


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        print("materializing synthetic imagenet ...")
        ds = SyntheticImageDataset.materialize(d, 96, hw=(128, 128), seed=0)

        pipe = build_image_loader(ds, batch_size=16, hw=(112, 112), decode_concurrency=4)
        t0 = time.monotonic()
        n_img = 0
        with pipe.auto_stop():
            for batch in pipe:
                # device-side last mile: uint8 → bf16 normalize (Pallas on TPU)
                x = dequant_normalize(batch["images"], MEAN, STD)
                n_img += x.shape[0]
        dt = time.monotonic() - t0
        print(f"SPDL: {n_img} images in {dt:.2f}s = {n_img / dt:.0f} img/s")
        print(pipe.format_stats())

        loader = MPLoader(ds, batch_size=16, hw=(112, 112), num_workers=2)
        t0 = time.monotonic()
        n_img = sum(b.shape[0] for b in loader)
        dt = time.monotonic() - t0
        print(f"\nMPLoader (PyTorch-style, 2 workers): {n_img / dt:.0f} img/s "
              f"(startup {loader.startup_s:.2f}s)")


if __name__ == "__main__":
    main()

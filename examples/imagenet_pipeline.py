"""The paper's benchmark scenario end-to-end, on the sharded record store:
'ImageNet'-style directory → ``pack`` into mmap shards → SPDL pipeline
(shard-aware sampler → mmap read → decode-into-slab → batch → uint8 device
transfer) with the visibility dashboard (including shard-cache counters),
vs the per-file path and the multiprocessing baseline — plus the **real
HTTP backend**: the same shards served over a loopback ``http.server``
with Range support, consumed via ``ShardDataset("http://...")`` (which
builds HTTP range reads → retry/backoff → prefetcher cache automatically).

Multi-field projection (columnar format v2): the last shard section packs
image + caption as named columns and trains image-only via
``build_image_loader(..., fields=("image",))`` — projection pushdown
means the caption column never crosses the wire, and the dashboard counts
the skipped bytes.

Flight-recorder walkthrough (the observability layer, ``core/trace.py``):
the remote-shards run below executes under ``tracing()`` with the tracer
passed to ``build_image_loader(trace=...)``, so every layer records spans —
per-chunk stage phases, queue waits, shard fetches and cache hits/misses,
the host→device transfer — one track per worker thread.  The capture is
exported as Chrome Trace JSON (load it at https://ui.perfetto.dev or
``chrome://tracing``) to ``$REPRO_TRACE_PATH`` (default
``imagenet_trace.json`` next to this file).  The recipe is three lines:

    with tracing() as tracer:
        pipe = build_image_loader(ds, ..., trace=tracer)
        ...consume...
    tracer.export("trace.json")

``tracing()`` also installs the tracer process-wide so subsystems built
outside the loader (prefetcher, peer tier, chaos) land on the same
timeline; for scrape-style monitoring instead of post-hoc traces, see
``core.metrics`` (``StatsHistory`` + ``MetricsExporter``'s ``/metrics``).

Run: PYTHONPATH=src python examples/imagenet_pipeline.py
"""

import os
import pathlib
import tempfile
import time

import jax.numpy as jnp

from repro.data import (
    CheckpointableSampler,
    LocalShardSource,
    ShardDataset,
    ShardPrefetcher,
    SimulatedLatencySource,
    SyntheticImageDataset,
    build_image_loader,
    pack,
)
from repro.core import tracing
from repro.data.baselines import MPLoader
from repro.kernels.ops import dequant_normalize

MEAN = jnp.array([0.485, 0.456, 0.406], jnp.float32)
STD = jnp.array([0.229, 0.224, 0.225], jnp.float32)


def consume(pipe) -> tuple[int, float]:
    t0 = time.monotonic()
    n_img = 0
    with pipe.auto_stop():
        for batch in pipe:
            # device-side last mile: uint8 → bf16 normalize (Pallas on TPU)
            x = dequant_normalize(batch["images"], MEAN, STD)
            n_img += x.shape[0]
    return n_img, time.monotonic() - t0


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        print("materializing synthetic imagenet ...")
        files_ds = SyntheticImageDataset.materialize(
            d + "/files", 96, hw=(128, 128), seed=0
        )

        # migrate the one-file-per-sample directory into packed shards
        shard_ds = pack(files_ds, d + "/shards", samples_per_shard=24)
        print(
            f"packed {len(shard_ds)} samples into {shard_ds.num_shards} shards "
            f"under {shard_ds.root}"
        )

        # shard-aware shuffle: shards shuffled, samples shuffled within a
        # sliding window — random enough for SGD, local enough to cache
        sampler = CheckpointableSampler(
            len(shard_ds),
            batch_size=1,
            seed=0,
            shard_sizes=shard_ds.shard_sizes,
            shard_window=48,
        )
        pipe = build_image_loader(
            shard_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
            sampler=sampler,
        )
        n_img, dt = consume(pipe)
        print(f"SPDL (local shards, mmap): {n_img} images in {dt:.2f}s "
              f"= {n_img / dt:.0f} img/s")
        print(pipe.format_stats())

        # chunked vs per-item engine: the loader above ran with its default
        # chunk=16 and read→decode FUSED into one worker call per chunk
        # (pass chunk=1, fuse_stages=False to get the classic per-item
        # engine; the dashboard shows read/decode as separate rows either
        # way).  At this toy size decode dominates, so the loader numbers
        # barely move — the engine overhead shows on the READ path, where
        # the work per item is a near-free mmap slice and every sample
        # otherwise pays ~4-5 event-loop round trips per stage.  Chunking
        # pulls N items per queue hop and dispatches one executor call per
        # chunk, making that cost O(items/chunk):
        from repro.core import PipelineBuilder

        def read_epoch(chunk: int) -> float:
            def read(i: int) -> int:
                return shard_ds.read_bytes(i).nbytes

            p = (
                PipelineBuilder()
                .add_source(list(range(len(shard_ds))), name="sampler")
                .pipe(read, concurrency=2, chunk=chunk, name="read", queue_size=32)
                .aggregate(32, name="batch")
                .add_sink(buffer_size=4)
                .build(num_threads=4)
            )
            t0 = time.monotonic()
            with p.auto_stop():
                n = sum(len(b) for b in p)
            return n / (time.monotonic() - t0)

        per_item_rate = read_epoch(1)
        chunked_rate = read_epoch(32)
        print(f"\nread path, per-item engine: {per_item_rate:.0f} samples/s"
              f"\nread path, chunked engine:  {chunked_rate:.0f} samples/s"
              f" (x{chunked_rate / max(per_item_rate, 1e-9):.1f} from chunk=32"
              " — see benchmarks/bench_engine.py for the full sweep)")

        # ---- the hot path to the device: uint8 wire + on-chip decode ----
        # device_decode finishes the decode ON the accelerator: batches
        # cross the wire as uint8 (4x fewer bytes than f32) and the fused
        # dequant_normalize_augment kernel (dequant → normalize → flip/crop,
        # one VMEM pass; Pallas on TPU, jnp ref elsewhere) runs right after
        # device_put — zero host-side float math on pixels.  The consumer
        # drains the sink in chunks (get_items) so the batch leg pays one
        # cross-thread hop per chunk, matching the chunked transfer
        # dispatch (transfer_chunk).  The host-decode baseline below is
        # what every classic pipeline pays per batch: uint8→f32 /255,
        # normalize, NCHW transpose — on the consumer's CPU.
        from repro.data.transfer import DeviceDecode

        def proc_cpu_s() -> float:
            parts = open("/proc/self/stat").read().split()
            return (int(parts[13]) + int(parts[14])) / os.sysconf("SC_CLK_TCK")

        def epoch(device_decode: bool):
            import numpy as np

            dd = (
                DeviceDecode(mean=tuple(MEAN.tolist()), std=tuple(STD.tolist()))
                if device_decode else None
            )
            p = build_image_loader(
                shard_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
                device_decode=dd, transfer_chunk=2,
            )
            n, c0 = 0, proc_cpu_s()
            with p.auto_stop():
                p.start()
                while True:
                    try:
                        chunk = p.get_items(2)  # chunked sink drain
                    except StopIteration:
                        break
                    for b in chunk:
                        if device_decode:
                            x = b["images"]  # already NCHW bf16, decoded on-chip
                        else:  # classic host float tail
                            x = np.asarray(b["images"]).astype(np.float32) / 255.0
                            x = (x - np.asarray(MEAN)) / np.asarray(STD)
                            x = jnp.asarray(np.ascontiguousarray(
                                x.transpose(0, 3, 1, 2)))
                        n += x.shape[0]
                x.block_until_ready()
            return n, proc_cpu_s() - c0, p

        # compile the fused decode outside the measured window (the bench
        # does the same — a one-off jit cost is not per-epoch host CPU)
        from repro.kernels.ops import dequant_normalize_augment

        dequant_normalize_augment(
            jnp.zeros((16, 112, 112, 3), jnp.uint8), MEAN, STD
        ).block_until_ready()

        n_host, cpu_host, _ = epoch(device_decode=False)
        n_dev, cpu_dev, pipe = epoch(device_decode=True)
        wire_mb = 16 * 112 * 112 * 3 / 2**20
        print(f"\nhot path to the device ({n_dev} images/epoch):"
              f"\n  wire bytes/batch:  {wire_mb:.2f}MB uint8"
              f" (vs {wire_mb * 4:.2f}MB as f32 — x4 off the wire)"
              f"\n  host CPU/epoch:    {cpu_host:.2f}s host-decode baseline"
              f" -> {cpu_dev:.2f}s with on-chip fused decode"
              f" (toy size — the full-size ViT run is gated >= x1.5 less"
              " host CPU in benchmarks/bench_e2e.py / BENCH_e2e.json)")
        print(pipe.format_stats())  # note the device-decode and sink rows

        # same shards behind a simulated-latency remote + local cache: the
        # prefetcher overlaps shard fetch with decode, the dashboard shows
        # the cache doing its job.  This run doubles as the flight-recorder
        # walkthrough: tracing() installs the tracer process-wide (the
        # prefetcher resolves it at call time), trace= hands it to the
        # engine/queues/transfer, and the capture lands in a Perfetto-
        # loadable JSON with one track per worker thread.
        prefetcher = ShardPrefetcher(
            SimulatedLatencySource(
                LocalShardSource(d + "/shards"), latency_s=0.01
            ),
            d + "/cache",
            max_bytes=1 << 30,
        )
        remote_ds = ShardDataset(d + "/shards", prefetcher=prefetcher)
        with tracing() as tracer:
            pipe = build_image_loader(
                remote_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
                sampler=CheckpointableSampler(
                    len(remote_ds),
                    batch_size=1,
                    seed=0,
                    shard_sizes=remote_ds.shard_sizes,
                    shard_window=48,
                ),
                trace=tracer,
            )
            n_img, dt = consume(pipe)
        print(f"\nSPDL (remote shards + cache): {n_img / dt:.0f} img/s")
        print(pipe.format_stats())
        remote_ds.close()

        trace_path = os.environ.get(
            "REPRO_TRACE_PATH",
            str(pathlib.Path(__file__).resolve().parent / "imagenet_trace.json"),
        )
        tracer.export(trace_path)
        cats = {e.get("cat") for e in tracer.events()} - {None}
        print(f"flight recorder: {len(tracer)} spans across "
              f"{sorted(cats)} -> {trace_path} "
              "(open at https://ui.perfetto.dev)")

        # the same shards over a REAL http server (loopback, Range-capable):
        # a bare URL root builds HttpShardSource → RetryingSource →
        # ShardPrefetcher, and the loader's lookahead feeds index-first
        # sample hints so narrow windows fetch ranges, not whole shards
        from repro.data.shards.testing import serve_shards

        with serve_shards(d + "/shards") as srv:
            http_ds = ShardDataset(srv.url, cache_dir=d + "/http_cache")
            pipe = build_image_loader(
                http_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
                sampler=CheckpointableSampler(
                    len(http_ds),
                    batch_size=1,
                    seed=0,
                    shard_sizes=http_ds.shard_sizes,
                    shard_window=48,
                ),
            )
            n_img, dt = consume(pipe)
            print(f"\nSPDL (HTTP shards + cache): {n_img / dt:.0f} img/s "
                  f"({srv.requests} requests, "
                  f"{srv.bytes_served / 2**20:.1f}MB served)")
            print(pipe.format_stats())

            # peer shard exchange: "rank A" above warmed its cache — serve
            # it over a PeerShardServer and let "rank B" read the whole
            # epoch through the origin → retry → peers → prefetcher stack.
            # Warm data comes from the peer (whole shards and resident
            # sparse spans); only what rank A never fetched falls through
            # to the origin, and the dashboard grows a peers line.
            from repro.data import PeerShardServer

            with PeerShardServer(http_ds.prefetcher) as peer:
                origin_before = srv.requests
                peer_ds = ShardDataset(
                    srv.url, cache_dir=d + "/peer_cache", peers=[peer.url]
                )
                pipe = build_image_loader(
                    peer_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
                    sampler=CheckpointableSampler(
                        len(peer_ds),
                        batch_size=1,
                        seed=0,
                        shard_sizes=peer_ds.shard_sizes,
                        shard_window=48,
                    ),
                )
                n_img, dt = consume(pipe)
                print(f"\nSPDL (peer shards, rank B): {n_img / dt:.0f} img/s "
                      f"({srv.requests - origin_before} origin requests, "
                      f"{peer.stats()['bytes_served'] / 2**20:.1f}MB "
                      f"peer-served)")
                print(pipe.format_stats())
                peer_ds.close()
            http_ds.close()

            # warm restart: a rank dies (preemption, rolling restart) and
            # comes back with its cache directory intact.  With
            # persist_cache=True the prefetcher writes a manifest + sparse
            # span sidecars (fsync+rename, crash-safe) on close; the
            # restarted rank re-opens resident shards and spans from disk
            # instead of re-fetching them, so the origin sees (near) zero
            # traffic for data the dead rank already paid for.
            warm_dir = d + "/warm_cache"
            run1 = ShardDataset(srv.url, cache_dir=warm_dir, persist_cache=True)
            for i in range(len(run1)):
                run1[i]  # epoch 1: fill the cache
            run1.close()  # "crash": state persisted on the way down

            origin_before = srv.requests
            run2 = ShardDataset(srv.url, cache_dir=warm_dir, persist_cache=True)
            for i in range(len(run2)):
                run2[i]  # epoch 2: served from the restored cache
            reused = run2.prefetcher.stats()["warm_restart_bytes_reused"]
            print(f"\nwarm restart: {reused / 2**20:.1f}MB re-opened from "
                  f"the persisted cache, {srv.requests - origin_before} "
                  "origin requests on the resumed epoch")
            run2.close()

        # ---- columnar shards + projection pushdown (format v2) ----------
        # Real corpora carry more than pixels: pack image + caption as
        # named fields of a columnar v2 shard, then train image-only with
        # fields=("image",) — the projection rides the prefetch hints
        # through every layer, so caption bytes never cross the wire and
        # the dashboard's shard-cache line grows skipped=/fields= counters.
        class ImageCaptionSource:
            """dict-of-blobs view over the file directory: the encoded
            image plus a caption sidecar per sample."""

            schema_fields = ("image", "caption")

            def __len__(self):
                return len(files_ds)

            def read_fields(self, i, fields=None):
                # the caption column carries a rich sidecar (tokenized
                # text, augmentation metadata, ...) — here sized like one
                # (~64KB/sample) so the wire saving is visible below
                blobs = {
                    "image": files_ds.read_bytes(i),
                    "caption": (b"a synthetic image, sample %d " % i) * 2200,
                }
                return {f: blobs[f] for f in (fields or self.schema_fields)}

        v2_ds = pack(
            ImageCaptionSource(), d + "/shards_v2", samples_per_shard=24,
            format_version=2,
        )
        print(
            f"\npacked {len(v2_ds)} samples into columnar v2 shards, "
            f"fields: {', '.join(v2_ds.schema_fields)}"
        )
        print(f"caption field rides along: "
              f"{bytes(v2_ds.read_fields(0)['caption'])[:28]!r}... "
              f"({len(v2_ds.read_fields(0)['caption']) / 1024:.0f}KB/sample)")
        with serve_shards(d + "/shards_v2") as srv:
            # fields= on the dataset pins the projection for every read —
            # scheduled prefetches AND demand fetches pull image-column
            # ranges only; the loader's fields= rides the same hint
            proj_ds = ShardDataset(
                srv.url, cache_dir=d + "/proj_cache", fields=("image",)
            )
            pipe = build_image_loader(
                proj_ds, batch_size=16, hw=(112, 112), decode_concurrency=4,
                fields=("image",),
                sampler=CheckpointableSampler(
                    len(proj_ds),
                    batch_size=1,
                    seed=0,
                    shard_sizes=proj_ds.shard_sizes,
                    shard_window=48,
                ),
            )
            n_img, dt = consume(pipe)
            stats = proj_ds.prefetcher.stats()
            print(f"\nSPDL (HTTP v2 shards, image-only projection): "
                  f"{n_img / dt:.0f} img/s "
                  f"({srv.bytes_served / 2**20:.1f}MB on the wire, "
                  f"{stats['bytes_skipped'] / 2**20:.1f}MB skipped — "
                  "caption column never fetched)")
            print(pipe.format_stats())
            proj_ds.close()
        v2_ds.close()

        # baselines: the seed per-file dataset through the same pipeline,
        # and the PyTorch-style multiprocessing loader
        pipe = build_image_loader(files_ds, batch_size=16, hw=(112, 112),
                                  decode_concurrency=4)
        n_img, dt = consume(pipe)
        print(f"\nSPDL (per-file): {n_img / dt:.0f} img/s")

        loader = MPLoader(files_ds, batch_size=16, hw=(112, 112), num_workers=2)
        t0 = time.monotonic()
        n_img = sum(b.shape[0] for b in loader)
        dt = time.monotonic() - t0
        print(f"MPLoader (PyTorch-style, 2 workers): {n_img / dt:.0f} img/s "
              f"(startup {loader.startup_s:.2f}s)")


if __name__ == "__main__":
    main()

"""Batched serving example: SPDL request pipeline → prefill → greedy decode.

Run: PYTHONPATH=src python examples/serve_llm.py
"""

import jax

from repro.configs import get_smoke_config
from repro.models import Model
from repro.runtime import BatchServer


def main() -> None:
    cfg = get_smoke_config("yi-6b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch_size=4, prompt_len=16, max_new=8)

    prompts = [
        "the paper shows that",
        "data loading is",
        "thread pools scale when",
        "the GIL prevents",
        "free-threaded python will",
    ]
    for res in server.generate(prompts):
        print(f"{res.prompt!r} -> tokens {res.token_ids}")


if __name__ == "__main__":
    main()

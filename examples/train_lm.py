"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU,
fed by the SPDL pipeline, with checkpoint/resume fault tolerance.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen3-0.6b]
"""

import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokenDataset, build_lm_loader
from repro.data.sampler import CheckpointableSampler
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M-param config: widen the smoke config
    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(
        cfg,
        d_model=args.d_model,
        num_layers=args.layers,
        num_heads=8,
        num_kv_heads=4,
        head_dim=0,
        d_ff=4 * args.d_model,
        vocab_size=50304,
    )
    shape = ShapeConfig("example_train", args.seq_len, args.batch, "train")

    ds = SyntheticTokenDataset(5_000, vocab=cfg.vocab_size, min_len=64, max_len=512)
    sampler = CheckpointableSampler(len(ds), batch_size=8, seed=0)
    pipe, sampler = build_lm_loader(
        ds, seq_len=args.seq_len, batch_size=args.batch, sampler=sampler, num_threads=6
    )

    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20)
    trainer = Trainer.from_checkpoint(cfg, shape, sampler=sampler, tcfg=tcfg)
    print(f"arch={cfg.name}  params={trainer.model.param_count() / 1e6:.1f}M  start_step={trainer.step}")

    with pipe.auto_stop():
        out = trainer.fit(pipe, steps=args.steps, sampler=sampler)
        print(trainer.tuning_hint(pipe))
    for h in out["history"]:
        print(h)
    print(f"data-wait fraction: {out['data_wait_frac']:.1%} (starved={out['starved']})")


if __name__ == "__main__":
    main()

"""Trip-count-aware census of a compiled SPMD HLO module.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers programs (a 16-layer scan undercounts flops 16×).  The
compiled HLO text, however, carries ``backend_config={"known_trip_count":
{"n":"16"}}`` on every while op, so we walk the call graph (entry → while
bodies × trip count → fusions → ops) and accumulate:

  - dot flops           : 2 · prod(result dims) · prod(contracting dims)
  - bytes accessed      : Σ (result + operand bytes) per top-level op — the
                          same traffic model XLA's own cost analysis uses,
                          but trip-count-corrected
  - collective bytes/ops: per kind, with result-size accounting

All numbers are PER DEVICE (the module is the per-partition SPMD program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

#: ops that don't touch HBM meaningfully (metadata / aliasing / control)
FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "copy-done", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "async-done", "domain", "opt-barrier",
    "get-dimension-size",
}

_SHAPE_RE = re.compile(r"(pred|[subfc]\d+|bf16|f16|token)\[([\d,]*)\]")
# result type: a (possibly /*index=N*/-commented) tuple, or a single token
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[^\s(]+)\s+([\w\-]+)\("
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_METADATA_RE = re.compile(r'op_name="([^"]*)"')

#: source-scope buckets for attributing dot flops/bytes (hillclimb accounting)
BUCKETS = {
    "attention": ("attention", "_sdpa", "flash", "kv_scan"),
    "ssd": ("ssd", "chunk_body", "_ssd"),
    "moe": ("apply_moe", "moe"),
}


def _bucket_of(raw: str) -> str | None:
    m = _METADATA_RE.search(raw)
    if not m:
        return None
    name = m.group(1)
    for b, keys in BUCKETS.items():
        if any(k in name for k in keys):
            return b
    return None


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _type_dims(type_str: str) -> list[int] | None:
    """Dims of a single (non-tuple) type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Comp:
    ops: list = dataclasses.field(default_factory=list)  # (name, type_str, kind, rest)
    types: dict = dataclasses.field(default_factory=dict)  # op name -> type str


def _split_computations(hlo: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        if raw and not raw.startswith(" ") and raw.rstrip().endswith("{"):
            m = _COMP_HDR.match(raw)
            if m:
                cur = comps.setdefault(m.group(1), _Comp())
                if raw.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if cur is None:
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, type_str, kind = m.groups()
        rest = raw[m.end():]
        cur.ops.append((name, type_str, kind, rest, raw))
        cur.types[name] = type_str
    return comps, entry


def census(hlo: str) -> dict:
    comps, entry = _split_computations(hlo)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    per_comp: dict[str, dict] = {}
    for cname, comp in comps.items():
        flops = 0.0
        bytes_ = 0.0
        tpu_bytes = 0.0  # fusion-optimistic: ops a TPU build cannot fuse away
        bucket_f: dict[str, float] = defaultdict(float)
        bucket_b: dict[str, float] = defaultdict(float)
        coll_b: dict[str, float] = defaultdict(float)
        coll_c: dict[str, float] = defaultdict(float)
        calls: list[tuple[str, int]] = []
        for name, type_str, kind, rest, raw in comp.ops:
            # -- call graph edges -----------------------------------------
            if kind == "while":
                trip = 1
                tm = _TRIP_RE.search(raw)
                if tm:
                    trip = int(tm.group(1))
                for rex in (_CALLS_RE, _COND_RE):
                    cm = rex.search(raw)
                    if cm:
                        calls.append((cm.group(1), trip))
                continue
            if kind in ("fusion", "call", "reduce", "reduce-window", "scatter", "sort", "map", "select-and-scatter", "custom-call", "async-start"):
                cm = _CALLS_RE.search(raw)
                if cm:
                    calls.append((cm.group(1), 1))
            if kind == "conditional":
                bm = _BRANCHES_RE.search(raw)
                if bm:
                    for b in bm.group(1).split(","):
                        calls.append((b.strip().lstrip("%"), 1))

            # operand name list (within the call parens only)
            paren = rest.split(")", 1)[0]
            operand_names = _OPERANDS_RE.findall(paren)

            # -- flops ------------------------------------------------------
            if kind in ("dot", "convolution"):
                out_elems = 0
                for dt, dims in _SHAPE_RE.findall(type_str):
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    out_elems += n
                contract = 1
                cm2 = _CONTRACT_RE.search(raw)
                lhs_dims = (
                    _type_dims(comp.types.get(operand_names[0], ""))
                    if operand_names
                    else None
                )
                if cm2 and lhs_dims is not None:
                    for idx in filter(None, cm2.group(1).split(",")):
                        i = int(idx)
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                elif kind == "convolution" and lhs_dims:
                    contract = max(lhs_dims)
                flops += 2.0 * out_elems * contract
                bk = _bucket_of(raw)
                if bk:
                    bucket_f[bk] += 2.0 * out_elems * contract
                    res_b0 = float(_type_bytes(type_str))
                    op_b0 = sum(_type_bytes(comp.types.get(on, "")) for on in operand_names)
                    bucket_b[bk] += res_b0 + op_b0

            # -- bytes ------------------------------------------------------
            if kind not in FREE_OPS:
                res_b = float(_type_bytes(type_str))
                op_bs = [float(_type_bytes(comp.types.get(on, ""))) for on in operand_names]
                bytes_ += res_b + sum(op_bs)
                # fusion-optimistic model (what a TPU build must still move):
                is_dus = "dynamic-update-slice" in name or "dynamic-update-slice" in kind
                base_k = kind[:-6] if kind.endswith("-start") else kind
                if is_dus:
                    # in-place update: read+write the inserted slice + other
                    # operands; the big aliased buffer is not re-traversed
                    tpu_bytes += sum(op_bs) - (max(op_bs) if op_bs else 0.0)
                elif base_k == "dynamic-slice":
                    # reads only the slice (result-sized), then writes it
                    tpu_bytes += 2.0 * res_b
                elif base_k in ("dot", "convolution", "gather", "scatter", "concatenate", "copy", "transpose", "sort"):
                    tpu_bytes += res_b + sum(op_bs)
                elif base_k in COLLECTIVES:
                    tpu_bytes += 2.0 * res_b
                elif base_k == "reduce":
                    tpu_bytes += sum(op_bs)
                # other elementwise/convert/broadcast ops: assumed fused

            # -- collectives -------------------------------------------------
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVES:
                nb = _type_bytes(type_str)
                if kind.endswith("-start"):
                    nb //= 2  # start result carries (input, output)
                coll_b[base] += nb
                coll_c[base] += 1
        per_comp[cname] = {
            "flops": flops,
            "bytes": bytes_,
            "tpu_bytes": tpu_bytes,
            "bucket_f": bucket_f,
            "bucket_b": bucket_b,
            "coll_b": coll_b,
            "coll_c": coll_c,
            "calls": calls,
        }

    memo: dict[str, dict] = {}

    def total(name: str) -> dict:
        if name in memo:
            return memo[name]
        st = per_comp.get(name)
        empty = {"flops": 0.0, "bytes": 0.0, "tpu_bytes": 0.0, "coll_b": {}, "coll_c": {}, "bucket_f": {}, "bucket_b": {}}
        if st is None:
            return dict(empty)
        memo[name] = dict(empty)
        acc_b = defaultdict(float, st["coll_b"])
        acc_c = defaultdict(float, st["coll_c"])
        buf = defaultdict(float, st["bucket_f"])
        bub = defaultdict(float, st["bucket_b"])
        fl, by, tby = st["flops"], st["bytes"], st["tpu_bytes"]
        for child, mult in st["calls"]:
            sub = total(child)
            fl += sub["flops"] * mult
            by += sub["bytes"] * mult
            tby += sub["tpu_bytes"] * mult
            for k, v in sub["coll_b"].items():
                acc_b[k] += v * mult
            for k, v in sub["coll_c"].items():
                acc_c[k] += v * mult
            for k, v in sub["bucket_f"].items():
                buf[k] += v * mult
            for k, v in sub["bucket_b"].items():
                bub[k] += v * mult
        memo[name] = {"flops": fl, "bytes": by, "tpu_bytes": tby, "coll_b": acc_b, "coll_c": acc_c, "bucket_f": buf, "bucket_b": bub}
        return memo[name]

    t = total(entry)
    return {
        "dot_flops": t["flops"],
        "bytes_accessed": t["bytes"],
        "tpu_bytes": t["tpu_bytes"],
        "bucket_flops": dict(t["bucket_f"]),
        "bucket_dot_bytes": dict(t["bucket_b"]),
        "collectives": {
            k: {"bytes": t["coll_b"].get(k, 0.0), "count": t["coll_c"].get(k, 0.0)}
            for k in COLLECTIVES
        },
        "collective_bytes": float(sum(t["coll_b"].values())),
        "collective_count": float(sum(t["coll_c"].values())),
        "n_computations": len(comps),
    }


if __name__ == "__main__":  # debugging helper
    import sys

    print(json.dumps(census(open(sys.argv[1]).read()), indent=2))

"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``."""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompts", nargs="*", default=["hello world", "data loading is"])
    args = ap.parse_args()

    import jax

    from ..configs import get_config, get_smoke_config
    from ..models import Model
    from ..runtime import BatchServer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, batch_size=args.batch, max_new=args.max_new)
    for res in server.generate(list(args.prompts)):
        print(f"{res.prompt!r} -> {res.token_ids}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS *before* calling it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """v5e pod meshes: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally (tests / examples / smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))

"""Distributed step builders: jitted train/prefill/decode with shardings.

One place assembles everything mesh-dependent: parameter NamedShardings from
the ParallelPlan rules, batch shardings over the data axes, cache shardings
(incl. SP sequence sharding for long-context decode), gradient accumulation
(microbatch scan), and donation (params/opt-state for train, cache for
decode).  Both the real runtime and the dry-run lower through these
builders, so what we roofline is what we'd run.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..dist.hints import mesh_context
from ..dist.sharding import ParallelPlan, batch_axes_for, make_plan
from ..models.model import Model
from ..models.params import param_shardings, tree_map_defs
from ..optim import OptConfig, apply_update, init_opt_state
from ..optim.optimizer import abstract_opt_state


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower/run one (arch × shape × mesh) cell."""

    model: Model
    plan: ParallelPlan
    shape: ShapeConfig
    fn: Callable  # the python step fn
    jitted: Any  # jax.jit-wrapped with shardings
    in_specs: tuple  # ShapeDtypeStructs to .lower(*in_specs)
    opt_cfg: OptConfig | None = None


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def _named(plan: ParallelPlan, spec_tree: Any) -> Any:
    # NB: P is a tuple subclass — must be treated as a leaf explicitly
    return jax.tree.map(
        lambda s: NamedSharding(plan.mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def params_shardings(model: Model, plan: ParallelPlan) -> Any:
    return param_shardings_checked(model.param_defs(), plan)


def param_shardings_checked(defs: Any, plan: ParallelPlan) -> Any:
    """Param shardings from logical rules, dropping non-divisible entries."""
    from ..models.params import ParamDef, resolve_pspec

    mesh_shape = plan.mesh_shape

    def one(d: ParamDef) -> NamedSharding:
        spec = resolve_pspec(d.axes, plan.rules)
        fixed = []
        for dim, entry in zip(d.shape, list(spec) + [None] * (len(d.shape) - len(spec))):
            if entry is None:
                fixed.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= mesh_shape.get(a, 1)
            fixed.append(entry if dim % total == 0 else None)
        while fixed and fixed[-1] is None:
            fixed.pop()
        return NamedSharding(plan.mesh, P(*fixed))

    return tree_map_defs(one, defs)


def opt_state_shardings(opt_cfg: OptConfig, p_shardings: Any, plan: ParallelPlan) -> Any:
    """Moments inherit param shardings; scalars replicated."""
    rep = NamedSharding(plan.mesh, P())
    if opt_cfg.kind in ("adamw", "adamw_bf16"):
        return {"step": rep, "m": p_shardings, "v": p_shardings}
    if opt_cfg.kind == "sgdm":
        return {"step": rep, "m": p_shardings}
    # adafactor: factored leaves — replicate the small factors of FSDP params
    def fac(s: NamedSharding):
        spec = list(s.spec)
        row = P(*spec[:-1]) if spec else P()
        col = P(*(spec[:-2] + spec[-1:])) if len(spec) >= 2 else P()
        return {
            "vr": NamedSharding(plan.mesh, row),
            "vc": NamedSharding(plan.mesh, col),
        }

    # NB: shapes with ndim<2 use {"v": ...}; handled loosely — adafactor is
    # only used as a fallback and its state is tiny.
    return {"step": rep, "f": jax.tree.map(fac, p_shardings)}


def batch_shardings(model: Model, plan: ParallelPlan, shape: ShapeConfig) -> Any:
    spec = model.batch_spec(shape)
    dp = batch_axes_for(plan, shape.global_batch)

    def one(s: jax.ShapeDtypeStruct) -> NamedSharding:
        return NamedSharding(plan.mesh, P(dp, *([None] * (len(s.shape) - 1))))

    return jax.tree.map(one, spec)


def cache_shardings(model: Model, plan: ParallelPlan, shape: ShapeConfig) -> Any:
    """Cache leaves: (layers, B, S, ...) for attn/mla; (layers, B, H, P, N)
    for ssd.  B over dp (when divisible); S over dp in SP mode; heads over tp."""
    cfg = model.cfg
    dp = batch_axes_for(plan, shape.global_batch)
    sp = plan.dp_axes if plan.seq_shard_cache else None
    t = plan.tp_axis if plan.shard_heads else None
    specs = []
    for seg_plan, _ in cfg.segments():
        blocks = []
        for kind, _moe in seg_plan:
            if kind == "attn":
                kv_eff = cfg.num_kv_heads * plan.kv_repeat
                kv_ax = t if (t and kv_eff % plan.tp_size == 0) else None
                s = P(None, dp, sp, kv_ax, None)
                blocks.append({"k": s, "v": s})
            elif kind == "mla":
                blocks.append(
                    {"ckv": P(None, dp, sp, None), "k_rope": P(None, dp, sp, None)}
                )
            else:  # ssd
                nh = cfg.ssd.n_heads(cfg.d_model)
                h_ax = t if (t and nh % plan.tp_size == 0) else None
                blocks.append(
                    {
                        "ssm": P(None, dp, h_ax, None, None),
                        "conv": P(None, dp, None, None),
                    }
                )
        specs.append({"blocks": blocks})
    return _named(plan, specs)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def opt_config_for(cfg: ModelConfig) -> OptConfig:
    return OptConfig(kind=cfg.optimizer)


def build_train_step(
    cfg: ModelConfig,
    mesh: jax.sharding.Mesh | None,
    shape: ShapeConfig,
    *,
    grad_accum: int | None = None,
    donate: bool = True,
    rules_override: dict | None = None,
) -> StepBundle:
    plan = make_plan(cfg, mesh, shape)
    if rules_override:
        plan = dataclasses.replace(plan, rules={**plan.rules, **rules_override})
    model = Model(cfg, plan)
    opt_cfg = opt_config_for(cfg)
    accum = grad_accum if grad_accum is not None else cfg.grad_accum.get(shape.name, 1)

    def train_step(params, opt_state, batch):
        with mesh_context(plan):
            if accum > 1:
                def micro(carry, mb):
                    (loss, metrics), grads = jax.value_and_grad(
                        model.train_loss, has_aux=True
                    )(params, mb)
                    gsum = jax.tree.map(jnp.add, carry, grads)
                    return gsum, metrics

                mb = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
                )
                # accumulate in the grad dtype (== param dtype) so the scan
                # carry type is stable and no extra fp32 copy materializes
                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
                gsum, metrics = jax.lax.scan(micro, zeros, mb)
                grads = jax.tree.map(lambda g: g / accum, gsum)
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(model.train_loss, has_aux=True)(
                    params, batch
                )
            params2, opt_state2, opt_metrics = apply_update(opt_cfg, params, grads, opt_state)
            metrics.update(opt_metrics)
            return params2, opt_state2, metrics

    if mesh is None:
        jitted = jax.jit(train_step, donate_argnums=(0, 1) if donate else ())
        return StepBundle(model, plan, shape, train_step, jitted, (), opt_cfg)

    p_sh = params_shardings(model, plan)
    o_sh = opt_state_shardings(opt_cfg, p_sh, plan)
    b_sh = batch_shardings(model, plan, shape)
    rep = NamedSharding(plan.mesh, P())
    jitted = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    in_specs = (
        model.abstract_params(),
        abstract_opt_state(opt_cfg, model.abstract_params()),
        model.batch_spec(shape),
    )
    return StepBundle(model, plan, shape, train_step, jitted, in_specs, opt_cfg)


def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig, *, rules_override: dict | None = None) -> StepBundle:
    plan = make_plan(cfg, mesh, shape)
    if rules_override:
        plan = dataclasses.replace(plan, rules={**plan.rules, **rules_override})
    model = Model(cfg, plan)

    def prefill(params, batch):
        with mesh_context(plan):
            return model.prefill(params, batch)

    if mesh is None:
        return StepBundle(model, plan, shape, prefill, jax.jit(prefill), ())
    p_sh = params_shardings(model, plan)
    b_sh = batch_shardings(model, plan, shape)
    c_sh = cache_shardings(model, plan, shape)
    dp = batch_axes_for(plan, shape.global_batch)
    logits_sh = NamedSharding(plan.mesh, P(dp, plan.tp_axis))
    if cfg.n_codebooks > 1:
        logits_sh = NamedSharding(plan.mesh, P(dp, None, plan.tp_axis))
    jitted = jax.jit(
        prefill, in_shardings=(p_sh, b_sh), out_shardings=(logits_sh, c_sh)
    )
    in_specs = (model.abstract_params(), model.batch_spec(shape))
    return StepBundle(model, plan, shape, prefill, jitted, in_specs)


def build_decode_step(cfg: ModelConfig, mesh, shape: ShapeConfig) -> StepBundle:
    plan = make_plan(cfg, mesh, shape)
    model = Model(cfg, plan)

    def decode(params, caches, tokens, pos):
        with mesh_context(plan):
            return model.decode_step(params, caches, tokens, pos)

    if mesh is None:
        return StepBundle(model, plan, shape, decode, jax.jit(decode, donate_argnums=(1,)), ())
    p_sh = params_shardings(model, plan)
    c_sh = cache_shardings(model, plan, shape)
    b = shape.global_batch
    dp = batch_axes_for(plan, b)
    tok_sh = NamedSharding(
        plan.mesh, P(dp, None, None) if cfg.n_codebooks > 1 else P(dp, None)
    )
    logits_sh = NamedSharding(plan.mesh, P(dp, plan.tp_axis))
    if cfg.n_codebooks > 1:
        logits_sh = NamedSharding(plan.mesh, P(dp, None, plan.tp_axis))
    pos_sh = NamedSharding(plan.mesh, P())
    jitted = jax.jit(
        decode,
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, c_sh),
        donate_argnums=(1,),
    )
    tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, 1)
    in_specs = (
        model.abstract_params(),
        model.cache_spec(b, shape.seq_len),
        jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return StepBundle(model, plan, shape, decode, jitted, in_specs)


def build_step(cfg: ModelConfig, mesh, shape: ShapeConfig, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    return build_decode_step(cfg, mesh, shape)

"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On real hardware this process runs once per host (jax.distributed handles
the pod topology); in this container ``--smoke`` trains the reduced config
end-to-end on CPU, and the full configs are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import logging


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config (CPU)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    from ..configs import get_config, get_smoke_config
    from ..configs.base import ShapeConfig, TRAIN_4K
    from ..data import SyntheticTokenDataset, build_lm_loader
    from ..data.sampler import CheckpointableSampler
    from ..runtime import Trainer, TrainerConfig
    from .mesh import make_host_mesh, make_production_mesh

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        shape = ShapeConfig("train_smoke", args.seq_len, args.batch, "train")
        mesh = None
    else:
        cfg = get_config(args.arch)
        shape = TRAIN_4K
        mesh = make_production_mesh()

    ds = SyntheticTokenDataset(10_000, vocab=cfg.vocab_size)
    sampler = CheckpointableSampler(len(ds), batch_size=8)
    pipe, sampler = build_lm_loader(
        ds, seq_len=shape.seq_len, batch_size=shape.global_batch, sampler=sampler
    )
    trainer = Trainer.from_checkpoint(
        cfg, shape, sampler=sampler, mesh=mesh, tcfg=TrainerConfig(ckpt_dir=args.ckpt_dir)
    )
    with pipe.auto_stop():
        out = trainer.fit(pipe, steps=args.steps, sampler=sampler)
        print(trainer.tuning_hint(pipe))
    print(out["history"][-1] if out["history"] else out)


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape × mesh) cell this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod) on
     512 forced host devices,
  2. lowers + compiles the jitted step with full shardings,
  3. records memory_analysis(), cost_analysis(), and the collective-op
     byte/op census parsed from the compiled SPMD module,
  4. writes one JSON per cell under experiments/dryrun/ — the roofline
     report (benchmarks/roofline.py) is derived from these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k
"""

import argparse
import json
import pathlib
import re
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path) -> dict:
    import jax

    from ..configs import SHAPES, get_config, shape_applicable
    from .mesh import make_production_mesh
    from .steps import build_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "skip" if not ok else "pending",
    }
    if not ok:
        rec["skip_reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape)
    with mesh:
        lowered = bundle.jitted.lower(*bundle.in_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)
    ca = compiled.cost_analysis()
    print({k: v for k, v in sorted(ca.items()) if not any(c.isdigit() for c in k)})
    from .hlo_census import census as hlo_census

    census = hlo_census(compiled.as_text())

    rec.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        cost_analysis_flops=ca.get("flops", 0.0),  # NB: loop bodies ×1
        cost_analysis_bytes=ca.get("bytes accessed", 0.0),
        flops_per_device=census["dot_flops"],  # trip-count-corrected
        bytes_per_device=census["bytes_accessed"],
        tpu_bytes_per_device=census["tpu_bytes"],
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        collectives={
            "per_kind": census["collectives"],
            "collective_bytes": census["collective_bytes"],
            "collective_count": census["collective_count"],
        },
        plan={
            "fsdp": bundle.plan.fsdp,
            "kv_repeat": bundle.plan.kv_repeat,
            "shard_heads": bundle.plan.shard_heads,
            "seq_shard_cache": bundle.plan.seq_shard_cache,
        },
        param_count=bundle.model.param_count(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    from ..configs import SHAPES, all_archs

    archs = all_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                cell = f"{arch}__{shape}__{mesh_kind}"
                path = out_dir / f"{cell}.json"
                print(f"=== {cell} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, out_dir)
                except Exception as e:  # a failing cell is a bug — record it
                    traceback.print_exc()
                    rec = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": mesh_kind,
                        "status": "fail",
                        "error": repr(e),
                    }
                    if args.fail_fast:
                        path.write_text(json.dumps(rec, indent=2))
                        raise
                path.write_text(json.dumps(rec, indent=2))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_fail += st == "fail"
                print(
                    f"--- {cell}: {st}"
                    + (
                        f" (compile {rec.get('compile_s')}s, "
                        f"{rec.get('collectives', {}).get('collective_count', 0)} collectives)"
                        if st == "ok"
                        else ""
                    ),
                    flush=True,
                )
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Checkpointing: async, atomic, and inclusive of data-pipeline state.

The checkpoint is (params, opt_state, step, **sampler state**) — saving the
sampler cursor is what the paper's §3 says process-based loaders cannot do
cleanly; with the thread-based pipeline it is a dict read.  Writes happen on
a background thread from a host snapshot (training continues), into a temp
dir renamed atomically, so a preemption mid-write never corrupts the latest
complete checkpoint — the fault-tolerance contract the trainer relies on.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        target_dtype = leaf.dtype
        leaves.append(arr.astype(target_dtype) if arr.dtype != target_dtype else arr)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree), leaves)


def save_checkpoint(
    ckpt_dir: str | pathlib.Path,
    step: int,
    params: Any,
    opt_state: Any | None = None,
    sampler_state: dict | None = None,
    extra: dict | None = None,
) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    blobs = {f"params{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blobs.update({f"opt{k}": v for k, v in _flatten(opt_state).items()})
    # bf16 is not npy-native: stash as uint16 raw with a dtype manifest
    manifest = {}
    store = {}
    for k, v in blobs.items():
        manifest[k] = str(v.dtype)
        store[k] = v.view(np.uint16) if v.dtype.name == "bfloat16" else v
    np.savez(tmp / "arrays.npz", **store)
    meta = {
        "step": step,
        "dtypes": manifest,
        "sampler": sampler_state,
        "extra": extra or {},
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_")
    ]
    return max(steps) if steps else None


def load_checkpoint(
    ckpt_dir: str | pathlib.Path,
    params_template: Any,
    opt_template: Any | None = None,
    step: int | None = None,
) -> dict:
    """Restore into the given pytree templates (shape/dtype authority)."""
    import ml_dtypes

    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    with np.load(d / "arrays.npz") as z:
        raw = {k: z[k] for k in z.files}
    for k, dt in meta["dtypes"].items():
        if dt == "bfloat16":
            raw[k] = raw[k].view(ml_dtypes.bfloat16)
    params = _unflatten_into(
        params_template, {k[len("params"):]: v for k, v in raw.items() if k.startswith("params")}
    )
    out = {"step": meta["step"], "params": params, "sampler": meta["sampler"], "extra": meta["extra"]}
    if opt_template is not None:
        out["opt_state"] = _unflatten_into(
            opt_template, {k[len("opt"):]: v for k, v in raw.items() if k.startswith("opt")}
        )
    return out


class CheckpointManager:
    """Periodic async checkpoints with retention; ``wait()`` before exit."""

    def __init__(self, ckpt_dir: str | pathlib.Path, *, every: int = 100, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.every = every
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def maybe_save(self, step: int, params, opt_state, sampler_state=None, extra=None) -> bool:
        if step % self.every:
            return False
        self.wait()  # at most one write in flight
        # snapshot on the caller thread (host copies); write in background
        params_host = jax.tree.map(np.asarray, params)
        opt_host = jax.tree.map(np.asarray, opt_state)

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, params_host, opt_host, sampler_state, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True, name="ckpt-writer")
        self._thread.start()
        return True

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.ckpt_dir.iterdir()
            if p.name.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

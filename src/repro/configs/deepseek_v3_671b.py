"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

61L, d_model=7168, 128H, vocab=129280.  First 3 layers dense (d_ff=18432 per
the release); MoE layers use 256 routed experts (d_expert=2048, top-8) plus
1 shared expert.  MLA: q_lora 1536, kv_lora 512, rope 64, v_head 128.
"""

import dataclasses

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,  # dense layers (first 3); spec's d_ff=2048 is the expert dim
    vocab_size=129280,
    pattern=("mla",),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        experts_per_token=8,
        d_expert=2048,
        n_shared_experts=1,
        first_k_dense=3,
        capacity_factor=1.25,
    ),
    mtp=True,
    norm="rmsnorm",
    remat_policy="none",
    optimizer="adamw_bf16",  # capacity: bf16 moments (DESIGN §5)
    grad_accum={"train_4k": 8},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="deepseek-v3-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    d_ff=128,
    vocab_size=256,
    mla=MLAConfig(
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16
    ),
    moe=MoEConfig(
        n_experts=8, experts_per_token=2, d_expert=32, n_shared_experts=1, first_k_dense=1
    ),
)

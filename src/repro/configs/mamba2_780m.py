"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1536, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Tied embeddings (per the released checkpoints).
"""

import dataclasses

from .base import ModelConfig, SSDConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssd",),
    ssd=SSDConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True,
    norm="rmsnorm",
    optimizer="adamw",
    grad_accum={"train_4k": 2},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="mamba2-780m-smoke",
    num_layers=4,
    d_model=64,
    vocab_size=256,
    ssd=SSDConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16),
)

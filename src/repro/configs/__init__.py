"""Architecture registry: the 10 assigned architectures (+ smoke variants).

``get_config(name)`` / ``get_smoke_config(name)`` resolve by arch id.
"""

from __future__ import annotations

import importlib

from .base import (
    LONG_500K,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SSDConfig,
    ShapeConfig,
    shape_applicable,
)

ARCHS: tuple[str, ...] = (
    "mamba2_780m",
    "jamba_1p5_large_398b",
    "deepseek_v3_671b",
    "granite_moe_1b_a400m",
    "musicgen_medium",
    "qwen1p5_110b",
    "olmo_1b",
    "qwen3_0p6b",
    "yi_6b",
    "internvl2_2b",
)

#: public --arch ids (dashes) → module names
ALIASES = {
    "mamba2-780m": "mamba2_780m",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "musicgen-medium": "musicgen_medium",
    "qwen1.5-110b": "qwen1p5_110b",
    "olmo-1b": "olmo_1b",
    "qwen3-0.6b": "qwen3_0p6b",
    "yi-6b": "yi_6b",
    "internvl2-2b": "internvl2_2b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f".{mod_name}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE_CONFIG


def all_archs() -> list[str]:
    return list(ALIASES.keys())


__all__ = [
    "ARCHS",
    "ALIASES",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSDConfig",
    "ShapeConfig",
    "SHAPES",
    "LONG_500K",
    "get_config",
    "get_smoke_config",
    "all_archs",
    "shape_applicable",
]

"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone (InternLM2-1.8B): 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192,
vocab=92553.  The InternViT frontend is a STUB per the assignment:
``input_specs()`` supplies 256 precomputed patch embeddings (B, 256, 2048)
which are projected and spliced over the first 256 positions.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    pattern=("attn",),
    vis_prefix_len=256,
    norm="rmsnorm",
    grad_accum={"train_4k": 4},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="internvl2-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    vis_prefix_len=8,
)

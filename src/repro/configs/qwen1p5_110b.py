"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-110B].

80L, d_model=8192, 64H (GQA kv=8), d_ff=49152, vocab=152064.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    remat_policy="none",
    optimizer="adamw_bf16",  # capacity: bf16 moments (DESIGN §5)
    grad_accum={"train_4k": 8},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="qwen1.5-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
)

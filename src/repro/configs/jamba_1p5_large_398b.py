"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L = 9×(1 attn + 7 mamba) super-blocks, d_model=8192, 64H (GQA kv=8),
d_ff=24576, vocab=65536, MoE 16 experts top-2 on every other layer.
Note: we use Mamba2 SSD blocks for the mamba layers (substrate-wide SSD
implementation; Jamba-1 used Mamba-1 — recorded deviation, DESIGN.md §9).
"""

import dataclasses

from .base import ModelConfig, MoEConfig, SSDConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=("attn",) + ("ssd",) * 7,
    ssd=SSDConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=8),
    moe=MoEConfig(
        n_experts=16,
        experts_per_token=2,
        d_expert=24576,
        moe_every=2,
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    remat_policy="none",
    optimizer="adamw_bf16",  # capacity: bf16 moments (DESIGN §5)
    grad_accum={"train_4k": 8},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="jamba-smoke",
    num_layers=8,  # one super-block
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    ssd=SSDConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=2, chunk=16),
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_expert=128, moe_every=2),
)

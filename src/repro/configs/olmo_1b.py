"""olmo-1b [dense] — non-parametric LN [arXiv:2402.00838].

16L, d_model=2048, 16H (MHA kv=16), d_ff=8192, vocab=50304.
OLMo uses non-parametric LayerNorm (no affine) and SwiGLU.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    pattern=("attn",),
    norm="nonparam_ln",
    tie_embeddings=True,
    grad_accum={"train_4k": 4},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="olmo-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
)

"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model=1024, 16H (GQA kv=8), d_expert=512, vocab=49155.
"""

import dataclasses

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=0,  # every FFN is MoE
    vocab_size=49155,
    pattern=("attn",),
    moe=MoEConfig(n_experts=32, experts_per_token=8, d_expert=512),
    tie_embeddings=True,
    norm="rmsnorm",
    grad_accum={"train_4k": 2},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="granite-moe-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    vocab_size=256,
    moe=MoEConfig(n_experts=4, experts_per_token=2, d_expert=32),
)

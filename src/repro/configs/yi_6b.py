"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652].

32L, d_model=4096, 32H (GQA kv=4), d_ff=11008, vocab=64000.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    pattern=("attn",),
    rope_theta=5_000_000.0,
    norm="rmsnorm",
    grad_accum={"train_4k": 4},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="yi-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
)

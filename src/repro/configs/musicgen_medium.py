"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

48L, d_model=1536, 24H (MHA), d_ff=6144, 4 codebooks × vocab 2048.
The EnCodec frontend is a stub per the assignment: the data pipeline feeds
token ids (B, S, 4); embeddings are the sum over codebooks and the head
emits 4×2048 logits.  MusicGen uses plain LayerNorm + GELU FFN.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    n_codebooks=4,
    norm="layernorm",
    act="gelu",
    grad_accum={"train_4k": 2},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="musicgen-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
)

"""Model / run configuration system.

One ``ModelConfig`` describes an architecture; the 10 assigned architectures
each get a module in this package exporting ``CONFIG`` (full size) and
``SMOKE_CONFIG`` (reduced, CPU-runnable).  ``ShapeConfig`` describes the
assigned input-shape cells (train / prefill / decode / long-context decode).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mla", "ssd"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """Mamba2 SSD block dims."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_expert: int  # per-expert ffn hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001  # load-balance loss
    moe_every: int = 1  # apply MoE FFN every k-th layer (others dense)
    first_k_dense: int = 0  # first k layers use dense FFN (DeepSeek)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # repeating mixer pattern, cycled over num_layers, e.g. ("attn",) or
    # ("attn",) + ("ssd",)*7  (Jamba 1:7)
    pattern: tuple[BlockKind, ...] = ("attn",)
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssd: SSDConfig | None = None
    mtp: bool = False  # DeepSeek multi-token-prediction aux module
    n_codebooks: int = 1  # MusicGen EnCodec codebooks
    vis_prefix_len: int = 0  # InternVL2 patch-embedding prefix positions
    dtype: str = "bfloat16"
    # training-side knobs (capacity engineering; see DESIGN.md §5)
    remat: bool = True
    remat_policy: str = "dots"  # dots | none (full remat; ≥100B archs)
    attn_chunk: int = 0  # 0 -> auto: chunked attention when seq > 8192
    optimizer: str = "adamw"  # adamw | adamw_bf16 | sgdm | adafactor
    grad_accum: dict[str, int] = dataclasses.field(default_factory=dict)  # per-shape

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron/MaxText practice) so
        the vocab dim always divides TP=16; padded logits are masked to -inf
        in the loss and in serving."""
        return -(-self.vocab_size // 128) * 128

    def block_kinds(self) -> list[BlockKind]:
        """Mixer kind for each of the num_layers layers."""
        p = self.pattern
        return [p[i % len(p)] for i in range(self.num_layers)]

    def layer_is_moe(self) -> list[bool]:
        if self.moe is None:
            return [False] * self.num_layers
        m = self.moe
        return [
            i >= m.first_k_dense and (i % m.moe_every == m.moe_every - 1 if m.moe_every > 1 else True)
            for i in range(self.num_layers)
        ]

    def layer_plan(self) -> list[tuple[BlockKind, bool]]:
        return list(zip(self.block_kinds(), self.layer_is_moe()))

    def segments(self) -> list[tuple[list[tuple[BlockKind, bool]], int]]:
        """Split layers into (super_block_plan, n_repeat) segments so each
        segment is a repetition of an identical super-block — the unit we
        ``lax.scan`` over (keeps HLO size ~O(pattern), not O(num_layers))."""
        plan = self.layer_plan()
        n = len(plan)
        segments: list[tuple[list[tuple[BlockKind, bool]], int]] = []
        i = 0
        while i < n:
            # pick the super-block with the most repetitions (that's what
            # minimizes HLO size: one scan body per segment), tie-breaking on
            # layers covered, then on shorter super-blocks
            best = None  # (reps, covered, -blk_len, block)
            for blk_len in range(1, min(16, n - i) + 1):
                block = plan[i : i + blk_len]
                reps = 1
                while plan[i + reps * blk_len : i + (reps + 1) * blk_len] == block:
                    reps += 1
                cand = (reps, blk_len * reps, -blk_len, block)
                if best is None or cand[:3] > best[:3]:
                    best = cand
            reps, covered, _, block = best
            segments.append((block, reps))
            i += covered
        return segments


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    subquadratic_only: bool = False  # long_500k: SSM/hybrid archs only


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode", subquadratic_only=True)

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}

#: Families whose decode state is sub-quadratic in context (may run long_500k)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch × shape) cell runs; (False, reason) for documented skips."""
    if shape.subquadratic_only and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (
            "pure full-attention arch: 524k-token decode requires sub-quadratic "
            "state (see DESIGN.md §Arch-applicability)"
        )
    return True, ""

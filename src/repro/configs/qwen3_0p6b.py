"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-0.6B].

28L, d_model=1024, 16H (GQA kv=8, head_dim=128 explicit), d_ff=3072,
vocab=151936.
"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,  # explicit (≠ d_model // heads), per the release
    d_ff=3072,
    vocab_size=151936,
    pattern=("attn",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    grad_accum={"train_4k": 4},
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="qwen3-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
)

"""Flight recorder: per-thread span/instant ring buffers + Perfetto export.

The stats counters (``core.stats``) answer "how much, on average"; they
cannot answer "what happened at t=3.2s when the pipeline hiccuped".  The
``Tracer`` is the timeline half of the visibility story (paper §5.4): every
instrumented subsystem — stage phases at chunk boundaries, queue waits,
straggler detach/resolve, shard fetches, hedges, circuit breakers, device
transfers, health transitions, chaos injections — appends events into a
bounded per-thread ring buffer, and the whole flight is exported as Chrome
Trace Event Format JSON, loadable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` with one track per thread.

Design constraints, in order:

1. **Disabled cost is one attribute check.**  Every call site is gated on
   ``tracer.enabled`` (instrumented objects bind their tracer once, at
   construction, defaulting to the module-level ``NULL_TRACER`` no-op);
   nothing else runs when tracing is off.  ``benchmarks/bench_trace.py``
   gates this at ≤1% on the engine passthrough workload.
2. **No new clock reads on hot paths.**  Spans at chunk boundaries and
   queue waits reuse the ``time.monotonic()`` readings the stats counters
   already paid for (``Tracer.complete`` takes ``t0``/``dur`` instead of
   reading clocks itself).
3. **No locks on the record path.**  Each thread appends to its own
   ``deque(maxlen=...)`` ring; the registry lock is taken once per thread
   (first event) and on export.  Ring bounds make a forgotten tracer a
   bounded-memory annoyance, not a leak.

Usage::

    tracer = Tracer()                      # or: with tracing() as tracer:
    set_tracer(tracer)                     # data-layer subsystems see it
    pipe = builder.build(trace=tracer)     # engine + queues see it
    ... run ...
    tracer.export("trace.json")            # open in ui.perfetto.dev
    tracer.export_jsonl("events.jsonl")    # structured log, one event/line
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from collections import deque
from typing import Any, Iterator

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "tracing",
]


class _NullSpan:
    """Reusable no-op context manager (shared singleton; no per-call alloc)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every method is a no-op, ``enabled`` is False.

    Instrumented code holds a reference to *some* tracer at all times (this
    one by default), so the hot-path guard is a single attribute check with
    no ``is None`` branching.
    """

    __slots__ = ()
    enabled = False

    def span(self, name: str, cat: str = "", args: dict | None = None) -> _NullSpan:
        return _NULL_SPAN

    def complete(
        self, name: str, cat: str, t0: float, dur: float, args: dict | None = None
    ) -> None:
        pass

    def instant(self, name: str, cat: str = "", args: dict | None = None) -> None:
        pass

    def counter(self, name: str, values: dict) -> None:
        pass

    def events(self) -> list:
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict | None):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc: object) -> bool:
        t1 = time.monotonic()
        self._tracer.complete(self._name, self._cat, self._t0, t1 - self._t0, self._args)
        return False


class Tracer:
    """Flight recorder with one bounded event ring per thread.

    Events are 6-tuples ``(ph, name, cat, ts, dur, args)`` with ``ts``/
    ``dur`` in *seconds* on the monotonic clock (converted to Chrome's
    microseconds at export).  ``ph`` follows the Chrome Trace Event Format:
    ``"X"`` complete span, ``"i"`` instant, ``"C"`` counter.
    """

    def __init__(self, capacity_per_thread: int = 65536):
        if capacity_per_thread <= 0:
            raise ValueError("capacity_per_thread must be > 0")
        self.enabled = True
        self.capacity = int(capacity_per_thread)
        self.pid = os.getpid()
        self._epoch = time.monotonic()
        self._local = threading.local()
        self._lock = threading.Lock()
        # [(tid, thread_name, ring)] — grows by one entry per thread that
        # ever records; rings persist so a finished worker's track survives
        self._buffers: list[tuple[int, str, deque]] = []

    # -- recording (hot path) -------------------------------------------
    def _ring(self) -> deque:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            t = threading.current_thread()
            ring = deque(maxlen=self.capacity)
            with self._lock:
                self._buffers.append((t.ident or 0, t.name, ring))
            self._local.ring = ring
        return ring

    def complete(
        self, name: str, cat: str, t0: float, dur: float, args: dict | None = None
    ) -> None:
        """Record a finished span from clock readings the caller already has
        (``t0`` monotonic seconds, ``dur`` seconds) — zero extra clock reads."""
        if self.enabled:
            self._ring().append(("X", name, cat, t0, dur, args))

    def instant(self, name: str, cat: str = "", args: dict | None = None) -> None:
        if self.enabled:
            self._ring().append(("i", name, cat, time.monotonic(), 0.0, args))

    def counter(self, name: str, values: dict) -> None:
        """Record a counter sample (rendered as a stacked chart in Perfetto)."""
        if self.enabled:
            self._ring().append(("C", name, "counter", time.monotonic(), 0.0, dict(values)))

    def span(self, name: str, cat: str = "", args: dict | None = None):
        """``with tracer.span("fetch", "shard"): ...`` — measures its own
        clocks; use ``complete()`` where the caller already read them."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    # -- draining ---------------------------------------------------------
    def _snapshots(self) -> list[tuple[int, str, list]]:
        with self._lock:
            buffers = list(self._buffers)
        out = []
        for tid, tname, ring in buffers:
            for _ in range(8):
                try:
                    evs = list(ring)
                    break
                except RuntimeError:  # ring mutated mid-copy by its owner
                    continue
            else:  # pragma: no cover - pathological contention
                evs = []
            out.append((tid, tname, evs))
        return out

    def events(self) -> list[dict]:
        """All recorded events as Chrome Trace Event dicts, sorted by ts."""
        epoch = self._epoch
        rows: list[dict] = []
        for tid, tname, evs in self._snapshots():
            for ph, name, cat, ts, dur, args in evs:
                ev: dict[str, Any] = {
                    "ph": ph,
                    "name": name,
                    "cat": cat or "repro",
                    "ts": (ts - epoch) * 1e6,
                    "pid": self.pid,
                    "tid": tid,
                }
                if ph == "X":
                    ev["dur"] = dur * 1e6
                elif ph == "i":
                    ev["s"] = "t"  # thread-scoped instant
                if args:
                    ev["args"] = args
                rows.append(ev)
        rows.sort(key=lambda e: e["ts"])
        return rows

    def clear(self) -> None:
        """Drop all recorded events (rings stay registered to their threads)."""
        with self._lock:
            buffers = list(self._buffers)
        for _tid, _tname, ring in buffers:
            ring.clear()  # deque.clear is atomic under the GIL

    def __len__(self) -> int:
        return sum(len(evs) for _, _, evs in self._snapshots())

    # -- export -----------------------------------------------------------
    def to_chrome(self) -> dict:
        """The trace as a Chrome Trace Event Format object: metadata events
        naming each thread track, then the data events."""
        meta: list[dict] = [
            {
                "ph": "M",
                "name": "process_name",
                "pid": self.pid,
                "tid": 0,
                "args": {"name": "repro-pipeline"},
            }
        ]
        for tid, tname, _evs in self._snapshots():
            meta.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": self.pid,
                    "tid": tid,
                    "args": {"name": tname},
                }
            )
        return {"traceEvents": meta + self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write Chrome Trace Event JSON; open in ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=repr)
        return path

    def export_jsonl(self, path: str) -> str:
        """Structured event log: one JSON object per line (for grep/jq and
        log shippers — same events, no Chrome framing)."""
        by_tid = {tid: tname for tid, tname, _ in self._snapshots()}
        with open(path, "w") as f:
            for ev in self.events():
                row = dict(ev)
                row["thread"] = by_tid.get(ev["tid"], "")
                f.write(json.dumps(row, default=repr) + "\n")
        return path


# -- module-level active tracer (the data-layer default) -------------------
#
# Subsystems not built by PipelineBuilder (shard prefetcher, peer sources,
# device transfer, health monitor, chaos stages) resolve their tracer from
# here at call time; ``build(trace=...)`` wires the engine/queue side
# explicitly.  Install with ``set_tracer`` or the ``tracing()`` context
# manager to capture every subsystem at once.
_active: Tracer | NullTracer = NULL_TRACER


def get_tracer() -> Tracer | NullTracer:
    """The installed process-wide tracer (``NULL_TRACER`` when off)."""
    return _active


def set_tracer(tracer: Tracer | NullTracer | None) -> Tracer | NullTracer:
    """Install ``tracer`` process-wide; returns the previous one.
    ``None`` uninstalls (restores the no-op)."""
    global _active
    prev = _active
    _active = tracer if tracer is not None else NULL_TRACER
    return prev


@contextlib.contextmanager
def tracing(
    tracer: Tracer | None = None, *, capacity_per_thread: int = 65536
) -> Iterator[Tracer]:
    """Install a tracer for the duration of the block::

        with tracing() as tracer:
            run_pipeline()
        tracer.export("trace.json")
    """
    t = tracer if tracer is not None else Tracer(capacity_per_thread)
    prev = set_tracer(t)
    try:
        yield t
    finally:
        set_tracer(prev)

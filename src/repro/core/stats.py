"""Per-stage visibility (paper §5.4 "Visibility").

Every stage keeps cheap monotonic-clock counters: items in/out, failures,
task latency, and how long tasks were blocked putting into a full output
queue (the backpressure signal) or waiting on an empty input queue (the
starvation signal).  ``Pipeline.stats()`` snapshots them; ``format_stats``
renders the dashboard used to find the bottleneck stage.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time

#: Cap on distinct exception types tracked per stage; further types fold
#: into the ``"_other"`` bucket so a pathological error stream cannot grow
#: the counter without bound.
MAX_ERROR_TYPES = 16


@dataclasses.dataclass
class StageStats:
    """Mutable counters for one stage. Updated from the event-loop thread."""

    name: str
    concurrency: int = 1
    chunk: int = 1  # items per executor dispatch (1 = per-item path)
    chunkable: bool = False  # sync pipe stage: chunk= would be accepted
    num_in: int = 0  # items pulled from the input queue
    num_out: int = 0  # items emitted to the output queue
    num_failed: int = 0
    # straggler slow lane (chunked stages with straggler_after): items
    # detached past the soft deadline, seconds those items ran in total,
    # and detach candidates that had to run inline because the straggler
    # pool was saturated (no deadline protection for those)
    stragglers: int = 0
    straggler_time: float = 0.0
    straggler_shed: int = 0
    task_time: float = 0.0  # seconds spent inside the stage function
    get_wait: float = 0.0  # seconds blocked waiting for input (starved)
    put_wait: float = 0.0  # seconds blocked waiting for output space (backpressured)
    first_out_t: float | None = None  # monotonic time of first emitted item
    last_error: str | None = None
    # bounded per-exception-type failure counts (``last_error`` keeps only
    # the most recent repr; this keeps the distribution)
    errors_by_type: dict[str, int] = dataclasses.field(default_factory=dict)
    arena: object | None = None  # SlabArena of an aggregate_into stage, if any
    cache: object | None = None  # shard cache/prefetcher probed by this stage
    _t_start: float = dataclasses.field(default_factory=time.monotonic)

    # -- recording ---------------------------------------------------------
    def record_task(self, dt: float) -> None:
        self.task_time += dt

    def record_out(self) -> None:
        self.num_out += 1
        if self.first_out_t is None:
            self.first_out_t = time.monotonic()

    def record_out_many(self, n: int) -> None:
        """Batched ``record_out`` — one call per chunk, not per item."""
        if n <= 0:
            return
        self.num_out += n
        if self.first_out_t is None:
            self.first_out_t = time.monotonic()

    def record_failure(self, err: BaseException) -> None:
        self.num_failed += 1
        self.last_error = repr(err)
        etype = type(err).__name__
        if etype not in self.errors_by_type and len(self.errors_by_type) >= MAX_ERROR_TYPES:
            etype = "_other"
        self.errors_by_type[etype] = self.errors_by_type.get(etype, 0) + 1

    # -- derived -----------------------------------------------------------
    @property
    def elapsed(self) -> float:
        return time.monotonic() - self._t_start

    @property
    def qps(self) -> float:
        return self.num_out / self.elapsed if self.elapsed > 0 else 0.0

    @property
    def avg_task_time(self) -> float:
        n = self.num_out + self.num_failed
        return self.task_time / n if n else 0.0

    @property
    def occupancy(self) -> float:
        """Fraction of wall time the stage's workers were busy (per-worker)."""
        if self.elapsed <= 0 or self.concurrency <= 0:
            return 0.0
        return self.task_time / (self.elapsed * self.concurrency)

    def snapshot(self) -> "StageStatsSnapshot":
        cache = self.cache.stats() if self.cache is not None else {}
        ttfi = (
            self.first_out_t - self._t_start if self.first_out_t is not None else None
        )
        return StageStatsSnapshot(
            name=self.name,
            concurrency=self.concurrency,
            chunk=self.chunk,
            chunkable=self.chunkable,
            num_in=self.num_in,
            num_out=self.num_out,
            num_failed=self.num_failed,
            stragglers=self.stragglers,
            straggler_time=self.straggler_time,
            straggler_shed=self.straggler_shed,
            qps=self.qps,
            avg_task_time=self.avg_task_time,
            occupancy=self.occupancy,
            get_wait=self.get_wait,
            put_wait=self.put_wait,
            last_error=self.last_error,
            task_time=self.task_time,
            elapsed=self.elapsed,
            time_to_first_s=ttfi,
            errors_by_type=tuple(sorted(self.errors_by_type.items())),
            bytes_allocated=getattr(self.arena, "bytes_allocated", 0),
            slabs_in_flight=(
                self.arena.slabs_in_flight if self.arena is not None else 0
            ),
            num_slabs=getattr(self.arena, "num_slabs", 0),
            cache_hits=int(cache.get("hits", 0)),
            cache_misses=int(cache.get("misses", 0)),
            cache_evictions=int(cache.get("evictions", 0)),
            bytes_cached=int(cache.get("bytes_cached", 0)),
            prefetch_depth=int(cache.get("prefetch_depth", 0)),
            bytes_fetched=int(cache.get("bytes_fetched", 0)),
            bytes_skipped=int(cache.get("bytes_skipped", 0)),
            fields_requested=int(cache.get("fields_requested", 0)),
            source_errors=int(cache.get("source_errors", 0)),
            source_retries=int(cache.get("source_retries", 0)),
            promotions=int(cache.get("promotions", 0)),
            peer_hits=int(cache.get("source_peer_hits", 0)),
            peer_bytes=int(cache.get("source_peer_bytes", 0)),
            origin_bytes=int(cache.get("source_origin_bytes", 0)),
            device_decode_ms=float(cache.get("device_decode_ms", 0.0)),
            device_decode_batches=int(cache.get("device_decode_batches", 0)),
        )


@dataclasses.dataclass(frozen=True)
class StageStatsSnapshot:
    name: str
    concurrency: int
    num_in: int
    num_out: int
    num_failed: int
    qps: float
    avg_task_time: float
    occupancy: float
    get_wait: float
    put_wait: float
    last_error: str | None
    # cumulative task seconds + stage uptime: the pair windowed-rate math
    # (``core.metrics.StatsHistory``) needs that the derived qps/occupancy
    # averages destroy
    task_time: float = 0.0
    elapsed: float = 0.0
    # seconds from stage start to its first emitted item (the paper's
    # first-batch-latency signal); None until something came out
    time_to_first_s: float | None = None
    # bounded per-exception-type failure counts, as sorted (type, n) pairs
    errors_by_type: tuple[tuple[str, int], ...] = ()
    # chunked execution: items per executor dispatch (1 = per-item path),
    # and whether chunk= is even applicable (sync pipe stage)
    chunk: int = 1
    chunkable: bool = False
    # straggler slow lane: deadline-detached items, their total run time,
    # and detach candidates shed to inline execution (pool saturated)
    stragglers: int = 0
    straggler_time: float = 0.0
    straggler_shed: int = 0
    # memory pressure (nonzero only for arena-backed aggregate_into stages)
    bytes_allocated: int = 0
    slabs_in_flight: int = 0
    num_slabs: int = 0
    # shard-cache visibility (nonzero only for stages with a cache probe)
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    bytes_cached: int = 0
    prefetch_depth: int = 0
    # remote-source visibility: wire bytes downloaded, and the retry/error
    # counters a RetryingSource-wrapped backend reports (0 for local/simulated)
    bytes_fetched: int = 0
    source_errors: int = 0
    source_retries: int = 0
    # columnar projection visibility (format v2 shards read with fields=...):
    # wire bytes the projection avoided fetching, and how many distinct
    # field names consumers have asked this prefetcher for
    bytes_skipped: int = 0
    fields_requested: int = 0
    # peer-exchange visibility (nonzero only behind a peer.TieredSource):
    # fetches answered by warm peer ranks vs bytes that had to come from the
    # origin object store, plus sparse→full cache promotions
    promotions: int = 0
    peer_hits: int = 0
    peer_bytes: int = 0
    origin_bytes: int = 0
    # consumer/device boundary visibility: chunks the consumer pulled via
    # the chunked sink drain (``Pipeline.get_items``; rides the terminal
    # stage's row), and the on-chip fused-decode dispatch cost a
    # ``DeviceTransfer(device_decode=...)`` stage reports via its probe
    sink_drained_chunks: int = 0
    device_decode_ms: float = 0.0
    device_decode_batches: int = 0


def format_stats(snaps: list[StageStatsSnapshot], window=None) -> str:
    """Render the visibility dashboard.

    A stage with high ``put_wait`` is backpressured (downstream is the
    bottleneck); a stage with high ``get_wait`` is starved (upstream is the
    bottleneck); the bottleneck stage itself shows high occupancy and low
    waits.  ``ttfi_ms`` is time-to-first-item — the paper's first-batch
    latency signal, per stage.

    ``window`` (a ``StatsHistory.window()`` result: ``{stage: WindowRates}``)
    adds *current* rate columns next to the lifetime averages — ``qps_w`` /
    ``occ_w%`` are the trailing-window values, which is what "is it slow
    NOW" questions need (the lifetime ``qps`` column averages over the
    whole run).
    """
    windowed = window or {}
    hdr = (
        f"{'stage':<24}{'conc':>5}{'in':>9}{'out':>9}{'fail':>6}"
        f"{'qps':>10}{'task_ms':>9}{'occ%':>6}{'get_w':>8}{'put_w':>8}"
        f"{'ttfi_ms':>9}"
    )
    if windowed:
        hdr += f"{'qps_w':>10}{'occ_w%':>7}"
    lines = [hdr, "-" * len(hdr)]
    for s in snaps:
        ttfi = f"{s.time_to_first_s * 1e3:>9.1f}" if s.time_to_first_s is not None else f"{'-':>9}"
        line = (
            f"{s.name:<24}{s.concurrency:>5}{s.num_in:>9}{s.num_out:>9}"
            f"{s.num_failed:>6}{s.qps:>10.1f}{s.avg_task_time * 1e3:>9.2f}"
            f"{s.occupancy * 100:>6.1f}{s.get_wait:>8.2f}{s.put_wait:>8.2f}"
            f"{ttfi}"
        )
        if windowed:
            w = windowed.get(s.name)
            if w is not None:
                line += f"{w.qps:>10.1f}{w.occupancy * 100:>7.1f}"
            else:
                line += f"{'-':>10}{'-':>7}"
        lines.append(line)
    for s in snaps:
        if s.errors_by_type:
            kinds = " ".join(f"{t}={n}" for t, n in s.errors_by_type)
            lines.append(f"[{s.name}] errors: {kinds} last={s.last_error}")
        if s.stragglers or s.straggler_shed:
            avg = s.straggler_time / s.stragglers * 1e3 if s.stragglers else 0.0
            lines.append(
                f"[{s.name}] stragglers: detached={s.stragglers}"
                f" avg_ms={avg:.1f} shed={s.straggler_shed}"
            )
        if s.num_slabs:
            lines.append(
                f"[{s.name}] arena: slabs_in_flight={s.slabs_in_flight}/{s.num_slabs}"
                f" bytes_allocated={s.bytes_allocated / 2**20:.1f}MB"
            )
        if s.device_decode_batches or s.device_decode_ms:
            avg = (
                s.device_decode_ms / s.device_decode_batches
                if s.device_decode_batches
                else 0.0
            )
            lines.append(
                f"[{s.name}] device-decode: batches={s.device_decode_batches}"
                f" dispatch_ms={s.device_decode_ms:.1f} avg_ms={avg:.2f}"
            )
        if s.sink_drained_chunks:
            items = s.num_out / s.sink_drained_chunks
            lines.append(
                f"[{s.name}] sink: drained_chunks={s.sink_drained_chunks}"
                f" avg_items/chunk={items:.1f}"
            )
        if s.cache_hits or s.cache_misses or s.prefetch_depth:
            total = s.cache_hits + s.cache_misses
            rate = s.cache_hits / total if total else 0.0
            line = (
                f"[{s.name}] shard-cache: hits={s.cache_hits} misses={s.cache_misses}"
                f" ({rate * 100:.0f}% hit) evictions={s.cache_evictions}"
                f" cached={s.bytes_cached / 2**20:.1f}MB"
                f" prefetch_depth={s.prefetch_depth}"
            )
            if s.bytes_fetched:
                line += f" fetched={s.bytes_fetched / 2**20:.1f}MB"
            if s.bytes_skipped or s.fields_requested:
                line += (
                    f" skipped={s.bytes_skipped / 2**20:.1f}MB"
                    f" fields={s.fields_requested}"
                )
            if s.promotions:
                line += f" promotions={s.promotions}"
            if s.source_errors or s.source_retries:
                line += f" src_errors={s.source_errors} src_retries={s.source_retries}"
            lines.append(line)
            if s.peer_hits or s.peer_bytes or s.origin_bytes:
                lines.append(
                    f"[{s.name}] peers: peer_hits={s.peer_hits}"
                    f" peer_bytes={s.peer_bytes / 2**20:.1f}MB"
                    f" origin_bytes={s.origin_bytes / 2**20:.1f}MB"
                )
    return "\n".join(lines)


class ResourceSampler:
    """Background sampler of process CPU time and RSS (for the paper's
    Fig 6/7-style resource benchmarks).  Samples from /proc/self."""

    def __init__(self, interval: float = 0.2):
        self.interval = interval
        self.samples: list[tuple[float, float, int]] = []  # (t, cpu_s, rss_bytes)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    @staticmethod
    def _read() -> tuple[float, int]:
        with open("/proc/self/stat") as f:
            parts = f.read().split()
        try:
            tick = float(os.sysconf("SC_CLK_TCK")) or 100.0
        except (ValueError, OSError, AttributeError):
            tick = 100.0  # USER_HZ default when sysconf can't say
        cpu_s = (int(parts[13]) + int(parts[14])) / tick  # utime + stime
        with open("/proc/self/statm") as f:
            rss_pages = int(f.read().split()[1])
        try:
            page = os.sysconf("SC_PAGE_SIZE") or 4096
        except (ValueError, OSError, AttributeError):
            page = 4096
        return cpu_s, rss_pages * page

    def current(self) -> tuple[float, int]:
        """Latest ``(cpu_seconds, rss_bytes)`` — the newest background
        sample, or a fresh /proc read when the sampler is not running
        (this is what the ``/metrics`` exporter scrapes)."""
        if self.samples:
            _t, cpu, rss = self.samples[-1]
            return cpu, rss
        return self._read()

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            cpu, rss = self._read()
            self.samples.append((time.monotonic(), cpu, rss))

    def __enter__(self) -> "ResourceSampler":
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True, name="rsrc-sampler")
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()

    def summary(self) -> dict[str, float]:
        if len(self.samples) < 2:
            cpu, rss = self._read()
            return {"cpu_util": 0.0, "peak_rss_mb": rss / 2**20, "avg_rss_mb": rss / 2**20}
        (t0, c0, _), (t1, c1, _) = self.samples[0], self.samples[-1]
        rss = [s[2] for s in self.samples]
        return {
            "cpu_util": (c1 - c0) / (t1 - t0) if t1 > t0 else 0.0,
            "peak_rss_mb": max(rss) / 2**20,
            "avg_rss_mb": sum(rss) / len(rss) / 2**20,
        }

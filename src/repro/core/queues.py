"""Bounded, instrumented inter-stage queues (paper §5.5.3).

Stages communicate exclusively through bounded ``asyncio.Queue``s.  A full
output queue blocks the producing task, so congestion propagates from the
sink (the training loop) upstream to the source, and resolves from the sink
downward as soon as the consumer drains one item — the paper's backpressure
mechanism.  The wrapper records how long producers/consumers were blocked;
those two numbers are the core of the visibility story.
"""

from __future__ import annotations

import asyncio
import time
from typing import Any

from .stats import StageStats
from .trace import NULL_TRACER


class _Sentinel:
    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{self.name}>"


#: End-of-stream marker.  Exactly one EOF traverses each queue, placed by a
#: stage after all of its in-flight tasks completed.
EOF = _Sentinel("EOF")


class MonitoredQueue:
    """A bounded asyncio.Queue that attributes blocking time to stages.

    ``put`` blocking is charged to the *producer* stage (backpressure);
    ``get`` blocking is charged to the *consumer* stage (starvation).

    Blocking waits are also recorded as tracer spans (category ``queue``,
    track = the scheduler thread) — only the blocking branch pays; the
    non-blocking fast path stays untouched and the clock readings are the
    ones the wait counters already took.
    """

    def __init__(self, maxsize: int, name: str = "q", tracer=None):
        self._q: asyncio.Queue[Any] = asyncio.Queue(maxsize)
        self.name = name
        self.producer_stats: StageStats | None = None
        self.consumer_stats: StageStats | None = None
        self.tracer = tracer if tracer is not None else NULL_TRACER

    # ------------------------------------------------------------------
    async def put(self, item: Any) -> None:
        if self._q.full():
            t0 = time.monotonic()
            await self._q.put(item)
            dt = time.monotonic() - t0
            if self.producer_stats is not None:
                self.producer_stats.put_wait += dt
            if self.tracer.enabled:
                self.tracer.complete(f"put_wait {self.name}", "queue", t0, dt)
        else:
            self._q.put_nowait(item)

    async def get(self) -> Any:
        if self._q.empty():
            t0 = time.monotonic()
            item = await self._q.get()
            dt = time.monotonic() - t0
            if self.consumer_stats is not None:
                self.consumer_stats.get_wait += dt
            if self.tracer.enabled:
                self.tracer.complete(f"get_wait {self.name}", "queue", t0, dt)
        else:
            item = self._q.get_nowait()
        if self.consumer_stats is not None and item is not EOF:
            self.consumer_stats.num_in += 1
        return item

    async def get_many(self, max_items: int) -> list[Any]:
        """Pull up to ``max_items`` items in ONE event-loop hop.

        This is the chunked-execution primitive — chunked pipe stages,
        aggregate stages, and the consumer-side sink drain
        (``Pipeline.get_items``) all pull through it: blocking (and the
        get_wait charge) happens only for the *first* item; everything
        already buffered is drained without touching the loop again, so the
        per-item hop cost is amortized over the chunk.  A chunk is never
        awaited full: whatever is available now is returned (latency over
        batching).  ``EOF`` is only ever the LAST element of the returned
        list — nothing follows it on the wire, and nothing is consumed
        past it.  Cancellation while awaiting the first item strands
        nothing: the sweep phase never awaits, so a cancelled ``get_many``
        has consumed either zero items or the list it returns.
        """
        if self._q.empty():
            t0 = time.monotonic()
            item = await self._q.get()
            dt = time.monotonic() - t0
            if self.consumer_stats is not None:
                self.consumer_stats.get_wait += dt
            if self.tracer.enabled:
                self.tracer.complete(f"get_wait {self.name}", "queue", t0, dt)
        else:
            item = self._q.get_nowait()
        out = [item]
        while item is not EOF and len(out) < max_items and not self._q.empty():
            item = self._q.get_nowait()
            out.append(item)
        if self.consumer_stats is not None:
            n = len(out) - (1 if out[-1] is EOF else 0)
            self.consumer_stats.num_in += n
        return out

    async def put_many(self, items: list[Any]) -> None:
        """Put a chunk of items, awaiting only while the queue is full.

        The fast path is pure ``put_nowait`` — zero awaits for a chunk that
        fits, versus one loop hop per item on the scalar path.  Blocking on
        a full queue is still per-item (that is the backpressure working,
        and it is charged to the producer as ``put_wait``).
        """
        for item in items:
            if self._q.full():
                t0 = time.monotonic()
                await self._q.put(item)
                dt = time.monotonic() - t0
                if self.producer_stats is not None:
                    self.producer_stats.put_wait += dt
                if self.tracer.enabled:
                    self.tracer.complete(f"put_wait {self.name}", "queue", t0, dt)
            else:
                self._q.put_nowait(item)

    # non-blocking helpers used by the pipeline runner -------------------
    def put_nowait_force(self, item: Any) -> None:
        """Best-effort put that never blocks (used to flush EOF on failure)."""
        try:
            self._q.put_nowait(item)
        except asyncio.QueueFull:
            # Drop one item to make room for the sentinel; the pipeline is
            # tearing down anyway.
            try:
                self._q.get_nowait()
            except asyncio.QueueEmpty:  # pragma: no cover - race safety
                pass
            self._q.put_nowait(item)

    def qsize(self) -> int:
        return self._q.qsize()

    @property
    def maxsize(self) -> int:
        return self._q.maxsize

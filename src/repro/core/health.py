"""Pipeline health: stall detection + graceful degradation.

The engine's own backstops (per-item timeouts, the whole-chunk hang budget,
the straggler slow lane) only fire *inside* a stage function.  A pipeline
can still stop making progress with every backstop disarmed — a source
blocked on a dead socket, an untimed stage stuck in C code, a peer fleet
timing out every fetch.  The consumer then blocks in ``get_item`` forever
with no exception to catch and no thread to look at.

``HealthMonitor`` closes that gap from the *consumer* side: it derives a
HEALTHY / DEGRADED / STALLED state per stage from successive
``Pipeline.stats()`` snapshots (progress = ``num_out + num_failed`` delta —
a stage skipping bad items is making progress), sheds optional work while
degraded, and raises a structured ``PipelineStalled`` naming the suspect
stage instead of letting the consumer hang.  The snapshots ride a
``core.metrics.StatsHistory`` (one ring shared with dashboards and the
``/metrics`` exporter): every ``observe()`` appends a sample, so guarding
a pipeline gives you its windowed rates for free via
``monitor.history.window(...)``; state *transitions* are also recorded as
tracer instants (category ``health``) when a process-wide tracer is
installed.

It is deliberately *not* a background thread: ``observe()`` is cheap (one
stats snapshot) and is driven by the consumer's own cadence — either
explicit ``observe()``/``check()`` calls, or the ``guard()`` iterator that
wraps ``get_item`` with a timeout tick.  No new threads, no new races, and
a paused consumer cannot be spuriously diagnosed as a stalled pipeline.

Graceful degradation: a DEGRADED pipeline (some stage quiet for
``degraded_after_s`` with work pending) starts shedding *optional* work —
correctness stays, opportunistic throughput features go.  Degrade actions
form a one-way escalation ladder: each ``escalate_every_s`` of continued
degradation applies the next rung.  The stock rungs:

* ``disable_verify(prefetcher)`` — stop eager CRC verification on shard
  install (per-sample lazy CRC still protects reads);
* ``widen_sparse_threshold(prefetcher, factor)`` — prefer sparse/partial
  shard fetches to whole-shard downloads, cutting bytes on the wire;
* ``shrink_replication(tiered)`` — serve each shard from its ring owner
  only (skip replica probes): keeps the peer tier but halves its
  per-request fan-out — the rung *between* widening sparse fetches and
  giving up on peers entirely;
* ``origin_only(tiered)`` — stop consulting the peer tier entirely
  (``TieredSource.disable_peers``) when the fleet itself is the suspect.

Example::

    monitor = HealthMonitor(
        pipeline,
        degraded_after_s=5.0,
        stalled_after_s=60.0,
        actions=[disable_verify(pf), origin_only(tiered)],
    )
    with pipeline.auto_stop():
        for batch in monitor.guard():
            train_step(batch)          # raises PipelineStalled, never hangs
"""

from __future__ import annotations

import enum
import logging
import time
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Iterator

from . import trace as _trace
from .errors import PipelineStalled
from .metrics import StatsHistory

logger = logging.getLogger("repro.core")


class StageHealth(enum.Enum):
    """Per-stage (and overall) health state, worst-of across stages."""

    HEALTHY = "healthy"
    DEGRADED = "degraded"  # work pending, no progress for degraded_after_s
    STALLED = "stalled"  # work pending, no progress for stalled_after_s

    def __lt__(self, other: "StageHealth") -> bool:
        order = [StageHealth.HEALTHY, StageHealth.DEGRADED, StageHealth.STALLED]
        return order.index(self) < order.index(other)


class DegradeAction:
    """One rung of the degradation ladder: a named, idempotent, one-way
    shed of optional work.  ``apply()`` swallows and logs exceptions — a
    broken degrade hook must never take down an already-struggling
    pipeline."""

    def __init__(self, name: str, fn: Callable[[], Any]):
        self.name = name
        self._fn = fn
        self.applied = False

    def apply(self) -> None:
        if self.applied:
            return
        self.applied = True
        try:
            self._fn()
            logger.warning("pipeline degraded: applied %r", self.name)
        except Exception:  # noqa: BLE001 - degrade hooks are best-effort
            logger.exception("degrade action %r failed (ignored)", self.name)


def disable_verify(prefetcher) -> DegradeAction:
    """Shed eager CRC verification on shard install (lazy per-sample CRC
    on the read path still catches corruption where it matters)."""

    def fn() -> None:
        prefetcher.verify_on_install = False

    return DegradeAction("disable_verify", fn)


def widen_sparse_threshold(prefetcher, factor: float = 4.0) -> DegradeAction:
    """Prefer sparse fetches: multiply the prefetcher's whole-shard
    threshold so fewer reads trigger full-shard downloads — less wire
    pressure while the fetch path is struggling."""

    def fn() -> None:
        prefetcher.sparse_threshold = float(prefetcher.sparse_threshold) * factor

    return DegradeAction(f"widen_sparse_threshold(x{factor:g})", fn)


def shrink_replication(tiered) -> DegradeAction:
    """Serve each shard from its consistent-hash owner only — replica
    probes are opportunistic work worth shedding before abandoning the
    peer tier altogether.  Accepts a ``TieredSource`` (delegates to its
    peer tier) or a ``PeerShardSource`` directly; a no-op ladder rung for
    round-robin placement (it has no replicas to shed)."""

    target = getattr(tiered, "peers", tiered)
    return DegradeAction("shrink_replication", target.shrink_replication)


def origin_only(tiered) -> DegradeAction:
    """Stop consulting the peer tier (``TieredSource.disable_peers``) —
    for when peer timeouts/errors are the suspected drag."""

    return DegradeAction("origin_only", tiered.disable_peers)


class HealthMonitor:
    """Consumer-driven pipeline health state machine.

    Progress per stats row is ``num_out + num_failed`` (failing forward is
    still forward).  A stage is suspect only while it *holds* work
    (``num_in`` exceeds what it has disposed of) or is the source of a
    silent pipeline — a stage that is merely finished is healthy.

    ``observe()`` returns the overall ``StageHealth`` (worst across
    stages) and applies the next degrade rung when the pipeline has been
    continuously degraded for another ``escalate_every_s``.  ``check()``
    additionally raises ``PipelineStalled`` on STALLED.  ``guard()`` wraps
    the two around ``Pipeline.get_item`` as an iterator.
    """

    def __init__(
        self,
        pipeline,
        *,
        degraded_after_s: float = 5.0,
        stalled_after_s: float = 30.0,
        actions: list[DegradeAction] | tuple = (),
        escalate_every_s: float | None = None,
        clock: Callable[[], float] = time.monotonic,
        history: StatsHistory | None = None,
    ):
        if degraded_after_s <= 0 or stalled_after_s <= 0:
            raise ValueError("health thresholds must be > 0 seconds")
        if stalled_after_s < degraded_after_s:
            raise ValueError("stalled_after_s must be >= degraded_after_s")
        self.pipeline = pipeline
        self.degraded_after_s = degraded_after_s
        self.stalled_after_s = stalled_after_s
        self.actions = list(actions)
        self.escalate_every_s = (
            escalate_every_s if escalate_every_s is not None else degraded_after_s
        )
        self._clock = clock
        # the time series this monitor reads (and feeds): progress-change
        # ledger + windowed rates live here, shared with dashboards/exporters
        self.history = (
            history
            if history is not None
            else StatsHistory(pipeline, clock=clock)
        )
        self._t_last_action: float | None = None
        self._states: dict[str, StageHealth] = {}
        # True when the last STALLED verdict came from the whole-pipeline
        # sentinel (no individual row stalled): duration reporting must then
        # use the sentinel's quiet time, not any single row's.
        self._sentinel_stall = False

    # -- state derivation ---------------------------------------------------
    def observe(self) -> StageHealth:
        """Append a sample to the history, update per-stage states, fire
        degrade rungs.  Returns the overall health (worst across stages)."""
        now = self._clock()
        snaps = self.history.sample(now=now)
        states: dict[str, StageHealth] = {}
        worst = StageHealth.HEALTHY
        finished = bool(getattr(self.pipeline, "finished", False))
        any_progress = False
        for i, s in enumerate(snaps):
            quiet = self.history.quiet_for(i, now=now)
            if quiet == 0.0:
                any_progress = True
            # a quiet stage is only suspect while it HOLDS work: items in
            # that it has neither emitted nor failed.  (The first stage of
            # a fused runtime owns the runtime's input accounting, so this
            # covers fused stages too.)
            pending = s.num_in > s.num_out + s.num_failed
            state = StageHealth.HEALTHY
            if pending and not finished:
                if quiet >= self.stalled_after_s:
                    state = StageHealth.STALLED
                elif quiet >= self.degraded_after_s:
                    state = StageHealth.DEGRADED
            states[s.name] = state
            if worst < state:
                worst = state
        # a fully-quiet pipeline with nothing visibly pending is still a
        # stall from the consumer's seat (e.g. the SOURCE is stuck, so no
        # stage ever shows pending work) — track whole-pipeline quiet via a
        # sentinel row keyed past the real ones
        quiet_all = self.history.quiet_for(-1, now=now)
        self._sentinel_stall = False
        if not finished and not any_progress and worst is StageHealth.HEALTHY:
            # no stage shows pending work, so the source is the suspect
            src_name = snaps[0].name if snaps else "pipeline"
            if quiet_all >= self.stalled_after_s:
                states[src_name] = StageHealth.STALLED
                worst = StageHealth.STALLED
                self._sentinel_stall = True
            elif quiet_all >= self.degraded_after_s:
                states[src_name] = StageHealth.DEGRADED
                worst = StageHealth.DEGRADED
        tracer = _trace.get_tracer()
        if tracer.enabled:
            for name, state in states.items():
                if self._states.get(name, StageHealth.HEALTHY) is not state:
                    tracer.instant(
                        f"health:{name}", "health", {"state": state.value}
                    )
        self._states = states
        if worst != StageHealth.HEALTHY:
            self._maybe_escalate(now)
        else:
            self._t_last_action = None  # a recovery re-arms the first delay
        return worst

    def _maybe_escalate(self, now: float) -> None:
        nxt = next((a for a in self.actions if not a.applied), None)
        if nxt is None:
            return
        if self._t_last_action is None or (
            now - self._t_last_action >= self.escalate_every_s
        ):
            self._t_last_action = now
            nxt.apply()

    # -- queries ------------------------------------------------------------
    def stage_states(self) -> dict[str, StageHealth]:
        """Per-stage states as of the last ``observe()``."""
        return dict(self._states)

    def applied_actions(self) -> list[str]:
        return [a.name for a in self.actions if a.applied]

    def _suspect(self, snaps) -> str:
        for name, state in self._states.items():
            if state is StageHealth.STALLED:
                return name
        for s in snaps:
            if s.num_in > s.num_out + s.num_failed:
                return s.name
        return snaps[0].name if snaps else "pipeline"

    def check(self) -> StageHealth:
        """``observe()``, but raises ``PipelineStalled`` on STALLED."""
        state = self.observe()
        if state is StageHealth.STALLED:
            snaps = self.pipeline.stats()
            stage = self._suspect(snaps)
            now = self._clock()
            if self._sentinel_stall:
                # whole-pipeline stall: no individual row is stalled, so the
                # sentinel's own quiet time IS the stall duration (a source
                # row that legitimately finished ages ago must not inflate it)
                quiets = [self.history.quiet_for(-1, now=now)]
            else:
                # quiet time of the STALLED rows only — finished stages and
                # the sentinel must not overstate how long we've been stuck
                quiets = [
                    self.history.quiet_for(i, now=now)
                    for i, s in enumerate(snaps)
                    if self._states.get(s.name) is StageHealth.STALLED
                ]
            raise PipelineStalled(
                stage,
                max(quiets, default=self.stalled_after_s),
                snapshot=snaps,
            )
        return state

    # -- consumption --------------------------------------------------------
    def guard(self, *, tick: float = 1.0, chunk: int = 1) -> Iterator[Any]:
        """Iterate the pipeline with stall detection: yields every item,
        polls health every ``tick`` seconds of sink silence, and raises
        ``PipelineStalled`` instead of blocking forever.  Degrade rungs
        fire from the same cadence.

        ``chunk > 1`` drains via ``Pipeline.get_items(chunk, ...)`` — one
        cross-thread round trip per chunk of already-buffered items instead
        of one per item — and still yields item by item.

        Ticking is lossless either way: a timed-out drain keeps its sink
        getter pending inside the ``Pipeline`` and the next call (per-item
        or chunked) resumes it, so a tick shorter than the inter-batch
        latency never drops a batch or the EOF."""
        while True:
            try:
                if chunk > 1:
                    items = self.pipeline.get_items(chunk, timeout=tick)
                else:
                    items = [self.pipeline.get_item(timeout=tick)]
            except FuturesTimeout:
                self.check()
                continue
            except StopIteration:
                return
            yield from items

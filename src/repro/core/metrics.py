"""Time-series telemetry: windowed rates + Prometheus ``/metrics`` export.

The stats counters are lifetime-cumulative: a ``qps`` that averages over the
whole run says nothing about *now*, which is exactly the signal a live
dashboard, the health monitor, and the (ROADMAP) autotune controller need.
This module adds the two missing layers:

* ``StatsHistory`` — a bounded ring of timestamped ``Pipeline.stats()``
  snapshots.  ``sample()`` is driven by the consumer's cadence (the
  ``HealthMonitor`` calls it from ``observe()``) or by an optional
  background thread (``start(interval)``); ``window(seconds)`` serves
  *windowed* deltas — current qps / occupancy / wait fractions per stage —
  and ``quiet_for(row)`` the per-row progress-staleness the health state
  machine keys off.
* ``MetricsExporter`` — renders pipelines, histories, and resource samples
  in the Prometheus text exposition format.  Mountable on the existing
  shard HTTP servers (``ShardHTTPServer(metrics=...)``,
  ``PeerShardServer(metrics=...)`` answer ``GET /metrics``) or standalone
  via ``exporter.serve(port=...)`` — a tiny stdlib HTTP server, no new
  dependencies.

Windowed rates are computed between the newest sample and the newest sample
at least ``seconds`` old (so a ``window(5)`` covers ≥5s once history is
that deep); rows are matched positionally, which is stable for a pipeline's
lifetime.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Iterable

from .stats import StageStatsSnapshot

__all__ = [
    "WindowRates",
    "StatsHistory",
    "MetricsExporter",
    "MetricsServer",
    "CONTENT_TYPE_LATEST",
]

#: Prometheus text exposition content type.
CONTENT_TYPE_LATEST = "text/plain; version=0.0.4; charset=utf-8"


@dataclasses.dataclass(frozen=True)
class WindowRates:
    """Per-stage rates over one history window (the "now" row next to the
    snapshot's lifetime averages)."""

    name: str
    dt: float  # window length actually covered (seconds)
    in_rate: float  # items entering the stage per second
    qps: float  # items emitted per second
    fail_rate: float  # failures per second
    occupancy: float  # fraction of the window the stage's workers were busy
    get_wait_frac: float  # fraction of the window spent starved for input
    put_wait_frac: float  # fraction of the window spent backpressured


class StatsHistory:
    """Ring-bounded time series of ``Pipeline.stats()`` snapshots.

    ``sample()`` appends one timestamped snapshot row-set and updates the
    per-row last-progress-change ledger (progress = ``num_out +
    num_failed``; row ``-1`` is the whole-pipeline sentinel).  All methods
    are thread-safe: the background sampler, a ``/metrics`` scrape, and the
    consumer's health ticks may interleave freely.
    """

    def __init__(
        self,
        pipeline: Any = None,
        *,
        capacity: int = 512,
        clock: Callable[[], float] = time.monotonic,
        stats_fn: Callable[[], list[StageStatsSnapshot]] | None = None,
    ):
        if stats_fn is None:
            if pipeline is None:
                raise ValueError("StatsHistory needs a pipeline or a stats_fn")
            stats_fn = pipeline.stats
        if capacity < 2:
            raise ValueError("capacity must be >= 2 (deltas need two samples)")
        self._stats_fn = stats_fn
        self._clock = clock
        self._lock = threading.Lock()
        self._samples: deque[tuple[float, list[StageStatsSnapshot]]] = deque(
            maxlen=capacity
        )
        # row index -> (progress count, clock time it last changed);
        # row -1 is the whole-pipeline sentinel (sum across rows)
        self._last_change: dict[int, tuple[int, float]] = {}
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()

    # -- sampling ---------------------------------------------------------
    def sample(self, now: float | None = None) -> list[StageStatsSnapshot]:
        """Take one snapshot; returns the rows (also kept in the ring)."""
        if now is None:
            now = self._clock()
        snaps = self._stats_fn()
        with self._lock:
            self._samples.append((now, snaps))
            total = 0
            for i, s in enumerate(snaps):
                count = s.num_out + s.num_failed
                total += count
                prev = self._last_change.get(i)
                if prev is None or prev[0] != count:
                    self._last_change[i] = (count, now)
            prev = self._last_change.get(-1)
            if prev is None or prev[0] != total:
                self._last_change[-1] = (total, now)
        return snaps

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def last(self) -> tuple[float, list[StageStatsSnapshot]] | None:
        """Newest ``(t, rows)`` sample, or None before the first one."""
        with self._lock:
            return self._samples[-1] if self._samples else None

    # -- progress staleness (the health monitor's signal) -----------------
    def quiet_for(self, row: int, now: float | None = None) -> float:
        """Seconds since row ``row``'s progress count last changed, as of
        ``now`` (default: the newest sample's timestamp).  0.0 for a row
        never sampled or one that changed on the latest sample."""
        with self._lock:
            rec = self._last_change.get(row)
            if now is None:
                now = self._samples[-1][0] if self._samples else self._clock()
        if rec is None:
            return 0.0
        return max(0.0, now - rec[1])

    # -- windowed rates ----------------------------------------------------
    def window(self, seconds: float | None = None) -> dict[str, WindowRates]:
        """Per-stage rates over the trailing window (whole history when
        ``seconds`` is None).  Empty dict until two samples exist."""
        with self._lock:
            samples = list(self._samples)
        if len(samples) < 2:
            return {}
        t1, new = samples[-1]
        t0, old = samples[0]
        if seconds is not None:
            # newest sample at least `seconds` old → the window covers >= the
            # asked-for span as soon as history is deep enough
            for t, rows in reversed(samples[:-1]):
                if t1 - t >= seconds:
                    t0, old = t, rows
                    break
            else:
                t0, old = samples[0]
        dt = t1 - t0
        out: dict[str, WindowRates] = {}
        for i in range(min(len(new), len(old))):
            n, o = new[i], old[i]
            if dt <= 0:
                out[n.name] = WindowRates(n.name, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
                continue
            conc = max(1, n.concurrency)
            out[n.name] = WindowRates(
                name=n.name,
                dt=dt,
                in_rate=max(0, n.num_in - o.num_in) / dt,
                qps=max(0, n.num_out - o.num_out) / dt,
                fail_rate=max(0, n.num_failed - o.num_failed) / dt,
                occupancy=max(0.0, n.task_time - o.task_time) / (dt * conc),
                get_wait_frac=max(0.0, n.get_wait - o.get_wait) / dt,
                put_wait_frac=max(0.0, n.put_wait - o.put_wait) / dt,
            )
        return out

    # -- optional background cadence --------------------------------------
    def start(self, interval: float = 1.0) -> "StatsHistory":
        """Sample on a daemon-thread cadence (for dashboards/scrapes that
        have no consumer loop to ride).  Idempotent; ``stop()`` to end."""
        if self._thread is not None:
            return self
        self._stop_evt.clear()

        def _run() -> None:
            while not self._stop_evt.wait(interval):
                try:
                    self.sample()
                except Exception:  # pragma: no cover - stats_fn died mid-run
                    return

        self._thread = threading.Thread(
            target=_run, daemon=True, name="stats-history"
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "StatsHistory":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.stop()


# -- Prometheus text exposition -------------------------------------------
def _esc(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels(**kv: str) -> str:
    inner = ",".join(f'{k}="{_esc(str(v))}"' for k, v in kv.items() if v is not None)
    return "{" + inner + "}" if inner else ""


def _num(v: float) -> str:
    if isinstance(v, float) and v != v:  # NaN
        return "NaN"
    return repr(v) if isinstance(v, float) else str(v)


class _Families:
    """Accumulates samples grouped by metric family, renders HELP/TYPE once
    per family in insertion order."""

    def __init__(self) -> None:
        self._fams: dict[str, tuple[str, str, list[str]]] = {}

    def add(self, name: str, kind: str, help_: str, value: float, **labels: str) -> None:
        fam = self._fams.get(name)
        if fam is None:
            fam = (kind, help_, [])
            self._fams[name] = fam
        fam[2].append(f"{name}{_labels(**labels)} {_num(value)}")

    def render(self) -> str:
        out: list[str] = []
        for name, (kind, help_, rows) in self._fams.items():
            out.append(f"# HELP {name} {help_}")
            out.append(f"# TYPE {name} {kind}")
            out.extend(rows)
        return "\n".join(out) + "\n" if out else ""


def stage_metrics_lines(
    snaps: list[StageStatsSnapshot],
    *,
    namespace: str = "repro",
    pipeline: str = "pipeline",
    window: dict[str, WindowRates] | None = None,
) -> list[str]:
    """Prometheus lines for one pipeline's stage rows (plus windowed gauges
    when a ``StatsHistory.window()`` result is supplied)."""
    f = _Families()
    p = namespace
    for s in snaps:
        lb = {"pipeline": pipeline, "stage": s.name}
        f.add(f"{p}_stage_items_in_total", "counter",
              "Items pulled from the stage input queue.", s.num_in, **lb)
        f.add(f"{p}_stage_items_out_total", "counter",
              "Items emitted to the stage output queue.", s.num_out, **lb)
        f.add(f"{p}_stage_failures_total", "counter",
              "Items that raised in the stage function.", s.num_failed, **lb)
        f.add(f"{p}_stage_task_seconds_total", "counter",
              "Seconds spent inside the stage function.", s.task_time, **lb)
        f.add(f"{p}_stage_get_wait_seconds_total", "counter",
              "Seconds blocked waiting for input (starved).", s.get_wait, **lb)
        f.add(f"{p}_stage_put_wait_seconds_total", "counter",
              "Seconds blocked on a full output queue (backpressured).",
              s.put_wait, **lb)
        f.add(f"{p}_stage_qps", "gauge",
              "Lifetime-average items/s emitted.", s.qps, **lb)
        f.add(f"{p}_stage_occupancy", "gauge",
              "Lifetime fraction of wall time the stage workers were busy.",
              s.occupancy, **lb)
        if s.time_to_first_s is not None:
            f.add(f"{p}_stage_time_to_first_item_seconds", "gauge",
                  "Seconds from stage start to its first emitted item.",
                  s.time_to_first_s, **lb)
        for etype, count in s.errors_by_type:
            f.add(f"{p}_stage_errors_total", "counter",
                  "Stage failures by exception type.", count,
                  type=etype, **lb)
        if s.stragglers or s.straggler_shed:
            f.add(f"{p}_stage_stragglers_total", "counter",
                  "Items detached to the straggler slow lane.", s.stragglers, **lb)
            f.add(f"{p}_stage_straggler_shed_total", "counter",
                  "Detach candidates run inline (pool saturated).",
                  s.straggler_shed, **lb)
        if s.num_slabs:
            f.add(f"{p}_arena_slabs_in_flight", "gauge",
                  "Arena slabs currently lent out.", s.slabs_in_flight, **lb)
            f.add(f"{p}_arena_bytes_allocated", "gauge",
                  "Arena bytes allocated.", s.bytes_allocated, **lb)
        if s.cache_hits or s.cache_misses or s.prefetch_depth or s.bytes_cached:
            f.add(f"{p}_shard_cache_hits_total", "counter",
                  "Shard cache hits.", s.cache_hits, **lb)
            f.add(f"{p}_shard_cache_misses_total", "counter",
                  "Shard cache misses.", s.cache_misses, **lb)
            f.add(f"{p}_shard_cache_evictions_total", "counter",
                  "Shard cache evictions.", s.cache_evictions, **lb)
            f.add(f"{p}_shard_cache_bytes", "gauge",
                  "Bytes resident in the shard cache.", s.bytes_cached, **lb)
            f.add(f"{p}_shard_fetched_bytes_total", "counter",
                  "Bytes downloaded from shard sources.", s.bytes_fetched, **lb)
            f.add(f"{p}_shard_promotions_total", "counter",
                  "Sparse-to-full cache promotions.", s.promotions, **lb)
            if s.bytes_skipped or s.fields_requested:
                f.add(f"{p}_shard_skipped_bytes_total", "counter",
                      "Wire bytes avoided by columnar projection.",
                      s.bytes_skipped, **lb)
                f.add(f"{p}_shard_fields_requested", "gauge",
                      "Distinct field names requested from the prefetcher.",
                      s.fields_requested, **lb)
            if s.source_errors or s.source_retries:
                f.add(f"{p}_shard_source_errors_total", "counter",
                      "Shard source fetch errors.", s.source_errors, **lb)
                f.add(f"{p}_shard_source_retries_total", "counter",
                      "Shard source fetch retries.", s.source_retries, **lb)
        if s.device_decode_batches or s.device_decode_ms:
            f.add(f"{p}_device_decode_batches_total", "counter",
                  "Batches decoded on-chip by the fused dequant/normalize/"
                  "augment kernel behind DeviceTransfer.",
                  s.device_decode_batches, **lb)
            f.add(f"{p}_device_decode_dispatch_seconds_total", "counter",
                  "Host-side dispatch seconds spent launching the fused "
                  "on-chip decode (the device work itself is async).",
                  s.device_decode_ms / 1e3, **lb)
        if s.sink_drained_chunks:
            f.add(f"{p}_sink_drained_chunks_total", "counter",
                  "Chunks the consumer pulled via the chunked sink drain "
                  "(Pipeline.get_items).", s.sink_drained_chunks, **lb)
        if s.peer_hits or s.peer_bytes or s.origin_bytes:
            f.add(f"{p}_shard_peer_hits_total", "counter",
                  "Shard fetches answered by warm peers.", s.peer_hits, **lb)
            f.add(f"{p}_shard_peer_bytes_total", "counter",
                  "Bytes served by peers.", s.peer_bytes, **lb)
            f.add(f"{p}_shard_origin_bytes_total", "counter",
                  "Bytes served by the origin store.", s.origin_bytes, **lb)
    if window:
        for name, w in window.items():
            lb = {"pipeline": pipeline, "stage": name}
            f.add(f"{p}_stage_window_qps", "gauge",
                  "Items/s emitted over the trailing window.", w.qps, **lb)
            f.add(f"{p}_stage_window_occupancy", "gauge",
                  "Worker busy fraction over the trailing window.",
                  w.occupancy, **lb)
            f.add(f"{p}_stage_window_get_wait_fraction", "gauge",
                  "Starved fraction of the trailing window.",
                  w.get_wait_frac, **lb)
            f.add(f"{p}_stage_window_put_wait_fraction", "gauge",
                  "Backpressured fraction of the trailing window.",
                  w.put_wait_frac, **lb)
            f.add(f"{p}_stage_window_seconds", "gauge",
                  "Length of the trailing window actually covered.",
                  w.dt, **lb)
    return f.render().splitlines()


class MetricsExporter:
    """Composable Prometheus text-exposition renderer.

    Register pipelines (with optional ``StatsHistory`` for window gauges),
    a ``ResourceSampler`` for process CPU/RSS, and arbitrary collectors;
    ``render()`` produces the exposition body.  Mount it::

        exporter = MetricsExporter()
        exporter.add_pipeline(pipe, history=history)
        server = exporter.serve(port=9100)        # standalone
        ShardHTTPServer(root, metrics=exporter)   # or ride the shard server
    """

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._lock = threading.Lock()
        self._collectors: list[Callable[[], Iterable[str]]] = []

    def add_collector(self, fn: Callable[[], Iterable[str]]) -> None:
        """Register a callable returning exposition lines (no trailing \\n)."""
        with self._lock:
            self._collectors.append(fn)

    def add_pipeline(
        self,
        pipeline: Any,
        *,
        name: str = "pipeline",
        history: StatsHistory | None = None,
        window_s: float | None = None,
    ) -> None:
        """Export a pipeline's stage rows (plus window gauges when a
        history is given; the history is sampled on every scrape)."""

        def collect() -> Iterable[str]:
            if history is not None:
                history.sample()
                window = history.window(window_s)
            else:
                window = None
            return stage_metrics_lines(
                pipeline.stats(),
                namespace=self.namespace,
                pipeline=name,
                window=window,
            )

        self.add_collector(collect)

    def add_resource_sampler(self, sampler: Any) -> None:
        """Export process CPU seconds and RSS from a ``ResourceSampler``
        (its latest background sample, or a fresh /proc read)."""

        def collect() -> Iterable[str]:
            cpu_s, rss = sampler.current()
            f = _Families()
            f.add(f"{self.namespace}_process_cpu_seconds_total", "counter",
                  "Process CPU time (user+sys).", cpu_s)
            f.add(f"{self.namespace}_process_rss_bytes", "gauge",
                  "Process resident set size.", rss)
            return f.render().splitlines()

        self.add_collector(collect)

    def add_fleet(
        self,
        *,
        peers: Any = None,
        registry: Any = None,
        admission: Any = None,
        prefetcher: Any = None,
        name: str = "fleet",
    ) -> None:
        """Export the elastic-shard-fleet gauges: ``peers_live`` /
        ``peers_suspect`` (from the ``registry`` — authoritative — or the
        consumer-side ``peers`` breaker view), ``ring_remaps_total`` and
        ``admission_rejections_total``, and the prefetcher's
        ``warm_restart_bytes_reused_total``.  Pass whichever components
        this process actually hosts; absent ones export nothing."""

        def collect() -> Iterable[str]:
            f = _Families()
            p = self.namespace
            lb = {"fleet": name}
            live = suspect = None
            if registry is not None:
                rs = registry.stats()
                live, suspect = rs["peers_live"], rs["peers_suspect"]
            ps = peers.stats() if peers is not None else {}
            if live is None:
                live = ps.get("peers_live")
                suspect = ps.get("peers_suspect")
            if live is not None:
                f.add(f"{p}_fleet_peers_live", "gauge",
                      "Fleet members currently live.", live, **lb)
                f.add(f"{p}_fleet_peers_suspect", "gauge",
                      "Fleet members with missed heartbeats.", suspect, **lb)
            if "ring_remaps" in ps:
                f.add(f"{p}_fleet_ring_remaps_total", "counter",
                      "Consistent-hash arcs remapped by membership changes.",
                      ps["ring_remaps"], **lb)
            if admission is not None:
                f.add(f"{p}_fleet_admission_rejections_total", "counter",
                      "Requests answered 429 by admission control.",
                      admission.stats()["admission_rejections"], **lb)
            if prefetcher is not None:
                f.add(f"{p}_fleet_warm_restart_bytes_reused_total", "counter",
                      "Bytes re-opened from persisted state instead of "
                      "re-fetched.",
                      prefetcher.stats().get("warm_restart_bytes_reused", 0),
                      **lb)
            return f.render().splitlines()

        self.add_collector(collect)

    def render(self) -> str:
        with self._lock:
            collectors = list(self._collectors)
        lines: list[str] = []
        for fn in collectors:
            try:
                lines.extend(fn())
            except Exception as e:  # noqa: BLE001 - one bad collector must
                # not take down the scrape; surface it as a comment instead
                lines.append(f"# collector error: {_esc(repr(e))}")
        return "\n".join(lines) + "\n" if lines else "\n"

    def serve(self, host: str = "127.0.0.1", port: int = 0) -> "MetricsServer":
        """Start a standalone stdlib HTTP server answering ``GET /metrics``."""
        return MetricsServer(self, host=host, port=port)


class _MetricsHandler(BaseHTTPRequestHandler):
    def do_GET(self) -> None:  # noqa: N802 - stdlib naming
        if self.path.split("?", 1)[0] != "/metrics":
            self.send_error(404, "try /metrics")
            return
        body = self.server.exporter.render().encode()  # type: ignore[attr-defined]
        self.send_response(200)
        self.send_header("Content-Type", CONTENT_TYPE_LATEST)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt: str, *args: Any) -> None:  # pragma: no cover
        pass  # scrapes are frequent; stay quiet


class MetricsServer:
    """A tiny threaded HTTP server exposing one route: ``GET /metrics``."""

    def __init__(self, exporter: MetricsExporter, host: str = "127.0.0.1", port: int = 0):
        self._httpd = ThreadingHTTPServer((host, port), _MetricsHandler)
        self._httpd.daemon_threads = True
        self._httpd.exporter = exporter  # type: ignore[attr-defined]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True, name="metrics-http"
        )
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close()

"""Fault injection for pipeline stages (the engine half of the chaos layer;
the shard-fleet half lives in ``data.shards.testing``).

``FaultInjectingStage`` wraps any sync stage function with deterministic,
seeded misbehavior — bimodal latency tails, per-item errors, hangs — so the
robustness machinery (straggler slow lane, per-item skip holes, the
whole-chunk hang backstop, health monitoring) can be exercised and *gated*
instead of trusted.  Used by ``benchmarks/bench_faults.py`` and
``tests/test_faults.py``; never by production loaders.

Determinism: each call draws from a private ``random.Random`` keyed by
``(seed, call-ordinal)``, so the SET of injected faults (how many slow
items, how many errors) is reproducible run-to-run even when the pipeline
executes items concurrently — only which *worker* hits them varies.
"""

from __future__ import annotations

import itertools
import random
import threading
import time
from typing import Any, Callable

from . import trace as _trace


class ChaosError(RuntimeError):
    """The injected per-item failure (distinguishable from real bugs)."""


class FaultInjectingStage:
    """Wrap a stage fn with seeded latency tails / errors / hangs.

    Args:
      fn: the real (sync) stage function.
      seed: chaos seed; same seed → same injected fault set.
      slow_rate: probability an item pays ``slow_s`` extra latency — the
        bimodal tail the straggler slow lane exists for.
      slow_s: the slow mode's added latency (seconds).
      error_rate: probability an item raises ``ChaosError`` instead of
        returning (exercises skip holes / fail-fast).
      hang_rate: probability an item sleeps ``hang_s`` — long enough to be
        "never returns" at test timescales (exercises the whole-chunk
        backstop; keep 0.0 unless every phase has a timeout).
      hang_s: the hang duration.

    Counters (thread-safe): ``injected_slow`` / ``injected_errors`` /
    ``injected_hangs``; ``stats()`` returns them as a dict.
    """

    def __init__(
        self,
        fn: Callable[[Any], Any],
        *,
        seed: int = 0,
        slow_rate: float = 0.0,
        slow_s: float = 0.0,
        error_rate: float = 0.0,
        hang_rate: float = 0.0,
        hang_s: float = 60.0,
    ):
        for name, rate in (
            ("slow_rate", slow_rate),
            ("error_rate", error_rate),
            ("hang_rate", hang_rate),
        ):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        self.fn = fn
        self.seed = seed
        self.slow_rate = slow_rate
        self.slow_s = slow_s
        self.error_rate = error_rate
        self.hang_rate = hang_rate
        self.hang_s = hang_s
        self.__name__ = getattr(fn, "__name__", "stage")
        self._calls = itertools.count()  # thread-safe in CPython
        self._lock = threading.Lock()
        self.injected_slow = 0
        self.injected_errors = 0
        self.injected_hangs = 0

    def __call__(self, item: Any) -> Any:
        # one private stream per call ordinal: the draw is independent of
        # thread scheduling, so fault COUNTS are reproducible run-to-run
        r = random.Random((self.seed << 20) ^ next(self._calls)).random()
        tracer = _trace.get_tracer()
        if r < self.hang_rate:
            with self._lock:
                self.injected_hangs += 1
            if tracer.enabled:
                tracer.instant(
                    "chaos:hang", "chaos",
                    {"stage": self.__name__, "hang_s": self.hang_s},
                )
            time.sleep(self.hang_s)
        elif r < self.hang_rate + self.error_rate:
            with self._lock:
                self.injected_errors += 1
            if tracer.enabled:
                tracer.instant("chaos:error", "chaos", {"stage": self.__name__})
            raise ChaosError(f"injected failure (seed={self.seed})")
        elif r < self.hang_rate + self.error_rate + self.slow_rate:
            with self._lock:
                self.injected_slow += 1
            if tracer.enabled:
                tracer.instant(
                    "chaos:slow", "chaos",
                    {"stage": self.__name__, "slow_s": self.slow_s},
                )
            time.sleep(self.slow_s)
        return self.fn(item)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "injected_slow": self.injected_slow,
                "injected_errors": self.injected_errors,
                "injected_hangs": self.injected_hangs,
            }

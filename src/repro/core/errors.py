"""Error types and per-sample failure policies for the pipeline engine.

The paper's "Robustness" principle (§5.4): sample-level failures (bad media,
flaky network) must not kill the pipeline; they are logged, counted, and
skipped.  A pipeline can opt into fail-fast semantics instead.

Failure provenance: a fail-fast ``PipelineFailure`` names the *phase* that
raised (for a fused stage that is the original sub-stage, not the composite
``"read+decode"`` runtime) and, where the runner knows it, the stage-stream
index of the item that failed — so "which input broke us" is recoverable
from the exception itself, not just from the stats dashboard.
"""

from __future__ import annotations

import enum


class OnError(str, enum.Enum):
    """What a stage does when its function raises for one item."""

    SKIP = "skip"  # log + count + drop the item, keep going (paper default)
    FAIL = "fail"  # cancel the whole pipeline, surface the error to the iterator


class PipelineFailure(RuntimeError):
    """Raised in the consumer thread when a fail-fast stage errored.

    ``stage`` is the name of the *raising* stage — for a fused runtime that
    is the phase that actually raised (``"decode"``, not ``"read+decode"``);
    the composite runtime name, when different, is in ``fused_stage``.
    ``phase`` is an explicit alias of the raising phase name.  ``item_index``
    is the 0-based index of the failing item in this stage's input stream
    (``None`` when the failure is not attributable to one item — e.g. a
    whole-chunk hang backstop or a vectorized chunk failure).  The original
    exception is available as ``__cause__``.
    """

    def __init__(
        self,
        stage: str,
        cause: BaseException,
        *,
        item_index: int | None = None,
        fused_stage: str | None = None,
    ):
        where = f"pipeline stage {stage!r}"
        if fused_stage is not None and fused_stage != stage:
            where += f" (phase of {fused_stage!r})"
        at = f" on item #{item_index}" if item_index is not None else ""
        super().__init__(f"{where} failed{at}: {cause!r}")
        self.stage = stage
        self.phase = stage
        self.item_index = item_index
        self.fused_stage = fused_stage
        self.__cause__ = cause


class PipelineStalled(RuntimeError):
    """Raised by the health monitor when the pipeline stopped making
    progress for longer than ``stalled_after_s`` — the structured
    alternative to a consumer blocking forever on a dead sink.

    ``stage`` names the suspected culprit (the earliest non-progressing
    stage that still holds items), ``stalled_for_s`` is how long the sink
    has been silent, and ``snapshot`` is the ``Pipeline.stats()`` rows at
    detection time for post-mortems.
    """

    def __init__(self, stage: str, stalled_for_s: float, snapshot=None):
        super().__init__(
            f"pipeline made no progress for {stalled_for_s:.1f}s "
            f"(suspected stage: {stage!r})"
        )
        self.stage = stage
        self.stalled_for_s = stalled_for_s
        self.snapshot = snapshot


class PipelineStopped(RuntimeError):
    """Raised when interacting with a pipeline that has been stopped."""

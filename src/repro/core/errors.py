"""Error types and per-sample failure policies for the pipeline engine.

The paper's "Robustness" principle (§5.4): sample-level failures (bad media,
flaky network) must not kill the pipeline; they are logged, counted, and
skipped.  A pipeline can opt into fail-fast semantics instead.
"""

from __future__ import annotations

import enum


class OnError(str, enum.Enum):
    """What a stage does when its function raises for one item."""

    SKIP = "skip"  # log + count + drop the item, keep going (paper default)
    FAIL = "fail"  # cancel the whole pipeline, surface the error to the iterator


class PipelineFailure(RuntimeError):
    """Raised in the consumer thread when a fail-fast stage errored.

    The original exception is available as ``__cause__``.
    """

    def __init__(self, stage: str, cause: BaseException):
        super().__init__(f"pipeline stage {stage!r} failed: {cause!r}")
        self.stage = stage
        self.__cause__ = cause


class PipelineStopped(RuntimeError):
    """Raised when interacting with a pipeline that has been stopped."""

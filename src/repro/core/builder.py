"""PipelineBuilder — the paper's user-facing construction API (§5.9.1).

No DSL: stages are plain Python callables (sync or async).  Example::

    pipeline = (
        PipelineBuilder()
        .add_source(source())
        .pipe(download, concurrency=12)
        .pipe(decode, concurrency=4)
        .aggregate(32)
        .pipe(batch_transfer)
        .add_sink(buffer_size=3)
        .build(num_threads=16)
    )
"""

from __future__ import annotations

from concurrent.futures import Executor
from typing import Any, AsyncIterable, Callable, Iterable

from .engine import StageSpec
from .errors import OnError
from .pipeline import Pipeline


class PipelineBuilder:
    def __init__(self) -> None:
        self._specs: list[StageSpec] = []
        self._sink_buffer_size: int | None = None

    # ------------------------------------------------------------------
    def add_source(self, source: Iterable | AsyncIterable, name: str = "source") -> "PipelineBuilder":
        if self._specs:
            raise ValueError("add_source must be the first stage")
        if not (hasattr(source, "__iter__") or hasattr(source, "__aiter__")):
            raise TypeError("source must be Iterable or AsyncIterable")
        self._specs.append(StageSpec(kind="source", name=name, source=source))
        return self

    def pipe(
        self,
        fn: Callable[[Any], Any],
        *,
        concurrency: int = 1,
        executor: Executor | None = None,
        name: str | None = None,
        output_order: str = "input",
        on_error: str | OnError = OnError.SKIP,
        timeout: float | None = None,
        queue_size: int = 2,
        cache: Any = None,
    ) -> "PipelineBuilder":
        """Chain a processing stage.

        Args:
          fn: sync or async callable applied to each item.  Sync callables
            run on the pipeline thread pool (or ``executor`` if given), so
            they should release the GIL to scale; async callables run on the
            event loop (never GIL-bound).
          concurrency: max in-flight tasks for this stage.
          executor: optional executor override; pass a
            ``ProcessPoolExecutor`` for GIL-holding third-party code (§5.8).
          output_order: "input" preserves input order; "completion" emits as
            tasks finish.
          on_error: "skip" (robust, default) or "fail" (fail-fast).
          timeout: optional per-item timeout in seconds.
          queue_size: output queue bound (backpressure granularity).
          cache: optional cache/prefetcher probe (anything with a ``stats()``
            dict of hits/misses/evictions/bytes_cached/prefetch_depth);
            its counters are folded into this stage's ``Pipeline.stats()``
            snapshot — how shard-cache visibility reaches the dashboard.
        """
        self._require_source()
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        if output_order not in ("input", "completion"):
            raise ValueError("output_order must be 'input' or 'completion'")
        self._specs.append(
            StageSpec(
                kind="pipe",
                name=name or getattr(fn, "__name__", "pipe"),
                fn=fn,
                concurrency=concurrency,
                executor=executor,
                output_order=output_order,
                on_error=OnError(on_error),
                timeout=timeout,
                queue_size=queue_size,
                cache=cache,
            )
        )
        return self

    def aggregate(self, num_items: int, *, drop_last: bool = False, name: str | None = None) -> "PipelineBuilder":
        """Group consecutive items into lists of ``num_items`` (§5.9.1)."""
        self._require_source()
        if num_items < 1:
            raise ValueError("num_items must be >= 1")
        self._specs.append(
            StageSpec(
                kind="aggregate",
                name=name or f"aggregate({num_items})",
                agg_size=num_items,
                drop_last=drop_last,
            )
        )
        return self

    def aggregate_into(
        self,
        arena: Any,
        num_items: int | None = None,
        *,
        drop_last: bool = False,
        name: str | None = None,
    ) -> "PipelineBuilder":
        """Slot-aware batching: group ``SlotRef`` tickets into the arena slab
        they were decoded into (zero-copy batch assembly).

        The upstream stages must carry ``(item, SlotRef)`` assignments handed
        out by ``arena.binder()`` and write each row in place (see
        ``repro.data.arena``).  Unlike ``aggregate`` this stage buffers no
        arrays: in the clean case the emitted batch *is* the slab.  Requires
        an input-order-preserving upstream (the default ``output_order``)
        and ``num_items == arena.batch_size`` — a sub-slab batch size would
        let one slab back two live batches, so in-place compaction of the
        second would corrupt the first after it was already delivered.
        """
        self._require_source()
        size = num_items if num_items is not None else arena.batch_size
        if size != arena.batch_size:
            raise ValueError(
                f"num_items ({size}) must equal arena batch_size "
                f"({arena.batch_size}): one emitted batch per slab"
            )
        self._specs.append(
            StageSpec(
                kind="aggregate_into",
                name=name or f"aggregate_into({size})",
                agg_size=size,
                drop_last=drop_last,
                arena=arena,
            )
        )
        return self

    def disaggregate(self, name: str | None = None) -> "PipelineBuilder":
        """Flatten iterable items back into single elements."""
        self._require_source()
        self._specs.append(StageSpec(kind="disaggregate", name=name or "disaggregate"))
        return self

    def add_sink(self, buffer_size: int = 3) -> "PipelineBuilder":
        """Terminal buffer the consumer thread reads from."""
        self._require_source()
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        if self._sink_buffer_size is not None:
            raise ValueError("add_sink already called")
        self._sink_buffer_size = buffer_size
        return self

    # ------------------------------------------------------------------
    def build(self, *, num_threads: int = 8) -> Pipeline:
        self._require_source()
        if len(self._specs) < 2:
            raise ValueError("pipeline needs at least a source and one stage")
        return Pipeline(
            list(self._specs),
            num_threads=num_threads,
            sink_buffer_size=self._sink_buffer_size or 3,
        )

    def _require_source(self) -> None:
        if not self._specs:
            raise ValueError("call add_source first")

"""PipelineBuilder — the paper's user-facing construction API (§5.9.1).

No DSL: stages are plain Python callables (sync or async).  Example::

    pipeline = (
        PipelineBuilder()
        .add_source(source())
        .pipe(download, concurrency=12)
        .pipe(decode, concurrency=4)
        .aggregate(32)
        .pipe(batch_transfer)
        .add_sink(buffer_size=3)
        .build(num_threads=16)
    )
"""

from __future__ import annotations

from concurrent.futures import Executor
from typing import Any, AsyncIterable, Callable, Iterable

from .engine import StageSpec, _is_async_callable
from .errors import OnError
from .pipeline import Pipeline


class PipelineBuilder:
    def __init__(self) -> None:
        self._specs: list[StageSpec] = []
        self._sink_buffer_size: int | None = None
        self._fuse_groups: list[tuple[str, ...]] = []

    # ------------------------------------------------------------------
    def add_source(self, source: Iterable | AsyncIterable, name: str = "source") -> "PipelineBuilder":
        if self._specs:
            raise ValueError("add_source must be the first stage")
        if not (hasattr(source, "__iter__") or hasattr(source, "__aiter__")):
            raise TypeError("source must be Iterable or AsyncIterable")
        self._specs.append(StageSpec(kind="source", name=name, source=source))
        return self

    def pipe(
        self,
        fn: Callable[[Any], Any],
        *,
        concurrency: int = 1,
        executor: Executor | None = None,
        name: str | None = None,
        output_order: str = "input",
        on_error: str | OnError = OnError.SKIP,
        timeout: float | None = None,
        queue_size: int = 2,
        cache: Any = None,
        chunk: int = 1,
        vectorized: bool = False,
        straggler_after: float | None = None,
        straggler_runahead: int = 0,
    ) -> "PipelineBuilder":
        """Chain a processing stage.

        Args:
          fn: sync or async callable applied to each item.  Sync callables
            run on the pipeline thread pool (or ``executor`` if given), so
            they should release the GIL to scale; async callables run on the
            event loop (never GIL-bound).
          concurrency: max in-flight tasks for this stage (with ``chunk``,
            max in-flight *chunks*).
          executor: optional executor override; pass a
            ``ProcessPoolExecutor`` for GIL-holding third-party code (§5.8).
          output_order: "input" preserves input order; "completion" emits as
            tasks finish.
          on_error: "skip" (robust, default) or "fail" (fail-fast).
          timeout: optional per-item timeout in seconds.  With ``chunk`` it
            is enforced post hoc inside the worker (plus a whole-chunk hang
            backstop) — see the engine docstring.
          queue_size: output queue bound (backpressure granularity).  The
            pipeline widens it automatically when the NEXT stage pulls in
            chunks, so a chunked consumer can actually fill its chunks.
          cache: optional cache/prefetcher probe (anything with a ``stats()``
            dict of hits/misses/evictions/bytes_cached/prefetch_depth);
            its counters are folded into this stage's ``Pipeline.stats()``
            snapshot — how shard-cache visibility reaches the dashboard.
          chunk: items per executor dispatch.  ``chunk=N`` pulls up to N
            items per queue hop and applies ``fn`` across them inside ONE
            worker call, making the event-loop cost O(items/chunk) — the
            fix for loop-overhead-bound stages (high occupancy, near-zero
            task time).  Per-item error holes are preserved: a failing
            item under ``on_error="skip"`` drops only itself, not its
            chunk.  Requires a sync ``fn``.
          vectorized: the fn takes the whole chunk (a list) and returns a
            same-length, same-order list — for stages that can batch their
            own lookups (numpy gathers, bulk reads).  The fn owns per-item
            robustness: an exception it raises fails the WHOLE chunk.
            Requires ``chunk > 1``.
          straggler_after: soft per-item deadline in seconds — the straggler
            slow lane.  A chunked item exceeding it is detached to the
            pipeline's bounded straggler pool so its chunk-mates emit
            without waiting; the straggler's result re-enters the stream at
            its original position (``output_order="input"``) or whenever it
            lands (``"completion"``).  Requires ``chunk > 1``, a sync
            ``fn``, and a *stateless* fn (items run item-major on
            concurrent pool threads).  Incompatible with ``vectorized``.
            See the engine docstring ("Straggler slow lane").
          straggler_runahead: extra parked chunks the ordered emitter may
            run ahead while a detached straggler resolves (0 = default of
            3 × ``concurrency``).  This bounds how much straggler latency
            the stage can hide: roughly
            ``(concurrency + straggler_runahead) × chunk`` items of cover.
        """
        self._require_source()
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if output_order not in ("input", "completion"):
            raise ValueError("output_order must be 'input' or 'completion'")
        if chunk > 1 and _is_async_callable(fn):
            raise ValueError(
                "chunk > 1 requires a sync stage function (an async fn runs "
                "on the event loop — there is no executor dispatch to amortize)"
            )
        if vectorized and chunk <= 1:
            raise ValueError("vectorized=True requires chunk > 1")
        if straggler_after is not None:
            if straggler_after <= 0:
                raise ValueError("straggler_after must be > 0 seconds")
            if chunk <= 1:
                raise ValueError(
                    "straggler_after requires chunk > 1 (the slow lane "
                    "exists to stop one item holding its chunk hostage)"
                )
            if vectorized:
                raise ValueError(
                    "straggler_after is incompatible with vectorized=True "
                    "(the slow lane runs items item-major; a vectorized fn "
                    "only takes whole chunks)"
                )
        if straggler_runahead < 0:
            raise ValueError("straggler_runahead must be >= 0")
        self._specs.append(
            StageSpec(
                kind="pipe",
                name=name or getattr(fn, "__name__", "pipe"),
                fn=fn,
                concurrency=concurrency,
                executor=executor,
                output_order=output_order,
                on_error=OnError(on_error),
                timeout=timeout,
                queue_size=queue_size,
                cache=cache,
                chunk=chunk,
                vectorized=vectorized,
                straggler_after=straggler_after,
                straggler_runahead=straggler_runahead,
            )
        )
        return self

    def fuse(self, *names: str) -> "PipelineBuilder":
        """Collapse the named adjacent pipe stages into ONE executor call
        per item/chunk at ``build()`` time.

        Fusion removes the queue + task layer between the stages — their
        functions run back to back inside the same worker thread — while
        ``Pipeline.stats()`` keeps reporting them as separate rows (phase
        timings are recorded in the worker).  Each phase keeps its own
        ``on_error``/``timeout``/``cache``; a failure is attributed to the
        phase that raised and (under ``on_error="skip"``) drops only that
        item.

        Requirements (checked at ``build()``): the stages must be adjacent,
        already added, sync, share an executor, and preserve input order.
        A ``concurrency=1`` stage (often stateful) can only fuse with other
        ``concurrency=1`` stages — fusing it wider would break its
        single-writer guarantee.
        """
        if len(names) < 2:
            raise ValueError("fuse needs at least two stage names")
        if len(set(names)) != len(names):
            raise ValueError(f"fuse names must be distinct, got {names!r}")
        self._fuse_groups.append(tuple(names))
        return self

    def aggregate(self, num_items: int, *, drop_last: bool = False, name: str | None = None) -> "PipelineBuilder":
        """Group consecutive items into lists of ``num_items`` (§5.9.1)."""
        self._require_source()
        if num_items < 1:
            raise ValueError("num_items must be >= 1")
        self._specs.append(
            StageSpec(
                kind="aggregate",
                name=name or f"aggregate({num_items})",
                agg_size=num_items,
                drop_last=drop_last,
            )
        )
        return self

    def aggregate_into(
        self,
        arena: Any,
        num_items: int | None = None,
        *,
        drop_last: bool = False,
        name: str | None = None,
    ) -> "PipelineBuilder":
        """Slot-aware batching: group ``SlotRef`` tickets into the arena slab
        they were decoded into (zero-copy batch assembly).

        The upstream stages must carry ``(item, SlotRef)`` assignments handed
        out by ``arena.binder()`` and write each row in place (see
        ``repro.data.arena``).  Unlike ``aggregate`` this stage buffers no
        arrays: in the clean case the emitted batch *is* the slab.  Requires
        an input-order-preserving upstream (the default ``output_order``)
        and ``num_items == arena.batch_size`` — a sub-slab batch size would
        let one slab back two live batches, so in-place compaction of the
        second would corrupt the first after it was already delivered.
        """
        self._require_source()
        size = num_items if num_items is not None else arena.batch_size
        if size != arena.batch_size:
            raise ValueError(
                f"num_items ({size}) must equal arena batch_size "
                f"({arena.batch_size}): one emitted batch per slab"
            )
        self._specs.append(
            StageSpec(
                kind="aggregate_into",
                name=name or f"aggregate_into({size})",
                agg_size=size,
                drop_last=drop_last,
                arena=arena,
            )
        )
        return self

    def disaggregate(self, name: str | None = None) -> "PipelineBuilder":
        """Flatten iterable items back into single elements."""
        self._require_source()
        self._specs.append(StageSpec(kind="disaggregate", name=name or "disaggregate"))
        return self

    def add_sink(self, buffer_size: int = 3) -> "PipelineBuilder":
        """Terminal buffer the consumer thread reads from."""
        self._require_source()
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        if self._sink_buffer_size is not None:
            raise ValueError("add_sink already called")
        self._sink_buffer_size = buffer_size
        return self

    # ------------------------------------------------------------------
    def build(
        self,
        *,
        num_threads: int = 8,
        auto_fuse: bool = False,
        straggler_workers: int = 8,
        trace=None,
    ) -> Pipeline:
        """Finalize the pipeline.  The fusion pass runs here: explicit
        ``fuse()`` groups are collapsed (invalid groups raise), and with
        ``auto_fuse=True`` any remaining adjacent sync, same-executor,
        order-preserving pipe stages are collapsed too (ineligible pairs
        are silently left alone).  ``straggler_workers`` sizes the
        pipeline's shared straggler pool (only created when some stage set
        ``straggler_after``).  ``trace`` is an optional
        ``core.trace.Tracer``: stage/phase spans and queue-wait spans of
        this pipeline are recorded into it (see the engine docstring's
        "Observability" section; install it process-wide with
        ``trace.set_tracer`` to also capture shard/transfer spans)."""
        self._require_source()
        if len(self._specs) < 2:
            raise ValueError("pipeline needs at least a source and one stage")
        specs = self._fused_specs(auto_fuse)
        return Pipeline(
            specs,
            num_threads=num_threads,
            sink_buffer_size=self._sink_buffer_size or 3,
            straggler_workers=straggler_workers,
            tracer=trace,
        )

    # -- fusion pass ----------------------------------------------------
    @staticmethod
    def _fusable(a: StageSpec, b: StageSpec) -> str | None:
        """Why ``b`` cannot be fused onto the group ending in ``a``
        (None = fusable).  ``a`` may itself already be a fused spec."""
        for spec in (a, b):
            if spec.kind != "pipe":
                return f"stage {spec.name!r} is not a pipe stage"
            if spec.output_order != "input":
                return f"stage {spec.name!r} does not preserve input order"
            for phase in spec.phases:
                if _is_async_callable(phase.fn):
                    return f"stage {phase.name!r} is async (never leaves the loop)"
        if (a.executor or None) is not (b.executor or None):
            return f"stages {a.name!r} and {b.name!r} use different executors"
        conc = max(a.concurrency, b.concurrency)
        if conc > 1 and min(a.concurrency, b.concurrency) == 1:
            return (
                f"stage {(a if a.concurrency == 1 else b).name!r} is "
                "concurrency=1 (possibly stateful) and cannot be widened "
                f"to the fused concurrency {conc}"
            )
        if a.straggler_after is not None or b.straggler_after is not None:
            # the slow lane runs items item-major through every phase — a
            # vectorized phase (whole-chunk fn) cannot be driven that way
            for spec in (a, b):
                for phase in spec.phases:
                    if phase.vectorized:
                        return (
                            f"stage {phase.name!r} is vectorized and cannot "
                            "fuse into a straggler slow lane (items run "
                            "item-major)"
                        )
        return None

    @staticmethod
    def _fuse_pair(a: StageSpec, b: StageSpec) -> StageSpec:
        """One fused spec from two adjacent ones (either may be fused
        already — groups grow left to right)."""
        phases = a.phases + b.phases
        deadlines = [
            s.straggler_after for s in (a, b) if s.straggler_after is not None
        ]
        return StageSpec(
            kind="pipe",
            name="+".join(p.name for p in phases),
            fn=None,
            concurrency=max(a.concurrency, b.concurrency),
            executor=a.executor,
            output_order="input",
            queue_size=b.queue_size,  # the fused output queue is b's
            chunk=max(a.chunk, b.chunk),
            fused=phases,
            # the fused item runs every phase back to back, so the
            # tightest deadline of the group governs the whole run
            straggler_after=min(deadlines) if deadlines else None,
            straggler_runahead=max(a.straggler_runahead, b.straggler_runahead),
        )

    def _fused_specs(self, auto_fuse: bool) -> list[StageSpec]:
        specs = list(self._specs)
        by_name: dict[str, int] = {}
        for i, s in enumerate(specs):
            by_name.setdefault(s.name, i)
        fused_away: set[int] = set()
        for group in self._fuse_groups:
            positions = []
            for n in group:
                if n not in by_name:
                    raise ValueError(f"fuse: no stage named {n!r}")
                positions.append(by_name[n])
            if positions != list(range(positions[0], positions[0] + len(group))):
                raise ValueError(
                    f"fuse: stages {group!r} are not adjacent in pipeline order"
                )
            if any(p in fused_away for p in positions):
                raise ValueError(f"fuse: stages {group!r} overlap another fuse group")
            merged = specs[positions[0]]
            for pos in positions[1:]:
                why = self._fusable(merged, specs[pos])
                if why is not None:
                    raise ValueError(f"cannot fuse {group!r}: {why}")
                merged = self._fuse_pair(merged, specs[pos])
                fused_away.add(pos)
            specs[positions[0]] = merged
        out = [s for i, s in enumerate(specs) if i not in fused_away]
        if auto_fuse:
            merged_out = [out[0]]
            for spec in out[1:]:
                if self._fusable(merged_out[-1], spec) is None:
                    merged_out[-1] = self._fuse_pair(merged_out[-1], spec)
                else:
                    merged_out.append(spec)
            out = merged_out
        return out

    def _require_source(self) -> None:
        if not self._specs:
            raise ValueError("call add_source first")

"""Stage runners: the coroutines that make up a pipeline (paper §5.5).

Each stage is a coroutine scheduled on the event loop that runs on the
scheduler thread.  A stage pulls items from its input ``MonitoredQueue``,
applies its function with up to ``concurrency`` tasks in flight, and pushes
results to its output queue.  Synchronous functions are delegated to the
executor (thread pool by default, user-supplied process pool optionally) via
``loop.run_in_executor`` — this is where GIL-releasing functions actually run
concurrently.  Coroutine functions are awaited on the loop itself and never
touch the pool (paper §5.2: coroutines are not constrained by the GIL).

EOF protocol: exactly one ``EOF`` sentinel traverses each queue.  On the
normal path a stage *blocks* putting EOF (downstream is draining, so this
terminates).  On the exceptional path (fail-fast error or cancellation) it
*force-puts* EOF without blocking so teardown can never deadlock on a full
queue whose consumer is already dead.
"""

from __future__ import annotations

import asyncio
import dataclasses
import inspect
import logging
import time
from concurrent.futures import Executor
from typing import Any, AsyncIterable, Callable, Iterable

from ._compat import TaskGroup
from .errors import OnError, PipelineFailure
from .queues import EOF, MonitoredQueue
from .stats import StageStats

logger = logging.getLogger("repro.core")


def _is_async_callable(fn: Callable) -> bool:
    if inspect.iscoroutinefunction(fn):
        return True
    call = getattr(fn, "__call__", None)  # noqa: B004 - callables/partials
    return call is not None and inspect.iscoroutinefunction(call)


@dataclasses.dataclass
class StageSpec:
    """One entry built by ``PipelineBuilder``."""

    kind: str  # "source" | "pipe" | "aggregate" | "aggregate_into" | "disaggregate"
    name: str
    fn: Callable | None = None
    source: Iterable | AsyncIterable | None = None
    concurrency: int = 1
    executor: Executor | None = None  # None -> pipeline default thread pool
    output_order: str = "input"  # "input" | "completion"
    on_error: OnError = OnError.SKIP
    timeout: float | None = None
    agg_size: int = 0
    drop_last: bool = False
    queue_size: int = 2  # output queue bound (per stage)
    arena: Any = None  # SlabArena for kind == "aggregate_into" (duck-typed)
    cache: Any = None  # shard cache/prefetcher probed for stats (duck-typed)


class StageRuntime:
    """Binds a StageSpec to queues/stats and runs it."""

    def __init__(
        self,
        spec: StageSpec,
        in_q: MonitoredQueue | None,
        out_q: MonitoredQueue,
        default_executor: Executor,
    ):
        self.spec = spec
        self.in_q = in_q
        self.out_q = out_q
        self.default_executor = default_executor
        self.stats = StageStats(name=spec.name, concurrency=spec.concurrency)
        if spec.arena is not None:
            self.stats.arena = spec.arena  # memory-pressure visibility
        if spec.cache is not None:
            self.stats.cache = spec.cache  # shard-cache visibility
        if in_q is not None:
            in_q.consumer_stats = self.stats
        out_q.producer_stats = self.stats

    # ------------------------------------------------------------------
    async def _call(self, item: Any) -> Any:
        """Invoke the stage function for one item (async- or executor-path)."""
        fn = self.spec.fn
        assert fn is not None
        if _is_async_callable(fn):
            coro = fn(item)
        else:
            loop = asyncio.get_running_loop()
            ex = self.spec.executor or self.default_executor
            coro = loop.run_in_executor(ex, fn, item)
        if self.spec.timeout is not None:
            return await asyncio.wait_for(coro, self.spec.timeout)
        return await coro

    async def _guarded(self, item: Any) -> tuple[bool, Any]:
        """Run one task; returns (ok, result). Raises only in fail-fast mode."""
        t0 = time.monotonic()
        try:
            result = await self._call(item)
            self.stats.record_task(time.monotonic() - t0)
            return True, result
        except asyncio.CancelledError:
            raise
        except Exception as e:
            self.stats.record_task(time.monotonic() - t0)
            self.stats.record_failure(e)
            logger.warning("stage %s failed on item: %r", self.spec.name, e)
            if self.spec.on_error is OnError.FAIL:
                raise PipelineFailure(self.spec.name, e) from e
            return False, None

    async def _emit(self, item: Any) -> None:
        await self.out_q.put(item)
        self.stats.record_out()

    # -- top-level runner --------------------------------------------------
    async def run(self) -> None:
        """Run the stage body with the EOF teardown protocol."""
        body = {
            "source": self._run_source,
            "pipe": self._run_pipe,
            "aggregate": self._run_aggregate,
            "aggregate_into": self._run_aggregate_into,
            "disaggregate": self._run_disaggregate,
        }[self.spec.kind]
        try:
            await body()
            await self.out_q.put(EOF)  # normal path: block until accepted
        except BaseException:
            self.out_q.put_nowait_force(EOF)  # teardown path: never block
            raise

    # -- stage bodies ----------------------------------------------------
    async def _run_source(self) -> None:
        src = self.spec.source
        if hasattr(src, "__aiter__"):
            async for item in src:  # type: ignore[union-attr]
                await self._emit(item)
        else:
            # A synchronous iterable is advanced on the loop thread.  The
            # per-item cost of sources (paths / indices) is tiny; blocking
            # sources should be wrapped in an async generator or offloaded
            # with a pipe stage instead.
            for item in src:  # type: ignore[union-attr]
                await self._emit(item)

    async def _run_pipe(self) -> None:
        if self.spec.output_order == "completion":
            await self._run_pipe_unordered()
        else:
            await self._run_pipe_ordered()

    async def _run_pipe_ordered(self) -> None:
        """Input-order-preserving concurrent map.

        A reader creates up to ``concurrency`` in-flight tasks; an emitter
        awaits them in FIFO order, so results come out in input order while
        up to N items are processed concurrently.  The bounded task queue is
        the concurrency limiter, so backpressure from out_q stalls the reader.
        """
        assert self.in_q is not None
        # ``sem`` is the true in-flight bound; ``task_q`` only parks tasks
        # (running or completed) in FIFO order for the emitter, so completed
        # results buffered ahead of a backpressured emitter stay bounded too.
        sem = asyncio.Semaphore(self.spec.concurrency)
        task_q: asyncio.Queue[Any] = asyncio.Queue(self.spec.concurrency)

        async def guarded_release(item: Any) -> tuple[bool, Any]:
            try:
                return await self._guarded(item)
            finally:
                sem.release()

        async def reader() -> None:
            try:
                while True:
                    item = await self.in_q.get()
                    if item is EOF:
                        break
                    await sem.acquire()
                    t = asyncio.ensure_future(guarded_release(item))
                    try:
                        await task_q.put(t)
                    except BaseException:
                        t.cancel()
                        raise
                await task_q.put(EOF)
            except BaseException:
                # Emitter is failed/cancelled (or we are); never block here.
                try:
                    task_q.put_nowait(EOF)
                except asyncio.QueueFull:
                    pass
                raise

        async def emitter() -> None:
            while True:
                t = await task_q.get()
                if t is EOF:
                    return
                ok, result = await t
                if ok:
                    await self._emit(result)

        try:
            async with TaskGroup() as tg:
                tg.create_task(reader(), name=f"{self.spec.name}:reader")
                tg.create_task(emitter(), name=f"{self.spec.name}:emitter")
        except BaseException:
            while not task_q.empty():  # cancel still-pending work
                t = task_q.get_nowait()
                if t is not EOF:
                    t.cancel()
            raise

    async def _run_pipe_unordered(self) -> None:
        """Completion-order concurrent map (lower latency, no ordering)."""
        assert self.in_q is not None
        sem = asyncio.Semaphore(self.spec.concurrency)

        async def worker(item: Any) -> None:
            try:
                ok, result = await self._guarded(item)
                if ok:
                    await self._emit(result)
            finally:
                sem.release()

        async with TaskGroup() as tg:
            while True:
                item = await self.in_q.get()
                if item is EOF:
                    break
                await sem.acquire()
                tg.create_task(worker(item))
            # TaskGroup's __aexit__ awaits outstanding workers before we
            # return to run(), which then emits EOF downstream.

    async def _run_aggregate(self) -> None:
        assert self.in_q is not None
        buf: list[Any] = []
        while True:
            item = await self.in_q.get()
            if item is EOF:
                break
            buf.append(item)
            if len(buf) >= self.spec.agg_size:
                await self._emit(buf)
                buf = []
        if buf and not self.spec.drop_last:
            await self._emit(buf)

    async def _run_aggregate_into(self) -> None:
        """Slot-aware batching over an arena (zero-copy assembly).

        Input items are ``SlotRef``s whose rows were already written in
        place by upstream stages; this stage never buffers arrays.  In the
        clean case the first ``agg_size`` refs are exactly slab X, slots
        0..N-1, and the batch is the slab itself: zero copies.  A failed
        item upstream leaves a hole in its slab; compaction then copies the
        displaced rows (only rows at/after the hole) so emitted batches
        stay dense.  A slab drained entirely by compaction (never emitted)
        is auto-released by the arena; an emitted slab is released by the
        consumer (see ``DeviceTransfer``) after its device copy completes.

        Requires an input-order-preserving upstream: refs of slab k must
        all arrive before refs of slab k+1.
        """
        assert self.in_q is not None
        size = self.spec.agg_size
        ready: list[Any] = []  # SlotRefs, in arrival (= source) order
        while True:
            item = await self.in_q.get()
            if item is EOF:
                break
            ready.append(item)
            if len(ready) >= size:
                await self._emit(self._assemble(ready, size))
        if ready:
            if self.spec.drop_last:
                for ref in ready:
                    ref.slab.consume_row()
                for ref in ready:
                    ref.slab.force_seal()
            else:
                # seal every slab the tail touches: a non-primary slab fully
                # drained into the final partial batch would otherwise stay
                # unsealed (the binder never finished it) and leak
                tail_slabs = list({id(r.slab): r.slab for r in ready}.values())
                await self._emit(self._assemble(ready, len(ready)))
                for slab in tail_slabs:
                    slab.force_seal()
        # A slab whose remaining assigned rows ALL failed upstream sends no
        # ref here at all — it is in use, unsealed, and nothing above can
        # reach it.  EOF means upstream is fully drained (queues preserve
        # order), so sealing every pending slab is safe and lets the
        # arena's hole accounting recycle it instead of leaking it until
        # teardown.
        self.spec.arena.seal_pending()

    def _assemble(self, ready: list[Any], n: int) -> Any:
        refs = ready[:n]
        del ready[:n]
        primary = refs[0].slab
        in_batch = 0
        for pos, ref in enumerate(refs):
            if ref.slab is primary:
                in_batch += 1
                # In-place compaction reads slot `ref.slot` into row `pos`;
                # rows < pos are already compacted destinations, so a source
                # below pos was ALREADY OVERWRITTEN — only an out-of-order
                # upstream (output_order="completion") produces that, and it
                # must fail loudly rather than emit duplicated rows.
                if ref.slot < pos:
                    raise RuntimeError(
                        f"aggregate_into stage {self.spec.name!r}: ref "
                        f"{ref!r} arrived after row {pos} was compacted — "
                        "the upstream stage must preserve input order"
                    )
                if ref.slot == pos:
                    continue
            for key, arr in primary.arrays.items():
                arr[pos] = ref.slab.arrays[key][ref.slot]
            if ref.slab is not primary:
                ref.slab.consume_row()
        # Emitting a sealed slab while some of its rows are still pending
        # upstream would recycle memory those refs point into.  Together
        # with the monotonic-slot check above, this makes an out-of-order
        # upstream (output_order="completion") fail loudly instead of
        # corrupting data.
        if (
            primary.sealed
            and in_batch + primary.holes + primary.drained < primary.assigned
        ):
            raise RuntimeError(
                f"aggregate_into stage {self.spec.name!r}: emitted slab "
                f"{primary!r} still has pending rows upstream — the "
                "upstream stage must preserve input order"
            )
        if not primary.sealed:
            primary.force_seal()  # partial final batch: no more rows coming
        primary.mark_emitted()
        return primary.as_batch(n)

    async def _run_disaggregate(self) -> None:
        assert self.in_q is not None
        while True:
            item = await self.in_q.get()
            if item is EOF:
                break
            for sub in item:
                await self._emit(sub)

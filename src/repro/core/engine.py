"""Stage runners: the coroutines that make up a pipeline (paper §5.5).

Each stage is a coroutine scheduled on the event loop that runs on the
scheduler thread.  A stage pulls items from its input ``MonitoredQueue``,
applies its function with up to ``concurrency`` tasks in flight, and pushes
results to its output queue.  Synchronous functions are delegated to the
executor (thread pool by default, user-supplied process pool optionally) via
``loop.run_in_executor`` — this is where GIL-releasing functions actually run
concurrently.  Coroutine functions are awaited on the loop itself and never
touch the pool (paper §5.2: coroutines are not constrained by the GIL).

Chunked + fused execution (amortizing the loop out of the hot path)
-------------------------------------------------------------------
The per-item path costs ~4-5 event-loop round trips per stage (queue
get/put, ``ensure_future``, semaphore, executor dispatch); once the stage
functions themselves are cheap (mmap reads, slot binding), that loop-side
overhead IS the pipeline's ceiling — and it does not parallelize, because
every stage's bookkeeping runs on the one scheduler thread.  Two
amortizations make the per-item cost O(items/chunk):

* **chunking** (``pipe(..., chunk=N)``): the stage pulls up to N items per
  queue hop (``MonitoredQueue.get_many``), dispatches ONE executor call
  that applies the stage function to each item *inside the worker thread*,
  and pushes the surviving results back with one hop (``put_many``).
  Ordered/unordered semantics, per-item error holes (``OnError.SKIP``
  drops only the failing item of a chunk), and backpressure (``concurrency``
  bounds in-flight *chunks*; queues stay bounded) are preserved.  Per-item
  timeouts are enforced post hoc inside the worker — an item whose run
  exceeded ``timeout`` is recorded as a per-item timeout failure — plus a
  whole-chunk ``wait_for`` backstop (``timeout × len(chunk)``) against a
  permanently hung function, which takes its whole chunk with it.
  Chunking requires a sync stage function (an async fn never leaves the
  loop, so there is nothing to amortize).

* **fusion** (``PipelineBuilder.fuse("read", "decode")`` or
  ``build(auto_fuse=True)``): adjacent sync, same-executor pipe stages
  collapse into a single executor call per item/chunk — an entire queue +
  task layer disappears.  The fused runtime keeps one ``StageStats`` per
  original stage (phase timings are recorded inside the worker), so
  ``Pipeline.stats()`` still reports the fused stages as separate rows;
  each phase keeps its own ``on_error``/``timeout``, and a failure is
  attributed to the phase that raised.

The hot path ends at the device, and the same amortization now covers the
last leg.  A **vectorized chunk stage** (``pipe(fn, chunk=N,
vectorized=True)``) hands the whole drained chunk to ``fn`` as one list —
the shape ``DeviceTransfer.transfer_many`` uses to issue a chunk of
``device_put`` dispatches per executor call — and on the consumer side
``Pipeline.get_items(n)`` drains up to *n* sink batches per cross-thread
round trip (``MonitoredQueue.get_many`` through the sink).  ``get_item``
and ``get_items`` share one consumer-side stash and the same lossless
timeout-resume contract: a call that times out leaves its still-running
getter parked, the next call (either flavor) resumes it, order is
preserved, EOF surfaces exactly once.  End to end a batch costs O(1/chunk)
loop hops from slab assembly to the accelerator (see ``data/loader.py``,
"The hot path to the device").

Straggler slow lane (``pipe(..., straggler_after=...)``)
--------------------------------------------------------
Chunked execution has a failure mode of its own: one slow item holds its
whole chunk hostage (MinatoLoader's observation — once raw throughput is
high, the tail of the item-latency distribution IS the bottleneck).  A
chunked stage with a ``straggler_after`` soft deadline runs its items
item-major through a bounded side executor (the pipeline's
``StragglerPool``): each item is submitted to the pool and awaited for at
most ``straggler_after`` seconds.  An item that finishes in time behaves
exactly like the phase-major path; one that does not is *detached* — the
chunk completes and emits without it, and a ``_Detached`` marker holds its
position.  An order-preserving stage re-inserts the straggler's result at
its original position (the emitter awaits the marker; processing of later
chunks continues meanwhile, bounded by ``straggler_runahead`` extra parked
chunks); an ``output_order="completion"`` stage emits the result whenever
it lands.  A straggler that ultimately *fails* becomes a normal per-item
failure hole under ``OnError.SKIP`` (or tears the pipeline down under
``FAIL``).  When the pool is saturated the item runs inline instead (no
deadline protection — counted as ``straggler_shed``), so the slow lane can
degrade but never deadlock.  ``StageStats`` grows ``stragglers`` /
``straggler_time`` / ``straggler_shed``.

EOF protocol: exactly one ``EOF`` sentinel traverses each queue.  On the
normal path a stage *blocks* putting EOF (downstream is draining, so this
terminates).  On the exceptional path (fail-fast error or cancellation) it
*force-puts* EOF without blocking so teardown can never deadlock on a full
queue whose consumer is already dead.  ``get_many`` only ever surfaces EOF
as the last element of a chunk, so a partial tail chunk is processed
normally before the stage winds down.

Failure semantics
-----------------
What happens when a stage function misbehaves, from mildest to hardest:

* **Per-item failure, ``on_error="skip"`` (default):** the exception is
  logged, counted in that phase's ``num_failed`` row, and ONLY that item
  is dropped — its chunk-mates and the rest of the stream are untouched.
  On the zero-copy loader path the dropped item's slab slot is marked as a
  hole and compacted away downstream.
* **Per-item failure, ``on_error="fail"``:** the stage raises
  ``PipelineFailure`` naming the raising phase (``.stage``/``.phase``; for
  a fused runtime that is the original sub-stage, with the composite name
  in ``.fused_stage``) and the item's stage-stream index
  (``.item_index``), the whole pipeline cancels, and the consumer sees the
  failure on its next ``get_item``.  Stats are recorded *before* the
  raise, so the dashboard shows the failure even when it is fatal.
* **Slow item (chunked stage with ``straggler_after``):** detached to the
  straggler pool — deferred, not failed.  See "Straggler slow lane".
* **Slow item (``timeout=``):** per-item timeouts are enforced post hoc
  (a thread cannot be preempted mid-call): the item is recorded as a
  timeout failure with the same skip/fail semantics as any other failure.
* **Hung item (never returns):** the whole-chunk ``wait_for`` backstop
  (``sum(phase timeouts) × len(chunk)``, armed only when every phase has a
  timeout) abandons the chunk: every item in it is recorded as failed, the
  hung worker thread is left to die with its call (it cannot be killed),
  and the stage moves on — or tears down under ``on_error="fail"``.
* **Stalled pipeline (no backstop armed, or stuck outside a stage fn):**
  nothing in-engine can fire; this is what ``core.health.HealthMonitor``
  exists for — it watches ``Pipeline.stats()`` for progress, sheds
  optional work while DEGRADED, and raises a structured
  ``PipelineStalled`` (naming the suspect stage) instead of letting the
  consumer block forever.

Stats rows: each phase of each stage is one row.  ``num_in``/``num_out``
count items entering/leaving the phase, ``num_failed`` its dropped items,
``task_time`` seconds inside its function, ``get_wait``/``put_wait``
starvation/backpressure, ``stragglers``/``straggler_time``/
``straggler_shed`` the slow-lane counters (first phase of the stage).

Observability
-------------
Three layers, cheapest first (see ``core.trace`` / ``core.metrics``):

* **Counters** (always on): the ``StageStats`` rows above, snapshotted by
  ``Pipeline.stats()`` and rendered by ``format_stats``.  Lifetime
  averages only.
* **Time series**: ``core.metrics.StatsHistory`` rings those snapshots on
  the consumer's cadence and serves *windowed* deltas — current qps /
  occupancy / wait fractions per stage.  ``HealthMonitor`` derives its
  HEALTHY/DEGRADED/STALLED verdicts from the same history; a
  ``MetricsExporter`` serves everything as Prometheus text on
  ``/metrics``.
* **Flight recorder**: ``core.trace.Tracer`` — per-thread ring buffers of
  span/instant events, exported as Chrome Trace Event JSON.

Tracer lifecycle: construct a ``Tracer``, pass it to ``build(trace=...)``
(engine + queue spans) and/or install it process-wide with
``trace.set_tracer`` / the ``tracing()`` context manager (shard fetches,
device transfers, health, chaos — subsystems not built by the builder);
after the run, ``tracer.export("trace.json")`` and open it in
https://ui.perfetto.dev.  Overhead guarantees, gated by
``benchmarks/bench_trace.py``: disabled tracing costs one attribute check
per site (≤1% on the passthrough workload); enabled tracing reuses the
clock readings the stats counters already take at chunk boundaries (no new
``monotonic()`` calls on the hot path) and appends one tuple to a
lock-free per-thread ring (≥0.95x untraced throughput).

Reading a Perfetto trace of a chunked+fused pipeline: each worker thread
is one track; a chunked stage shows one ``stage`` span per *phase* per
chunk (a fused ``read+decode`` chunk renders as back-to-back ``read`` and
``decode`` spans covering the whole chunk, with ``items=`` in the span
args), so per-item work is visible as span length ÷ items.  The scheduler
thread's track carries the ``queue`` category: ``get_wait q:X`` spans mean
X's consumer is starved (upstream too slow), ``put_wait q:X`` means X is
full (downstream too slow) — the same backpressure story as the counters,
but time-resolved.  ``straggler`` instants mark detach/resolve pairs, and
``shard``/``transfer`` spans (cache fetches, host→device copies) come from
the data layer when a process-wide tracer is installed.
"""

from __future__ import annotations

import asyncio
import dataclasses
import inspect
import itertools
import logging
import threading
import time
from concurrent.futures import Executor, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, AsyncIterable, Callable, Iterable

from ._compat import TaskGroup
from .errors import OnError, PipelineFailure
from .queues import EOF, MonitoredQueue
from .stats import StageStats
from .trace import NULL_TRACER

logger = logging.getLogger("repro.core")


def _is_async_callable(fn: Callable) -> bool:
    if inspect.iscoroutinefunction(fn):
        return True
    call = getattr(fn, "__call__", None)  # noqa: B004 - callables/partials
    return call is not None and inspect.iscoroutinefunction(call)


class StragglerPool:
    """Bounded side executor for deadline-detached items (one per pipeline).

    ``try_submit`` reserves a worker *at submit time* and returns ``None``
    when all workers are claimed — the caller then runs the item inline
    instead.  Without the reservation, submissions would queue unboundedly
    inside the ``ThreadPoolExecutor`` while stragglers hog every worker,
    and never-started items would later be "detached" having never run —
    spurious deferrals that re-serialize the stream for nothing.
    """

    def __init__(self, max_workers: int = 8):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        self.max_workers = max_workers
        self._ex = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-straggler"
        )
        self._lock = threading.Lock()
        self._in_flight = 0

    def try_submit(self, fn: Callable, *args) -> Future | None:
        with self._lock:
            if self._in_flight >= self.max_workers:
                return None
            self._in_flight += 1
        try:
            fut = self._ex.submit(fn, *args)
        except RuntimeError:  # shutdown race: pipeline is tearing down
            with self._lock:
                self._in_flight -= 1
            return None
        fut.add_done_callback(self._release)
        return fut

    def _release(self, _fut: Future) -> None:
        with self._lock:
            self._in_flight -= 1

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._in_flight

    def shutdown(self) -> None:
        # wait=False: a hung straggler's thread cannot be interrupted, and
        # teardown must not block on it (same contract as the chunk backstop)
        self._ex.shutdown(wait=False, cancel_futures=True)


class _Detached:
    """Positional marker for an item detached to the straggler pool: holds
    the pool future and the item's stage-stream index for provenance."""

    __slots__ = ("future", "index")

    def __init__(self, future: Future, index: int):
        self.future = future
        self.index = index


#: return marker from ``_resolve_straggler``: the straggler produced no
#: emittable value (it failed under OnError.SKIP, or timed out)
_DROPPED = object()


@dataclasses.dataclass
class StageSpec:
    """One entry built by ``PipelineBuilder``."""

    kind: str  # "source" | "pipe" | "aggregate" | "aggregate_into" | "disaggregate"
    name: str
    fn: Callable | None = None
    source: Iterable | AsyncIterable | None = None
    concurrency: int = 1
    executor: Executor | None = None  # None -> pipeline default thread pool
    output_order: str = "input"  # "input" | "completion"
    on_error: OnError = OnError.SKIP
    timeout: float | None = None
    agg_size: int = 0
    drop_last: bool = False
    queue_size: int = 2  # output queue bound (per stage)
    arena: Any = None  # SlabArena for kind == "aggregate_into" (duck-typed)
    cache: Any = None  # shard cache/prefetcher probed for stats (duck-typed)
    chunk: int = 1  # items per executor dispatch (chunked execution)
    #: the fn takes the whole chunk (a list) and returns a same-length,
    #: same-order list — lets numpy-style stages batch their own lookups.
    #: The fn owns per-item robustness: an exception it raises fails the
    #: WHOLE chunk (one failure record per item under SKIP).
    vectorized: bool = False
    #: phases of a FUSED stage (builder.fuse / auto_fuse): the original
    #: StageSpecs, applied back to back inside one executor call.  Empty for
    #: a plain stage.  A fused spec's fn is None; concurrency/chunk are the
    #: max over its phases; on_error/timeout/cache stay per phase.
    fused: tuple = ()
    #: soft per-item deadline (seconds): a chunked item exceeding it is
    #: detached to the pipeline's straggler pool so its chunk can emit
    #: without it (None = no slow lane).  Requires chunk > 1 + sync fn.
    straggler_after: float | None = None
    #: extra parked chunks the ordered emitter may run ahead while awaiting
    #: a detached straggler (0 = default of 3 × concurrency).  This bounds
    #: how much straggler latency the stage can hide: roughly
    #: (concurrency + straggler_runahead) × chunk items of cover.
    straggler_runahead: int = 0

    @property
    def phases(self) -> tuple:
        """The per-phase sub-specs this runtime executes ((self,) if plain)."""
        return self.fused or (self,)

    @property
    def input_chunk(self) -> int:
        """How many items this stage wants per queue hop from upstream —
        what the producer's output queue is auto-widened to.  Only a
        chunked pipe stage widens: ``chunk=`` is an explicit opt-in by the
        stage author, who thereby asserts the items are cheap to buffer
        chunk-deep.  Aggregate stages also drain via ``get_many`` but their
        items can be heavyweight (whole decoded samples on the list-collate
        path), so they make do with whatever the producer's ``queue_size``
        allows — raise it explicitly where the items are known-small."""
        return self.chunk if self.kind == "pipe" else 1


class StageRuntime:
    """Binds a StageSpec to queues/stats and runs it."""

    def __init__(
        self,
        spec: StageSpec,
        in_q: MonitoredQueue | None,
        out_q: MonitoredQueue,
        default_executor: Executor,
        straggler_pool: StragglerPool | None = None,
        tracer=None,
    ):
        self.spec = spec
        self.in_q = in_q
        self.out_q = out_q
        self.default_executor = default_executor
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._straggler_pool = (
            straggler_pool if spec.straggler_after is not None else None
        )
        # One StageStats per phase: a fused stage keeps reporting its
        # original stages as separate dashboard rows (per-phase timing is
        # recorded inside the worker).  A plain stage has exactly one phase.
        self.phases: tuple[StageSpec, ...] = spec.phases
        self.phase_stats = [
            StageStats(
                name=p.name,
                concurrency=spec.concurrency,
                chunk=spec.chunk,
                # autotune may only propose chunk= where pipe() accepts it
                chunkable=p.kind == "pipe" and not _is_async_callable(p.fn),
            )
            for p in self.phases
        ]
        for p, st in zip(self.phases, self.phase_stats):
            if p.arena is not None:
                st.arena = p.arena  # memory-pressure visibility
            if p.cache is not None:
                st.cache = p.cache  # shard-cache visibility
        self.stats = self.phase_stats[0]
        if in_q is not None:
            # input waits (starvation) are charged to the first phase ...
            in_q.consumer_stats = self.phase_stats[0]
        # ... output waits (backpressure) to the last.
        out_q.producer_stats = self.phase_stats[-1]

    # ------------------------------------------------------------------
    async def _call(self, item: Any) -> Any:
        """Invoke the stage function for one item (async- or executor-path)."""
        fn = self.spec.fn
        assert fn is not None
        if _is_async_callable(fn):
            coro = fn(item)
        else:
            loop = asyncio.get_running_loop()
            ex = self.spec.executor or self.default_executor
            coro = loop.run_in_executor(ex, fn, item)
        if self.spec.timeout is not None:
            return await asyncio.wait_for(coro, self.spec.timeout)
        return await coro

    async def _guarded(self, unit: tuple[int, Any]) -> tuple[bool, Any]:
        """Run one task; returns (ok, result). Raises only in fail-fast mode.
        ``unit`` is ``(stage-stream index, item)`` — the index feeds failure
        provenance (``PipelineFailure.item_index``)."""
        idx, item = unit
        t0 = time.monotonic()
        try:
            result = await self._call(item)
            dt = time.monotonic() - t0
            self.stats.record_task(dt)
            if self.tracer.enabled:
                self.tracer.complete(self.spec.name, "stage", t0, dt)
            return True, result
        except asyncio.CancelledError:
            raise
        except Exception as e:
            dt = time.monotonic() - t0
            self.stats.record_task(dt)
            if self.tracer.enabled:
                self.tracer.complete(self.spec.name, "stage", t0, dt, {"error": repr(e)})
            self.stats.record_failure(e)
            logger.warning(
                "stage %s failed on item #%d: %r", self.spec.name, idx, e
            )
            if self.spec.on_error is OnError.FAIL:
                raise PipelineFailure(self.spec.name, e, item_index=idx) from e
            return False, None

    async def _emit(self, item: Any) -> None:
        await self.out_q.put(item)
        self.phase_stats[-1].record_out()

    async def _emit_many(self, items: list[Any]) -> None:
        await self.out_q.put_many(items)
        self.phase_stats[-1].record_out_many(len(items))

    # -- chunked / fused execution ----------------------------------------
    def _apply_chunk(self, items: list[Any]) -> tuple:
        """Runs IN the worker thread: apply every phase to every item.

        This is the whole point of chunked execution — one executor
        dispatch covers ``len(items) × len(phases)`` function calls that
        the per-item path would each pay a loop round trip for.  Phases
        run phase-major (phase k over the whole chunk, then phase k+1 over
        its survivors): order within the chunk is preserved, timing costs
        two clock reads per phase per CHUNK instead of two per item, and
        the fused stages still get separate per-phase dashboard rows.
        Failures are caught per item — a bad sample must not take its
        chunk-mates with it.  Per-item clocks run only for phases with a
        ``timeout`` (post-hoc enforcement needs them).

        Returns ``(survivors, per_phase, failures)``: surviving values in
        input order, ``(n_entered, seconds)`` per phase reached, and
        ``(phase_idx, chunk_pos, exc)`` per failed item — ``chunk_pos`` is
        the failing item's position in the ORIGINAL chunk (None when a
        vectorized phase failed: attribution to one item is impossible).
        """
        per_phase: list[tuple[int, float]] = []
        failures: list[tuple[int, int | None, BaseException]] = []
        values = items
        # original-chunk position of values[j]; None = identity (no failures
        # yet), so the failure-free hot path never touches it
        positions: list[int] | None = None
        for k, phase in enumerate(self.phases):
            fn = phase.fn
            timeout = phase.timeout
            entered = len(values)
            survivors: list[Any] = []
            failed_js: list[int] = []  # this phase's failed input indices
            t0 = time.monotonic()
            if phase.vectorized:
                # one call over the whole chunk; the fn owns per-item
                # robustness, so a raise here loses every item of the chunk
                try:
                    survivors = list(fn(values))
                    if len(survivors) != entered:
                        raise ValueError(
                            f"vectorized stage {phase.name!r} returned "
                            f"{len(survivors)} items for a chunk of {entered}"
                        )
                except Exception as e:  # noqa: BLE001
                    survivors = []
                    failures.extend((k, None, e) for _ in range(entered))
                dt = time.monotonic() - t0
                if survivors and timeout is not None and dt > timeout * entered:
                    failures.extend(
                        (
                            k,
                            None,
                            asyncio.TimeoutError(
                                f"chunk exceeded {timeout}s/item in stage "
                                f"{phase.name!r} ({dt:.3f}s for {entered})"
                            ),
                        )
                        for _ in range(entered)
                    )
                    survivors = []
                per_phase.append((entered, dt))
                if self.tracer.enabled:
                    self.tracer.complete(
                        phase.name, "stage", t0, dt, {"items": entered, "vectorized": True}
                    )
                values = survivors
                if not values:
                    break
                continue
            if timeout is None:
                append = survivors.append
                for v in values:
                    try:
                        append(fn(v))
                    except Exception as e:  # noqa: BLE001 - per-item robustness
                        # input index of the failing item: every earlier
                        # item either survived or failed, so no enumerate
                        # is needed on the hot path
                        j = len(survivors) + len(failed_js)
                        failed_js.append(j)
                        failures.append(
                            (k, positions[j] if positions is not None else j, e)
                        )
            else:
                for v in values:
                    t1 = time.monotonic()
                    try:
                        out = fn(v)
                    except Exception as e:  # noqa: BLE001
                        j = len(survivors) + len(failed_js)
                        failed_js.append(j)
                        failures.append(
                            (k, positions[j] if positions is not None else j, e)
                        )
                        continue
                    dt = time.monotonic() - t1
                    if dt > timeout:
                        # post-hoc per-item timeout: the thread cannot be
                        # preempted mid-call, but the item is still dropped
                        # with the same skippable-failure semantics
                        j = len(survivors) + len(failed_js)
                        failed_js.append(j)
                        failures.append((
                            k,
                            positions[j] if positions is not None else j,
                            asyncio.TimeoutError(
                                f"item exceeded {timeout}s in stage "
                                f"{phase.name!r} ({dt:.3f}s)"
                            ),
                        ))
                    else:
                        survivors.append(out)
            phase_dt = time.monotonic() - t0
            per_phase.append((entered, phase_dt))
            if self.tracer.enabled:
                # the span reuses the two clock reads the stats already paid
                # for: one per-phase-per-chunk event, not per item
                self.tracer.complete(
                    phase.name, "stage", t0, phase_dt, {"items": entered}
                )
            if failed_js:
                # survivors' original positions, for attributing failures in
                # LATER phases back to the original chunk
                gone = set(failed_js)
                src = positions if positions is not None else range(entered)
                positions = [p for x, p in enumerate(src) if x not in gone]
            values = survivors
            if not values:
                break  # nothing left for later phases (they record 0 items)
        return values, per_phase, failures

    def _run_item(self, v: Any) -> tuple:
        """Run ALL phases over ONE item, item-major (the slow-lane unit of
        work — runs on a straggler-pool thread, or inline on the chunk
        worker when the pool is saturated).

        Returns ``(ok, value, failed_phase, exc, times, elapsed)`` where
        ``times`` is ``[(phase_idx, seconds), ...]`` for each phase reached
        — the record a chunk worker (fast item) or the loop-side straggler
        resolution (detached item) folds into stats.  Per-phase ``timeout``
        keeps its post-hoc semantics.
        """
        times: list[tuple[int, float]] = []
        t_start = time.monotonic()
        for k, phase in enumerate(self.phases):
            t0 = time.monotonic()
            try:
                out = phase.fn(v)
            except Exception as e:  # noqa: BLE001 - per-item robustness
                dt = time.monotonic() - t0
                times.append((k, dt))
                if self.tracer.enabled:
                    self.tracer.complete(
                        phase.name, "stage", t0, dt,
                        {"slowlane": True, "error": repr(e)},
                    )
                return False, None, k, e, times, time.monotonic() - t_start
            dt = time.monotonic() - t0
            times.append((k, dt))
            if self.tracer.enabled:
                self.tracer.complete(phase.name, "stage", t0, dt, {"slowlane": True})
            if phase.timeout is not None and dt > phase.timeout:
                exc = asyncio.TimeoutError(
                    f"item exceeded {phase.timeout}s in stage "
                    f"{phase.name!r} ({dt:.3f}s)"
                )
                return False, None, k, exc, times, time.monotonic() - t_start
            v = out
        return True, v, -1, None, times, time.monotonic() - t_start

    def _apply_chunk_slowlane(self, items: list[Any]) -> tuple:
        """Chunk application with the straggler slow lane (worker thread).

        Items run item-major through the pipeline's ``StragglerPool``; each
        is awaited for at most ``straggler_after`` seconds.  A fast item is
        folded exactly like the phase-major path; a slow one is detached —
        its ``_Detached`` marker keeps its position in ``entries`` and the
        chunk moves on.  Pool saturated → the item runs inline (no deadline
        protection; counted as shed).

        Returns ``(entries, per_phase, failures, (n_detached, n_shed))``
        where ``entries`` is input-ordered values interleaved with
        ``_Detached`` markers and ``failures`` matches ``_apply_chunk``.
        """
        pool = self._straggler_pool
        deadline = self.spec.straggler_after
        entries: list[Any] = []
        per_phase = [[0, 0.0] for _ in self.phases]
        failures: list[tuple[int, int | None, BaseException]] = []
        n_detached = 0
        n_shed = 0
        for pos, v in enumerate(items):
            fut = pool.try_submit(self._run_item, v) if pool is not None else None
            if fut is None:
                n_shed += 1
                rec = self._run_item(v)
            else:
                try:
                    rec = fut.result(timeout=deadline)
                except FuturesTimeout:
                    entries.append(_Detached(fut, pos))
                    n_detached += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "straggler:detach", "straggler",
                            {"stage": self.spec.name, "pos": pos},
                        )
                    continue
            ok, value, failed_k, exc, times, _elapsed = rec
            for k, dt in times:
                acc = per_phase[k]
                acc[0] += 1
                acc[1] += dt
            if ok:
                entries.append(value)
            else:
                failures.append((failed_k, pos, exc))
        return entries, per_phase, failures, (n_detached, n_shed)

    def _chunk_budget(self, n_items: int) -> float | None:
        """Whole-chunk hang backstop: only boundable when EVERY phase has a
        timeout (an untimed phase may legitimately run forever)."""
        if any(p.timeout is None for p in self.phases):
            return None
        return sum(p.timeout for p in self.phases) * n_items

    def _failure(
        self, k: int, exc: BaseException, item_index: int | None
    ) -> PipelineFailure:
        """A fail-fast ``PipelineFailure`` attributed to phase ``k`` (and,
        when known, the stage-stream index of the failing item)."""
        return PipelineFailure(
            self.phases[k].name,
            exc,
            item_index=item_index,
            fused_stage=self.spec.name if self.spec.fused else None,
        )

    def _record_chunk(self, outcome: tuple, base: int) -> list[Any]:
        """Fold a chunk's worker-side outcome into per-phase stats (on the
        loop thread — StageStats is single-writer) and return the surviving
        entries in input order (values, plus ``_Detached`` markers on the
        slow-lane path).  ``base`` is the chunk's first stage-stream index,
        for failure provenance.  Per-chunk cost is O(phases + failures),
        not O(items).  Raises ``PipelineFailure`` if a failing phase is
        fail-fast (after recording the whole chunk, so the dashboard shows
        it even when one item tears the pipeline down)."""
        if len(outcome) == 4:
            entries, per_phase, failures, (n_detached, n_shed) = outcome
            self.phase_stats[0].straggler_shed += n_shed
            if n_detached:
                # rebase the markers' chunk-local positions to stage-stream
                # indices (the worker does not know the chunk's base)
                for e in entries:
                    if type(e) is _Detached:
                        e.index += base
        else:
            entries, per_phase, failures = outcome
        for k, (entered, dt) in enumerate(per_phase):
            st = self.phase_stats[k]
            if k > 0:
                st.num_in += entered  # survivors of phase k-1 enter phase k
            st.record_task(dt)
            if k < len(self.phase_stats) - 1:
                # what this phase handed to the next phase, in-worker
                survived = per_phase[k + 1][0] if k + 1 < len(per_phase) else 0
                st.record_out_many(survived)
        failure: PipelineFailure | None = None
        for k, pos, exc in failures:
            self.phase_stats[k].record_failure(exc)
            logger.warning("stage %s failed on item: %r", self.phases[k].name, exc)
            if self.phases[k].on_error is OnError.FAIL and failure is None:
                failure = self._failure(
                    k, exc, base + pos if pos is not None else None
                )
        if failure is not None:
            raise failure
        return entries

    async def _guarded_chunk(self, unit: tuple[int, list[Any]]) -> list[Any]:
        """Run one chunk task; returns surviving entries (input order).
        Raises only in fail-fast mode (or on cancellation).  ``unit`` is
        ``(first stage-stream index, items)``."""
        base, items = unit
        loop = asyncio.get_running_loop()
        ex = self.spec.executor or self.default_executor
        apply = (
            self._apply_chunk_slowlane
            if self._straggler_pool is not None
            else self._apply_chunk
        )
        coro = loop.run_in_executor(ex, apply, items)
        budget = self._chunk_budget(len(items))
        try:
            if budget is not None:
                outcomes = await asyncio.wait_for(coro, budget)
            else:
                outcomes = await coro
        except asyncio.CancelledError:
            raise
        except asyncio.TimeoutError as e:
            # the whole-chunk backstop tripped: the worker is hung, so every
            # item of this chunk is lost (charged to the first timed phase)
            k = next(i for i, p in enumerate(self.phases) if p.timeout is not None)
            st = self.phase_stats[k]
            for _ in items:
                st.record_failure(e)
            logger.warning(
                "stage %s: chunk of %d items exceeded the %0.1fs chunk budget",
                self.phases[k].name, len(items), budget,
            )
            if any(p.on_error is OnError.FAIL for p in self.phases):
                raise self._failure(k, e, None) from e
            return []
        return self._record_chunk(outcomes, base)

    async def _resolve_straggler(self, d: _Detached) -> Any:
        """Await a detached item's completion (loop thread) and fold its
        record into stats.  Returns the item's value, or ``_DROPPED`` when
        it produced none (failure hole / timeout).  Raises
        ``PipelineFailure`` when the failing phase is fail-fast.

        The wait is bounded by the same budget rule as chunks (sum of phase
        timeouts — armed only when every phase has one); a straggler that
        outlives it is recorded as a timeout failure and its thread is left
        to finish on its own (it cannot be preempted).
        """
        st0 = self.phase_stats[0]
        budget = self._chunk_budget(1)
        fut = asyncio.wrap_future(d.future)
        try:
            if budget is not None:
                rec = await asyncio.wait_for(fut, budget)
            else:
                rec = await fut
        except asyncio.CancelledError:
            raise
        except asyncio.TimeoutError as e:
            k = next(i for i, p in enumerate(self.phases) if p.timeout is not None)
            st0.stragglers += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "straggler:budget_exceeded", "straggler",
                    {"stage": self.spec.name, "index": d.index, "budget_s": budget},
                )
            self.phase_stats[k].record_failure(e)
            logger.warning(
                "stage %s: straggler item #%d exceeded its %0.1fs budget",
                self.phases[k].name, d.index, budget,
            )
            if any(p.on_error is OnError.FAIL for p in self.phases):
                raise self._failure(k, e, d.index) from e
            return _DROPPED
        ok, value, failed_k, exc, times, elapsed = rec
        st0.stragglers += 1
        st0.straggler_time += elapsed
        if self.tracer.enabled:
            self.tracer.instant(
                "straggler:resolve", "straggler",
                {"stage": self.spec.name, "index": d.index,
                 "elapsed_s": round(elapsed, 6), "ok": ok},
            )
        last_reached = times[-1][0] if times else 0
        for k, dt in times:
            st = self.phase_stats[k]
            if k > 0:
                st.num_in += 1
            st.record_task(dt)
            if k < last_reached:
                st.record_out_many(1)  # it went on to the next phase
        if ok:
            return value
        self.phase_stats[failed_k].record_failure(exc)
        logger.warning(
            "stage %s failed on straggler item #%d: %r",
            self.phases[failed_k].name, d.index, exc,
        )
        if self.phases[failed_k].on_error is OnError.FAIL:
            raise self._failure(failed_k, exc, d.index) from exc
        return _DROPPED

    # -- top-level runner --------------------------------------------------
    async def run(self) -> None:
        """Run the stage body with the EOF teardown protocol."""
        body = {
            "source": self._run_source,
            "pipe": self._run_pipe,
            "aggregate": self._run_aggregate,
            "aggregate_into": self._run_aggregate_into,
            "disaggregate": self._run_disaggregate,
        }[self.spec.kind]
        try:
            await body()
            await self.out_q.put(EOF)  # normal path: block until accepted
        except BaseException:
            self.out_q.put_nowait_force(EOF)  # teardown path: never block
            raise

    # -- stage bodies ----------------------------------------------------
    async def _run_source(self) -> None:
        src = self.spec.source
        if hasattr(src, "__aiter__"):
            async for item in src:  # type: ignore[union-attr]
                await self._emit(item)
        else:
            # A synchronous iterable is advanced on the loop thread.  The
            # per-item cost of sources (paths / indices) is tiny; blocking
            # sources should be wrapped in an async generator or offloaded
            # with a pipe stage instead.  Emission is batched up to the
            # output queue's capacity so a chunk-pulling consumer costs one
            # source hop per chunk, not per item.
            it = iter(src)  # type: ignore[arg-type]
            n = max(1, self.out_q.maxsize)
            while True:
                chunk = list(itertools.islice(it, n))
                if not chunk:
                    break
                await self._emit_many(chunk)

    def _pipe_adapters(self) -> tuple[Callable, Callable, Callable]:
        """The three points where the per-item and chunked pipe runners
        differ:

        * ``pull()`` → ``(units, eof)``: zero or one dispatchable work
          units (a single item, or a non-empty chunk list) pulled with one
          queue interaction;
        * ``run(unit)`` → outcome: the unit's stage function(s), guarded;
        * ``emit(outcome)``: push whatever survived downstream.

        ``run`` and ``emit`` are separate because the ordered runner must
        run units concurrently but emit strictly in FIFO dispatch order.
        Everything else — the concurrency semaphore, the FIFO task queue,
        the EOF/teardown protocol — is shared scaffolding in
        ``_run_pipe_ordered``/``_run_pipe_unordered`` and exists exactly
        once.
        """
        if self.spec.chunk > 1 or self.spec.fused:
            # running stage-stream index of the next chunk's first item —
            # pulled single-threadedly by the reader, so a plain closure
            # counter is race-free and failure provenance costs nothing
            next_base = 0

            async def pull() -> tuple[tuple, bool]:
                nonlocal next_base
                chunk = await self.in_q.get_many(self.spec.chunk)
                eof = chunk[-1] is EOF
                if eof:
                    chunk.pop()  # the partial tail chunk still runs
                if not chunk:
                    return (), eof
                base = next_base
                next_base += len(chunk)
                return ((base, chunk),), eof

            if self._straggler_pool is not None:

                async def emit(entries: list[Any]) -> None:
                    # hole-fill: a _Detached marker is awaited AT its
                    # position, so the stream stays in input order; later
                    # chunks keep processing meanwhile (the widened task
                    # queue provides the runahead)
                    batch: list[Any] = []
                    for e in entries:
                        if type(e) is _Detached:
                            if batch:
                                await self._emit_many(batch)
                                batch = []
                            v = await self._resolve_straggler(e)
                            if v is not _DROPPED:
                                batch.append(v)
                        else:
                            batch.append(e)
                    if batch:
                        await self._emit_many(batch)

            else:

                async def emit(results: list[Any]) -> None:
                    if results:
                        await self._emit_many(results)

            return pull, self._guarded_chunk, emit

        next_idx = itertools.count()

        async def pull() -> tuple[tuple, bool]:
            item = await self.in_q.get()
            if item is EOF:
                return (), True
            return ((next(next_idx), item),), False

        async def emit(outcome: tuple[bool, Any]) -> None:
            ok, result = outcome
            if ok:
                await self._emit(result)

        return pull, self._guarded, emit

    async def _run_pipe(self) -> None:
        if self.spec.output_order == "completion":
            await self._run_pipe_unordered()
        else:
            await self._run_pipe_ordered()

    async def _run_pipe_ordered(self) -> None:
        """Input-order-preserving concurrent map (per-item or chunked).

        A reader creates up to ``concurrency`` in-flight tasks; an emitter
        awaits them in FIFO order, so results come out in input order while
        up to N units (items, or whole chunks) are processed concurrently.
        The bounded task queue is the concurrency limiter, so backpressure
        from out_q stalls the reader.  With chunks, order is preserved
        twice over: chunks dispatch and emit in FIFO order, and
        ``_apply_chunk`` walks its items in order.
        """
        assert self.in_q is not None
        pull, run, emit = self._pipe_adapters()
        # ``sem`` is the true in-flight bound; ``task_q`` only parks tasks
        # (running or completed) in FIFO order for the emitter, so completed
        # results buffered ahead of a backpressured emitter stay bounded too.
        sem = asyncio.Semaphore(self.spec.concurrency)
        # Slow-lane runahead: while the emitter is parked on a detached
        # straggler (hole-fill), the reader may keep dispatching chunks —
        # they complete (releasing sem) and park here until the hole fills.
        # The extra depth is what lets the stage hide straggler latency;
        # without it, one straggler re-serializes the stream after
        # ``concurrency`` chunks of cover.
        depth = self.spec.concurrency
        if self._straggler_pool is not None:
            depth += self.spec.straggler_runahead or 3 * self.spec.concurrency
        task_q: asyncio.Queue[Any] = asyncio.Queue(depth)

        async def guarded_release(unit: Any) -> Any:
            try:
                return await run(unit)
            finally:
                sem.release()

        async def reader() -> None:
            try:
                eof = False
                while not eof:
                    units, eof = await pull()
                    for unit in units:
                        await sem.acquire()
                        t = asyncio.ensure_future(guarded_release(unit))
                        try:
                            await task_q.put(t)
                        except BaseException:
                            t.cancel()
                            raise
                await task_q.put(EOF)
            except BaseException:
                # Emitter is failed/cancelled (or we are); never block here.
                try:
                    task_q.put_nowait(EOF)
                except asyncio.QueueFull:
                    pass
                raise

        async def emitter() -> None:
            while True:
                t = await task_q.get()
                if t is EOF:
                    return
                await emit(await t)

        try:
            async with TaskGroup() as tg:
                tg.create_task(reader(), name=f"{self.spec.name}:reader")
                tg.create_task(emitter(), name=f"{self.spec.name}:emitter")
        except BaseException:
            while not task_q.empty():  # cancel still-pending work
                t = task_q.get_nowait()
                if t is not EOF:
                    t.cancel()
            raise

    async def _run_pipe_unordered(self) -> None:
        """Completion-order concurrent map (lower latency, no ordering
        across units; items within a chunk still emit in order)."""
        assert self.in_q is not None
        pull, run, emit = self._pipe_adapters()
        sem = asyncio.Semaphore(self.spec.concurrency)
        slowlane = self._straggler_pool is not None and (
            self.spec.chunk > 1 or self.spec.fused
        )

        async def resolve_and_emit(d: _Detached) -> None:
            v = await self._resolve_straggler(d)
            if v is not _DROPPED:
                await self._emit(v)

        async def worker(unit: Any, tg: TaskGroup) -> None:
            try:
                outcome = await run(unit)
                if slowlane:
                    # emit ready values now; a detached straggler resolves
                    # on a sibling task so it does not hold this worker's
                    # concurrency slot (in-flight resolvers are bounded by
                    # the straggler pool's size — one marker per worker)
                    ready: list[Any] = []
                    for e in outcome:
                        if type(e) is _Detached:
                            tg.create_task(resolve_and_emit(e))
                        else:
                            ready.append(e)
                    if ready:
                        await self._emit_many(ready)
                else:
                    await emit(outcome)
            finally:
                sem.release()

        async with TaskGroup() as tg:
            eof = False
            while not eof:
                units, eof = await pull()
                for unit in units:
                    await sem.acquire()
                    tg.create_task(worker(unit, tg))
            # TaskGroup's __aexit__ awaits outstanding workers (and any
            # straggler resolvers they spawned) before we return to run(),
            # which then emits EOF downstream.

    async def _run_aggregate(self) -> None:
        assert self.in_q is not None
        size = self.spec.agg_size
        buf: list[Any] = []
        eof = False
        while not eof:
            items = await self.in_q.get_many(size)  # one hop per batch-ish
            if items[-1] is EOF:
                eof = True
                items.pop()
            buf.extend(items)
            while len(buf) >= size:
                await self._emit(buf[:size])
                del buf[:size]
        if buf and not self.spec.drop_last:
            await self._emit(buf)

    async def _run_aggregate_into(self) -> None:
        """Slot-aware batching over an arena (zero-copy assembly).

        Input items are ``SlotRef``s whose rows were already written in
        place by upstream stages; this stage never buffers arrays.  In the
        clean case the first ``agg_size`` refs are exactly slab X, slots
        0..N-1, and the batch is the slab itself: zero copies.  A failed
        item upstream leaves a hole in its slab; compaction then copies the
        displaced rows (only rows at/after the hole) so emitted batches
        stay dense.  A slab drained entirely by compaction (never emitted)
        is auto-released by the arena; an emitted slab is released by the
        consumer (see ``DeviceTransfer``) after its device copy completes.

        Requires an input-order-preserving upstream: refs of slab k must
        all arrive before refs of slab k+1.
        """
        assert self.in_q is not None
        size = self.spec.agg_size
        ready: list[Any] = []  # SlotRefs, in arrival (= source) order
        eof = False
        while not eof:
            items = await self.in_q.get_many(size)  # one hop per batch-ish
            if items[-1] is EOF:
                eof = True
                items.pop()
            ready.extend(items)
            while len(ready) >= size:
                await self._emit(self._assemble(ready, size))
        if ready:
            if self.spec.drop_last:
                for ref in ready:
                    ref.slab.consume_row()
                for ref in ready:
                    ref.slab.force_seal()
            else:
                # seal every slab the tail touches: a non-primary slab fully
                # drained into the final partial batch would otherwise stay
                # unsealed (the binder never finished it) and leak
                tail_slabs = list({id(r.slab): r.slab for r in ready}.values())
                await self._emit(self._assemble(ready, len(ready)))
                for slab in tail_slabs:
                    slab.force_seal()
        # A slab whose remaining assigned rows ALL failed upstream sends no
        # ref here at all — it is in use, unsealed, and nothing above can
        # reach it.  EOF means upstream is fully drained (queues preserve
        # order), so sealing every pending slab is safe and lets the
        # arena's hole accounting recycle it instead of leaking it until
        # teardown.
        self.spec.arena.seal_pending()

    def _assemble(self, ready: list[Any], n: int) -> Any:
        refs = ready[:n]
        del ready[:n]
        primary = refs[0].slab
        in_batch = 0
        for pos, ref in enumerate(refs):
            if ref.slab is primary:
                in_batch += 1
                # In-place compaction reads slot `ref.slot` into row `pos`;
                # rows < pos are already compacted destinations, so a source
                # below pos was ALREADY OVERWRITTEN — only an out-of-order
                # upstream (output_order="completion") produces that, and it
                # must fail loudly rather than emit duplicated rows.
                if ref.slot < pos:
                    raise RuntimeError(
                        f"aggregate_into stage {self.spec.name!r}: ref "
                        f"{ref!r} arrived after row {pos} was compacted — "
                        "the upstream stage must preserve input order"
                    )
                if ref.slot == pos:
                    continue
            for key, arr in primary.arrays.items():
                arr[pos] = ref.slab.arrays[key][ref.slot]
            if ref.slab is not primary:
                ref.slab.consume_row()
        # Emitting a sealed slab while some of its rows are still pending
        # upstream would recycle memory those refs point into.  Together
        # with the monotonic-slot check above, this makes an out-of-order
        # upstream (output_order="completion") fail loudly instead of
        # corrupting data.
        if (
            primary.sealed
            and in_batch + primary.holes + primary.drained < primary.assigned
        ):
            raise RuntimeError(
                f"aggregate_into stage {self.spec.name!r}: emitted slab "
                f"{primary!r} still has pending rows upstream — the "
                "upstream stage must preserve input order"
            )
        if not primary.sealed:
            primary.force_seal()  # partial final batch: no more rows coming
        primary.mark_emitted()
        return primary.as_batch(n)

    async def _run_disaggregate(self) -> None:
        assert self.in_q is not None
        while True:
            item = await self.in_q.get()
            if item is EOF:
                break
            await self._emit_many(list(item))

"""repro.core — the SPDL pipeline engine (the paper's contribution).

Public API mirrors the paper's Listing 1: ``PipelineBuilder`` chains plain
Python functions into a thread-pool-backed, queue-connected pipeline driven
by an asyncio event loop on a dedicated scheduler thread.
"""

from .autotune import Suggestion, autotune, suggest
from .builder import PipelineBuilder
from .chaos import ChaosError, FaultInjectingStage
from .errors import OnError, PipelineFailure, PipelineStalled, PipelineStopped
from .health import (
    DegradeAction,
    HealthMonitor,
    StageHealth,
    disable_verify,
    origin_only,
    widen_sparse_threshold,
)
from .pipeline import Pipeline
from .stats import ResourceSampler, StageStatsSnapshot, format_stats

__all__ = [
    "PipelineBuilder",
    "autotune",
    "suggest",
    "Suggestion",
    "Pipeline",
    "OnError",
    "PipelineFailure",
    "PipelineStalled",
    "PipelineStopped",
    "HealthMonitor",
    "StageHealth",
    "ChaosError",
    "FaultInjectingStage",
    "DegradeAction",
    "disable_verify",
    "widen_sparse_threshold",
    "origin_only",
    "ResourceSampler",
    "StageStatsSnapshot",
    "format_stats",
]

"""repro.core — the SPDL pipeline engine (the paper's contribution).

Public API mirrors the paper's Listing 1: ``PipelineBuilder`` chains plain
Python functions into a thread-pool-backed, queue-connected pipeline driven
by an asyncio event loop on a dedicated scheduler thread.
"""

from .autotune import Suggestion, autotune, suggest
from .builder import PipelineBuilder
from .chaos import ChaosError, FaultInjectingStage
from .errors import OnError, PipelineFailure, PipelineStalled, PipelineStopped
from .health import (
    DegradeAction,
    HealthMonitor,
    StageHealth,
    disable_verify,
    origin_only,
    shrink_replication,
    widen_sparse_threshold,
)
from .metrics import MetricsExporter, MetricsServer, StatsHistory, WindowRates
from .pipeline import Pipeline
from .stats import ResourceSampler, StageStatsSnapshot, format_stats
from .trace import NULL_TRACER, Tracer, get_tracer, set_tracer, tracing

__all__ = [
    "PipelineBuilder",
    "autotune",
    "suggest",
    "Suggestion",
    "Pipeline",
    "OnError",
    "PipelineFailure",
    "PipelineStalled",
    "PipelineStopped",
    "HealthMonitor",
    "StageHealth",
    "ChaosError",
    "FaultInjectingStage",
    "DegradeAction",
    "disable_verify",
    "widen_sparse_threshold",
    "shrink_replication",
    "origin_only",
    "ResourceSampler",
    "StageStatsSnapshot",
    "format_stats",
    "Tracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
    "tracing",
    "StatsHistory",
    "WindowRates",
    "MetricsExporter",
    "MetricsServer",
]

"""Pipeline: the user-facing object (paper §5.5, Listing 1).

Running an asyncio event loop is itself blocking, so it cannot live on the
main thread; a dedicated *scheduler thread* runs the loop (paper §5.5.2) and
the loop dispatches stage work to the worker thread pool.  The main thread
only ever touches the sink queue — GIL competition is confined to the main
thread and the scheduler thread, which is the paper's central scaling trick.

The sink hop itself is chunk-pullable: ``get_items(n)`` drains up to ``n``
already-buffered items in one cross-thread round trip (the consumer-side
mirror of the engine's ``pipe(..., chunk=N)``), while ``get_item`` stays the
per-item path.  Both share one timeout-resume stash, so a polling consumer
can mix them freely without losing items or the EOF.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import contextlib
import dataclasses
import logging
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Iterator

from ._compat import BaseExceptionGroup, TaskGroup
from .engine import StageRuntime, StageSpec, StragglerPool
from .errors import PipelineFailure, PipelineStopped
from .queues import EOF, MonitoredQueue
from .stats import StageStatsSnapshot, format_stats
from .trace import NULL_TRACER

logger = logging.getLogger("repro.core")


class Pipeline:
    """A built, runnable data pipeline.

    Iterate it from the consumer thread::

        with pipeline.auto_stop():
            for batch in pipeline:
                ...

    The pipeline starts lazily on first iteration (or explicitly via
    ``start()``).  ``stop()`` cancels all stages, joins the scheduler thread
    and shuts down the default thread pool.
    """

    def __init__(
        self,
        specs: list[StageSpec],
        num_threads: int,
        sink_buffer_size: int,
        straggler_workers: int = 8,
        tracer=None,
    ):
        self._specs = specs
        self._num_threads = num_threads
        self._sink_buffer_size = sink_buffer_size
        self._straggler_workers = straggler_workers
        self._straggler_pool: StragglerPool | None = None
        # engine + queue spans go to this tracer (NULL_TRACER = off: one
        # attribute check per site); wire via ``build(trace=...)``
        self.tracer = tracer if tracer is not None else NULL_TRACER

        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._executor: ThreadPoolExecutor | None = None
        self._root_fut: concurrent.futures.Future | None = None
        self._root_task: asyncio.Task | None = None
        self._runtimes: list[StageRuntime] = []
        self._sink_q: MonitoredQueue | None = None
        # A get_item/get_items(timeout=...) that times out leaves its sink
        # getter running on the loop; it is kept here so the next call —
        # EITHER entry point — resumes it instead of scheduling a second
        # getter (which would leak sink items).  The getter resolves to a
        # chunk (list) of items; anything the resuming call doesn't want
        # right now waits in ``_stash``.
        self._pending_anext: concurrent.futures.Future | None = None
        # Consumer-side item stash: already-drained sink items not yet
        # handed out (a resumed chunk getter can return more than the
        # current call asked for).  Consumer-thread-only, like get_item.
        self._stash: deque[Any] = deque()
        # True once EOF has been drained from the sink: every later call
        # (after the stash empties) raises StopIteration instead of
        # scheduling a getter that would block forever.
        self._sink_eof = False
        # chunked sink drains completed (a get_items call that returned
        # items counts one chunk) — surfaced on the sink stage's stats row
        self._sink_drained_chunks = 0
        self._started = False
        self._stopped = False
        self._loop_ready = threading.Event()
        # Set by _root once the sink queue is installed (or by the root
        # future's done-callback if setup fails) — consumers block on this
        # instead of busy-polling.
        self._sink_ready = threading.Event()
        self._stop_callbacks: list[Any] = []

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "Pipeline":
        if self._started:
            return self
        if self._stopped:
            raise PipelineStopped("pipeline already stopped")
        self._started = True
        self._executor = ThreadPoolExecutor(
            max_workers=self._num_threads, thread_name_prefix="repro-worker"
        )
        if any(s.straggler_after is not None for s in self._specs):
            # one shared slow lane per pipeline: detached items from every
            # straggler stage compete for the same bounded worker set
            self._straggler_pool = StragglerPool(self._straggler_workers)
        self._thread = threading.Thread(
            target=self._thread_main, daemon=True, name="repro-scheduler"
        )
        self._thread.start()
        self._loop_ready.wait()
        assert self._loop is not None
        self._root_fut = asyncio.run_coroutine_threadsafe(self._root(), self._loop)
        # If the root coroutine dies before installing the sink queue, wake
        # any consumer blocked in get_item so it can surface the error.
        self._root_fut.add_done_callback(lambda _f: self._sink_ready.set())
        return self

    def _thread_main(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        self._loop_ready.set()
        try:
            loop.run_forever()
        finally:
            # Cancel anything still pending, then close.
            pending = asyncio.all_tasks(loop)
            for t in pending:
                t.cancel()
            if pending:
                loop.run_until_complete(
                    asyncio.gather(*pending, return_exceptions=True)
                )
            loop.close()

    async def _root(self) -> None:
        """Wire queues to stages and run them all under one TaskGroup."""
        self._root_task = asyncio.current_task()
        assert self._executor is not None
        queues: list[MonitoredQueue] = []
        runtimes: list[StageRuntime] = []
        in_q: MonitoredQueue | None = None
        for i, spec in enumerate(self._specs):
            size = self._sink_buffer_size if i == len(self._specs) - 1 else spec.queue_size
            if i + 1 < len(self._specs):
                # a chunk-pulling consumer (chunked pipe, aggregate) can only
                # fill its chunks from what this queue holds — widen the
                # bound to the consumer's chunk so amortization actually
                # happens (items are small: indices, refs, views)
                size = max(size, self._specs[i + 1].input_chunk)
            out_q = MonitoredQueue(
                max(1, size), name=f"q:{spec.name}", tracer=self.tracer
            )
            queues.append(out_q)
            runtimes.append(
                StageRuntime(
                    spec, in_q, out_q, self._executor,
                    straggler_pool=self._straggler_pool,
                    tracer=self.tracer,
                )
            )
            in_q = out_q
        self._runtimes = runtimes
        self._sink_q = queues[-1]
        self._sink_ready.set()
        async with TaskGroup() as tg:
            for rt in runtimes:
                tg.create_task(rt.run(), name=f"stage:{rt.spec.name}")

    def add_stop_callback(self, fn) -> None:
        """Register a callable invoked first thing in ``stop()`` — e.g. a
        ``SlabArena.close`` so executor threads blocked on ``acquire`` are
        woken before the executor is shut down."""
        self._stop_callbacks.append(fn)

    def stop(self) -> None:
        """Cancel all stages and release every resource. Idempotent."""
        if self._stopped:
            return
        self._stopped = True
        for cb in self._stop_callbacks:
            with contextlib.suppress(Exception):
                cb()
        if not self._started:
            return
        assert self._loop is not None
        if self._root_fut is not None and not self._root_fut.done():

            def _cancel() -> None:
                if self._root_task is not None:
                    self._root_task.cancel()

            self._loop.call_soon_threadsafe(_cancel)
            with contextlib.suppress(BaseException):
                self._root_fut.result(timeout=30)
        self._loop.call_soon_threadsafe(self._loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=30)
        if self._executor is not None:
            self._executor.shutdown(wait=False, cancel_futures=True)
        if self._straggler_pool is not None:
            self._straggler_pool.shutdown()

    @contextlib.contextmanager
    def auto_stop(self) -> Iterator["Pipeline"]:
        """Context manager that guarantees background threads are torn down
        (paper §5.9.1: non-daemonic threads must not outlive the program)."""
        try:
            yield self.start()
        finally:
            self.stop()

    # -- consumption --------------------------------------------------------
    async def _anext_many(self, n: int) -> list[Any]:
        """Runs on the loop: drain up to ``n`` sink items in one hop, or
        raise if the pipeline died.  ``MonitoredQueue.get_many`` blocks only
        for the first item and sweeps whatever else is buffered, so this is
        the chunked counterpart of the old per-item ``_anext`` — one
        cross-thread round trip per CHUNK instead of per item.  EOF, when
        present, is always the last element of the returned list."""
        assert self._sink_q is not None and self._root_task is not None
        get_t = asyncio.ensure_future(self._sink_q.get_many(n))
        done, _ = await asyncio.wait(
            {get_t, self._root_task}, return_when=asyncio.FIRST_COMPLETED
        )
        if get_t in done:
            items = get_t.result()
            if items and items[-1] is EOF:
                # Close the EOF-vs-error race: surface fail-fast errors.
                await asyncio.wait({self._root_task})
                self._reraise_root()
            return items
        # get_many awaits only its FIRST item; cancellation here cannot
        # strand partially-drained items (the sweep phase never awaits).
        get_t.cancel()
        self._reraise_root()
        # Root finished cleanly: the EOF is guaranteed to be in the sink.
        return await self._sink_q.get_many(n)

    @staticmethod
    def _unwrap(exc: BaseException) -> BaseException:
        """Dig the most informative leaf out of (nested) ExceptionGroups:
        prefer PipelineFailure, then any non-cancel leaf, then anything."""

        def leaves(e: BaseException):
            if isinstance(e, BaseExceptionGroup):
                for sub in e.exceptions:
                    yield from leaves(sub)
            else:
                yield e

        all_leaves = list(leaves(exc))
        for leaf in all_leaves:
            if isinstance(leaf, PipelineFailure):
                return leaf
        for leaf in all_leaves:
            if not isinstance(leaf, asyncio.CancelledError):
                return leaf
        return all_leaves[0] if all_leaves else exc

    def _reraise_root(self) -> None:
        assert self._root_task is not None
        if not self._root_task.done() or self._root_task.cancelled():
            return
        exc = self._root_task.exception()
        if exc is None:
            return
        raise self._unwrap(exc)

    def _ensure_consumable(self) -> None:
        """Start lazily, surface stop/setup errors, wait for the sink."""
        if not self._started:
            self.start()
        if self._stopped:
            raise PipelineStopped("pipeline stopped")
        assert self._loop is not None
        # The root task is created via run_coroutine_threadsafe; block until
        # it has installed the sink queue (no busy-polling: _root sets the
        # event, and the root future's done-callback sets it on early death).
        self._sink_ready.wait()
        if self._sink_q is None or self._root_task is None:
            assert self._root_fut is not None
            self._root_fut.result()  # surfaces setup errors
            raise PipelineStopped("pipeline root exited before sink install")

    def _refill_stash(self, n: int, timeout: float | None) -> None:
        """Drain the next chunk (≤ ``n`` items) from the sink into
        ``_stash``, resuming a pending getter left by a timed-out call.

        Both ``get_item`` and ``get_items`` funnel through here, so they
        SHARE the ``_pending_anext`` stash: a timeout-polling consumer can
        mix the two freely and never lose an item or the EOF.  A resumed
        getter may return more (or fewer) items than ``n`` — the excess
        waits in ``_stash`` for the next call.  Raises ``StopIteration``
        only with the stash empty and EOF drained.
        """
        if self._stash:
            return
        if self._sink_eof:
            raise StopIteration
        fut = self._pending_anext
        if fut is None:
            assert self._loop is not None
            fut = asyncio.run_coroutine_threadsafe(
                self._anext_many(n), self._loop
            )
        try:
            items = fut.result(timeout)
        except BaseException:
            # On a wait timeout the getter coroutine is still running and
            # WILL consume the next sink chunk — keep the future so the next
            # call collects that chunk instead of scheduling a second getter
            # (which would leak sink items per timed-out call).  A future
            # that is already done raised from inside the pipeline: drop it.
            self._pending_anext = fut if not fut.done() else None
            raise
        self._pending_anext = None
        if items and items[-1] is EOF:
            self._sink_eof = True
            items = items[:-1]
        self._stash.extend(items)
        if not self._stash:
            raise StopIteration  # EOF was the whole chunk

    def get_item(self, timeout: float | None = None) -> Any:
        """Fetch one item from the sink (blocking the consumer thread).

        Raises ``StopIteration`` on EOF, ``PipelineFailure`` on fail-fast
        errors, ``concurrent.futures.TimeoutError`` on timeout.  A timed-out
        call does NOT abandon its sink getter: the getter keeps running on
        the loop and the next ``get_item`` (or ``get_items``) resumes
        waiting on it, so polling with a timeout (e.g.
        ``HealthMonitor.guard``) never drops an item or the EOF.
        """
        self._ensure_consumable()
        self._refill_stash(1, timeout)
        return self._stash.popleft()

    def get_items(self, n: int, timeout: float | None = None) -> list[Any]:
        """Drain up to ``n`` sink items in ONE cross-thread round trip.

        The chunked consumer pull: blocks only until the FIRST item is
        available (latency over batching — a partial chunk is returned
        immediately, never awaited full), then sweeps whatever else the
        sink already buffered, up to ``n``.  Returns a non-empty list of
        1..n items; raises like ``get_item`` (``StopIteration`` once,
        after the final partial chunk, when the stream is exhausted).

        Shares the timeout-resume stash with ``get_item``: mixing the two
        under a polling consumer is lossless, and EOF is surfaced exactly
        once.  Items retain sink order across calls.
        """
        if n < 1:
            raise ValueError(f"get_items needs n >= 1, got {n}")
        self._ensure_consumable()
        self._refill_stash(n, timeout)
        take = min(n, len(self._stash))
        out = [self._stash.popleft() for _ in range(take)]
        self._sink_drained_chunks += 1
        return out

    def __iter__(self) -> Iterator[Any]:
        if not self._started:
            self.start()
        while True:
            try:
                yield self.get_item()
            except StopIteration:
                return

    # -- visibility ----------------------------------------------------------
    def stats(self) -> list[StageStatsSnapshot]:
        # one row per ORIGINAL stage: a fused runtime contributes a row per
        # phase (timings recorded inside the worker), so fusion is invisible
        # to dashboards except for the vanished queue waits
        snaps = [st.snapshot() for rt in self._runtimes for st in rt.phase_stats]
        if snaps and self._sink_drained_chunks:
            # the chunked sink drain has no stage of its own — its counter
            # rides the terminal stage's row (the one feeding the sink)
            snaps[-1] = dataclasses.replace(
                snaps[-1], sink_drained_chunks=self._sink_drained_chunks
            )
        return snaps

    def format_stats(self) -> str:
        return format_stats(self.stats())

    def queue_depths(self) -> dict[str, tuple[int, int]]:
        """{queue_name: (qsize, maxsize)} — instantaneous congestion map."""
        out: dict[str, tuple[int, int]] = {}
        for rt in self._runtimes:
            out[rt.out_q.name] = (rt.out_q.qsize(), rt.out_q.maxsize)
        return out

    @property
    def finished(self) -> bool:
        """True once the root task has completed — every stage emitted its
        EOF (or the pipeline failed).  The health monitor uses this to tell
        "quiescent because done" from "quiescent because stalled"."""
        return self._root_fut is not None and self._root_fut.done()

    @property
    def sink_occupancy(self) -> float:
        """Fraction of the sink buffer currently filled.

        ~1.0 means the loader is ahead of the consumer (healthy); ~0.0 under
        a consuming trainer means the trainer is data-starved.  The trainer's
        straggler monitor keys off this."""
        if self._sink_q is None or self._sink_q.maxsize == 0:
            return 0.0
        return self._sink_q.qsize() / self._sink_q.maxsize

"""Python 3.10 compatibility: ``asyncio.TaskGroup`` / ``ExceptionGroup``.

The engine is written against the 3.11 structured-concurrency API.  On
3.11+ these names are just aliases for the stdlib; on 3.10 we provide a
minimal backport with the subset of semantics the engine relies on:

- ``create_task`` schedules a child; the first child error aborts (cancels)
  every sibling;
- ``__aexit__`` always waits for all children, then raises one
  ``ExceptionGroup`` carrying the child errors (plus the body error, if
  any);
- cancellation of the enclosing task cancels the children and propagates as
  ``CancelledError`` once they have unwound.
"""

from __future__ import annotations

import asyncio
import builtins

if hasattr(builtins, "BaseExceptionGroup"):  # Python 3.11+
    BaseExceptionGroup = builtins.BaseExceptionGroup
    ExceptionGroup = builtins.ExceptionGroup
else:

    class BaseExceptionGroup(BaseException):  # type: ignore[no-redef]
        def __init__(self, message: str, exceptions):
            super().__init__(message)
            self.message = message
            self.exceptions = tuple(exceptions)

        def __str__(self) -> str:
            return f"{self.message} ({len(self.exceptions)} sub-exception(s))"

    class ExceptionGroup(BaseExceptionGroup, Exception):  # type: ignore[no-redef]
        pass


if hasattr(asyncio, "TaskGroup"):  # Python 3.11+
    TaskGroup = asyncio.TaskGroup
else:

    class TaskGroup:  # type: ignore[no-redef]
        def __init__(self) -> None:
            self._tasks: set[asyncio.Task] = set()
            self._errors: list[BaseException] = []
            self._aborted = False
            self._parent_task: asyncio.Task | None = None
            # we cancelled the parent ourselves (3.11 semantics: the first
            # child error interrupts a body that is still awaiting); that
            # self-inflicted CancelledError must be swallowed exactly once
            self._parent_cancelled_by_us = False
            self._self_cancel_consumed = False
            self._outer_cancelled = False

        async def __aenter__(self) -> "TaskGroup":
            self._parent_task = asyncio.current_task()
            return self

        def create_task(self, coro, *, name: str | None = None) -> asyncio.Task:
            task = asyncio.get_running_loop().create_task(coro, name=name)
            self._tasks.add(task)
            task.add_done_callback(self._on_done)
            return task

        def _abort(self) -> None:
            self._aborted = True
            for t in self._tasks:
                if not t.done():
                    t.cancel()

        def _on_done(self, task: asyncio.Task) -> None:
            self._tasks.discard(task)
            if task.cancelled():
                return
            exc = task.exception()
            if exc is not None:
                self._errors.append(exc)
                self._abort()
                parent = self._parent_task
                if (
                    parent is not None
                    and not parent.done()
                    and not self._parent_cancelled_by_us
                ):
                    self._parent_cancelled_by_us = True
                    parent.cancel()

        def _classify_cancel(self) -> None:
            """One CancelledError hitting the parent is ours if we asked for
            it; any other one is a genuine outer cancellation."""
            if self._parent_cancelled_by_us and not self._self_cancel_consumed:
                self._self_cancel_consumed = True
            else:
                self._outer_cancelled = True

        async def __aexit__(self, et, exc, tb) -> bool:
            if exc is not None:
                self._abort()
            if et is not None and issubclass(et, asyncio.CancelledError):
                self._classify_cancel()
            while self._tasks:
                try:
                    await asyncio.gather(
                        *list(self._tasks), return_exceptions=True
                    )
                except asyncio.CancelledError:
                    self._classify_cancel()
                    self._abort()
            body_error = exc is not None and not isinstance(
                exc, asyncio.CancelledError
            )
            if body_error:
                self._errors.insert(0, exc)
            if self._outer_cancelled:
                # teardown wins over fail-fast: the canceller is tearing the
                # pipeline down and expects CancelledError to propagate
                raise asyncio.CancelledError()
            if self._errors:
                raise ExceptionGroup(
                    "unhandled errors in a TaskGroup", self._errors
                ) from None
            return False  # no child errors: let any body exception propagate

"""Pipeline auto-tuning: turn the visibility stats into concurrency changes.

The paper's principles make the loop explicit: *Visibility* tells you which
stage is the bottleneck, *Tunability* lets you widen exactly that stage.
``suggest()`` reads a live pipeline's stats and returns a concrete new
stage-concurrency map; ``autotune()`` re-builds the pipeline via a factory
until the sink stays ahead of the consumer or improvements stall.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from .pipeline import Pipeline


@dataclasses.dataclass(frozen=True)
class Suggestion:
    stage: str | None  # None -> nothing to do
    concurrency: int
    reason: str


def suggest(pipeline: Pipeline, *, max_concurrency: int = 16) -> Suggestion:
    """Pick the stage to widen: the busiest pipe stage that is neither
    starved (upstream problem) nor backpressured (downstream problem)."""
    stats = [s for s in pipeline.stats() if s.name not in ("source",)]
    if not stats:
        return Suggestion(None, 0, "no stages")
    work = [s for s in stats if s.avg_task_time > 0]
    if not work:
        return Suggestion(None, 0, "no measurable work yet")
    bottleneck = max(work, key=lambda s: s.occupancy)
    if bottleneck.occupancy < 0.5:
        return Suggestion(
            None, bottleneck.concurrency,
            f"busiest stage {bottleneck.name!r} only {bottleneck.occupancy:.0%} occupied: "
            "pipeline is not the limiter",
        )
    if bottleneck.put_wait > bottleneck.get_wait * 2:
        return Suggestion(
            None, bottleneck.concurrency,
            f"{bottleneck.name!r} is backpressured (put_wait {bottleneck.put_wait:.2f}s): "
            "the consumer, not the pipeline, is the limiter",
        )
    new = min(max_concurrency, bottleneck.concurrency * 2)
    if new == bottleneck.concurrency:
        return Suggestion(None, new, f"{bottleneck.name!r} already at max_concurrency")
    return Suggestion(
        bottleneck.name, new,
        f"{bottleneck.name!r} occupied {bottleneck.occupancy:.0%} with low waits: widen "
        f"{bottleneck.concurrency} -> {new}",
    )


def autotune(
    factory: Callable[[dict[str, int]], Pipeline],
    probe: Callable[[Pipeline], float],
    *,
    initial: dict[str, int] | None = None,
    rounds: int = 3,
    min_gain: float = 0.05,
) -> tuple[dict[str, int], list[dict]]:
    """Iterate: build pipeline with the concurrency map → probe throughput →
    apply the suggestion; stop on < min_gain improvement or no suggestion.

    ``factory(conc_map)`` builds a fresh pipeline; ``probe`` consumes some
    of it and returns items/s.  Returns (best_map, log)."""
    conc = dict(initial or {})
    log: list[dict] = []
    best = -1.0
    for r in range(rounds):
        pipe = factory(conc)
        with pipe.auto_stop():
            rate = probe(pipe)
            s = suggest(pipe)
        log.append({"round": r, "conc": dict(conc), "rate": rate, "suggestion": s.reason})
        if rate < best * (1.0 + min_gain) and r > 0:
            break
        best = max(best, rate)
        if s.stage is None:
            break
        conc[s.stage] = s.concurrency
    return conc, log

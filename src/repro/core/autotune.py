"""Pipeline auto-tuning: turn the visibility stats into concurrency changes.

The paper's principles make the loop explicit: *Visibility* tells you which
stage is the bottleneck, *Tunability* lets you widen exactly that stage.
``suggest()`` reads a live pipeline's stats and returns a concrete new
stage-concurrency map; ``autotune()`` re-builds the pipeline via a factory
until the sink stays ahead of the consumer or improvements stall.

Two bottleneck shapes, two remedies:

* a stage whose tasks take real time (``avg_task_time`` high) is
  *work-bound* — widen its ``concurrency`` so more tasks overlap;
* a stage that is busy yet does almost no work per item (high occupancy,
  near-zero ``avg_task_time``) is *loop-overhead-bound* — its cost is the
  4-5 event-loop round trips per item, which widening cannot parallelize
  (they all run on the one scheduler thread).  The remedy is chunking
  (``pipe(..., chunk=N)``), which amortizes the round trips over N items;
  ``suggest()`` proposes a chunk size in that case.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from .pipeline import Pipeline

#: below this per-item task time a busy stage is loop-overhead-bound: the
#: executor round trip (~100us-1ms of loop bookkeeping, more on a loaded
#: box) rivals the work itself, so chunking, not widening, is the lever
LOOP_BOUND_TASK_S = 2e-3

#: chunk size proposed for loop-overhead-bound stages — large enough to
#: amortize the hop cost to noise, small enough not to distort latency or
#: the checkpoint skip bound
DEFAULT_CHUNK = 32


@dataclasses.dataclass(frozen=True)
class Suggestion:
    stage: str | None  # None -> nothing to do
    concurrency: int
    reason: str
    #: proposed ``chunk=`` for the stage (None = keep per-item execution);
    #: set instead of a concurrency bump when the stage is loop-bound
    chunk: int | None = None


def suggest(pipeline: Pipeline, *, max_concurrency: int = 16) -> Suggestion:
    """Pick the stage to widen: the busiest pipe stage that is neither
    starved (upstream problem) nor backpressured (downstream problem).
    A busy stage doing near-zero work per item gets a ``chunk`` proposal
    instead of a concurrency bump (see module docstring)."""
    stats = [s for s in pipeline.stats() if s.name not in ("source",)]
    if not stats:
        return Suggestion(None, 0, "no stages")
    work = [s for s in stats if s.avg_task_time > 0]
    if not work:
        return Suggestion(None, 0, "no measurable work yet")
    bottleneck = max(work, key=lambda s: s.occupancy)
    if bottleneck.occupancy < 0.5:
        return Suggestion(
            None, bottleneck.concurrency,
            f"busiest stage {bottleneck.name!r} only {bottleneck.occupancy:.0%} occupied: "
            "pipeline is not the limiter",
        )
    if bottleneck.put_wait > bottleneck.get_wait * 2:
        return Suggestion(
            None, bottleneck.concurrency,
            f"{bottleneck.name!r} is backpressured (put_wait {bottleneck.put_wait:.2f}s): "
            "the consumer, not the pipeline, is the limiter",
        )
    if (
        bottleneck.avg_task_time < LOOP_BOUND_TASK_S
        and bottleneck.chunk <= 1
        and bottleneck.chunkable  # async stages cannot take chunk=
    ):
        return Suggestion(
            bottleneck.name, bottleneck.concurrency,
            f"{bottleneck.name!r} is loop-overhead-bound (occupied "
            f"{bottleneck.occupancy:.0%} at {bottleneck.avg_task_time * 1e6:.0f}us/item): "
            f"chunk it (chunk={DEFAULT_CHUNK}) — widening cannot parallelize "
            "event-loop bookkeeping",
            chunk=DEFAULT_CHUNK,
        )
    new = min(max_concurrency, bottleneck.concurrency * 2)
    if new == bottleneck.concurrency:
        return Suggestion(None, new, f"{bottleneck.name!r} already at max_concurrency")
    return Suggestion(
        bottleneck.name, new,
        f"{bottleneck.name!r} occupied {bottleneck.occupancy:.0%} with low waits: widen "
        f"{bottleneck.concurrency} -> {new}",
    )


def autotune(
    factory: Callable[[dict[str, int]], Pipeline],
    probe: Callable[[Pipeline], float],
    *,
    initial: dict[str, int] | None = None,
    rounds: int = 3,
    min_gain: float = 0.05,
) -> tuple[dict[str, int], list[dict]]:
    """Iterate: build pipeline with the concurrency map → probe throughput →
    apply the suggestion; stop on < min_gain improvement or no suggestion.

    ``factory(conc_map)`` builds a fresh pipeline; ``probe`` consumes some
    of it and returns items/s.  Returns ``(best_map, log)`` where
    ``best_map`` is the concurrency map of the BEST-measured round — a
    final regressing round never wins just by being applied last.  A chunk
    suggestion ends the loop (the concurrency-map factory cannot apply it;
    it is recorded in the log for the caller).
    """
    conc = dict(initial or {})
    log: list[dict] = []
    best = -1.0
    best_map = dict(conc)
    for r in range(rounds):
        pipe = factory(conc)
        with pipe.auto_stop():
            rate = probe(pipe)
            s = suggest(pipe)
        log.append({"round": r, "conc": dict(conc), "rate": rate, "suggestion": s.reason})
        improved = rate >= best * (1.0 + min_gain)
        if rate > best:
            best = rate
            best_map = dict(conc)
        if r > 0 and not improved:
            break
        if s.stage is None or s.chunk is not None:
            break
        conc[s.stage] = s.concurrency
    return best_map, log

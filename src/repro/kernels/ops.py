"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

``use_pallas="auto"`` (default) selects the Pallas kernel on TPU and the
jnp reference path elsewhere (CPU dry-run / tests), so model code can call
these unconditionally.  ``use_pallas=True`` with ``interpret=True`` runs
the kernel body in Python on CPU — the validation mode used by the kernel
test sweeps.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .dequant_normalize import dequant_normalize as _dequant_pallas
from .dequant_normalize import (
    dequant_normalize_augment as _dequant_augment_pallas,
)
from .flash_attention import flash_attention as _flash_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(use_pallas: bool | str) -> tuple[bool, bool]:
    """→ (use_kernel, interpret)."""
    if use_pallas == "auto":
        return (_on_tpu(), False)
    if use_pallas == "interpret":
        return (True, True)
    return (bool(use_pallas), not _on_tpu())


@partial(jax.jit, static_argnames=("causal", "use_pallas", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, use_pallas="auto", block_q=128, block_k=128):
    use, interp = _resolve(use_pallas)
    if use:
        return _flash_pallas(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interp
        )
    return ref.flash_attention_ref(q, k, v, causal=causal)


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_scan(x, dt, a, b, c, *, chunk=128, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _ssd_pallas(x, dt, a, b, c, chunk=chunk, interpret=interp)
    from ..models.ssm import ssd_chunked

    return ssd_chunked(x, dt, a, b, c, chunk=chunk)


@partial(jax.jit, static_argnames=("use_pallas",))
def dequant_normalize(x, mean, std, *, use_pallas="auto"):
    use, interp = _resolve(use_pallas)
    if use:
        return _dequant_pallas(x, mean, std, interpret=interp)
    return ref.dequant_normalize_ref(x, mean, std)


@partial(jax.jit, static_argnames=("out_hw", "out_dtype", "use_pallas"))
def dequant_normalize_augment(
    x, mean, std, flip=None, crop=None, *,
    out_hw=None, out_dtype=jnp.bfloat16, use_pallas="auto",
):
    """Fused on-chip decode tail: crop → flip → dequant → normalize → NCHW.

    The device side of the ``uint8_wire`` contract (what
    ``DeviceTransfer(device_decode=...)`` dispatches): uint8 (or [0,1]
    float) NHWC in, normalized ``out_dtype`` NCHW out, one pass.
    """
    use, interp = _resolve(use_pallas)
    if use:
        return _dequant_augment_pallas(
            x, mean, std, flip=flip, crop=crop,
            out_hw=out_hw, out_dtype=out_dtype, interpret=interp,
        )
    return ref.dequant_normalize_augment_ref(
        x, mean, std, flip=flip, crop=crop,
        out_hw=out_hw, out_dtype=out_dtype,
    )

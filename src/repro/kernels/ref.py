"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """q (B,H,Sq,hd); k/v (B,Hkv,Skv,hd) — full-materialization attention."""
    bq, h, sq, hd = q.shape
    hkv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    k = jnp.repeat(k, h // hkv, axis=1)
    v = jnp.repeat(v, h // hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        skv = k.shape[2]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        s = jnp.where(mask, s, -2.0**30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v).astype(q.dtype)


def ssd_ref(x, dt, a, b, c, h0=None):
    """Stepwise SSD recurrence; see models/ssm.ssd_recurrent (re-exported
    here so kernel tests depend only on kernels/)."""
    from ..models.ssm import ssd_recurrent

    return ssd_recurrent(x, dt, a, b, c, h0)


def dequant_normalize_ref(x, mean, std, *, out_dtype=jnp.bfloat16):
    """x (N,H,W,C) uint8 → (N,C,H,W) normalized."""
    y = x.astype(jnp.float32) / 255.0
    y = (y - mean[None, None, None, :]) / std[None, None, None, :]
    return y.transpose(0, 3, 1, 2).astype(out_dtype)


def dequant_normalize_augment_ref(
    x, mean, std, *, flip=None, crop=None, out_hw=None, out_dtype=jnp.bfloat16
):
    """Oracle for the fused decode: per-sample crop → horizontal flip →
    dequant → per-channel normalize → NCHW, as separate jnp ops.

    ``x`` is (N,H,W,C) uint8 (dequantized by /255) or float already in
    [0,1] (dequant is then the identity).  ``flip`` (N,) nonzero = mirror
    the width axis; ``crop`` (N,2) = (top, left) offsets of an
    ``out_hw``-sized window, clamped in-bounds like ``lax.dynamic_slice``.
    """
    n, h, w, c = x.shape
    oh, ow = out_hw if out_hw is not None else (h, w)
    scale = (1.0 / 255.0) if jnp.issubdtype(x.dtype, jnp.integer) else 1.0
    if flip is None:
        flip = jnp.zeros((n,), jnp.int32)
    if crop is None:
        crop = jnp.zeros((n, 2), jnp.int32)
    crop = jnp.clip(
        crop.astype(jnp.int32), 0, jnp.array([h - oh, w - ow], jnp.int32)
    )

    def one(img, f, off):
        y = jax.lax.dynamic_slice(img, (off[0], off[1], 0), (oh, ow, c))
        y = y.astype(jnp.float32) * scale
        y = jnp.where(f != 0, y[:, ::-1, :], y)
        return (y - mean[None, None, :]) / std[None, None, :]

    y = jax.vmap(one)(x, flip.astype(jnp.int32), crop)
    return y.transpose(0, 3, 1, 2).astype(out_dtype)

"""Pallas TPU kernel: uint8 → bf16 dequantize + per-channel normalize.

The device-side "last mile" of the data pipeline (DESIGN §6): the loader
transfers image batches as **uint8** (4× fewer PCIe/ICI bytes than f32,
2× fewer than bf16 — the paper's "avoid unnecessary memory copies"
principle extended to the wire), and this kernel expands to bf16 and
applies (x/255 − mean)/std on-chip, fused in one VMEM pass, emitting NCHW.

Grid: (batch, channels); each step moves one (H, W) plane HBM→VMEM,
applies the affine transform on the VPU, and writes the transposed layout.

TARGET: TPU; validated with ``interpret=True`` against
``ref.dequant_normalize_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_kernel(x_ref, mean_ref, std_ref, o_ref):
    # x_ref: (1, H, W, 1) uint8 ; mean/std: (1,) f32 ; o_ref: (1, 1, H, W)
    x = x_ref[0, :, :, 0].astype(jnp.float32) * (1.0 / 255.0)
    y = (x - mean_ref[0]) * (1.0 / std_ref[0])
    o_ref[0, 0] = y.astype(o_ref.dtype)


def dequant_normalize(
    x: jax.Array,  # (N, H, W, C) uint8
    mean: jax.Array,  # (C,) f32
    std: jax.Array,  # (C,) f32
    *,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N, C, H, W) ``out_dtype`` normalized images."""
    n, h, w, c = x.shape
    kernel = functools.partial(_dequant_kernel)
    return pl.pallas_call(
        kernel,
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, h, w, 1), lambda ni, ci: (ni, 0, 0, ci)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, w), lambda ni, ci: (ni, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), out_dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, mean, std)

"""Pallas TPU kernels: uint8 → bf16 dequantize + normalize (+ augment).

The device-side "last mile" of the data pipeline (DESIGN §6): the loader
transfers image batches as **uint8** (4× fewer PCIe/ICI bytes than f32,
2× fewer than bf16 — the paper's "avoid unnecessary memory copies"
principle extended to the wire), and these kernels expand to bf16 and
apply (x/255 − mean)/std on-chip, fused in one VMEM pass, emitting NCHW.

``dequant_normalize``          — dequant + per-channel normalize.
``dequant_normalize_augment``  — the full decode tail in ONE pass:
dynamic (top, left) crop to a static output window, per-sample horizontal
flip, dequant, per-channel normalize.  This is what ``DeviceTransfer``'s
``device_decode`` dispatches, so the host never touches a pixel float.

Grid: (batch, channels); each step moves one (H, W) plane HBM→VMEM,
crops via ``pl.ds`` dynamic slicing, applies flip + the affine transform
on the VPU, and writes the transposed layout.

TARGET: TPU; validated with ``interpret=True`` against the ``ref.py``
composition (``dequant_normalize_ref`` / ``dequant_normalize_augment_ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the params class as TPUCompilerParams; newer as
# CompilerParams — alias so interpret-mode validation runs on either
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _dequant_kernel(x_ref, mean_ref, std_ref, o_ref):
    # x_ref: (1, H, W, 1) uint8 ; mean/std: (1,) f32 ; o_ref: (1, 1, H, W)
    x = x_ref[0, :, :, 0].astype(jnp.float32) * (1.0 / 255.0)
    y = (x - mean_ref[0]) * (1.0 / std_ref[0])
    o_ref[0, 0] = y.astype(o_ref.dtype)


def dequant_normalize(
    x: jax.Array,  # (N, H, W, C) uint8
    mean: jax.Array,  # (C,) f32
    std: jax.Array,  # (C,) f32
    *,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N, C, H, W) ``out_dtype`` normalized images."""
    n, h, w, c = x.shape
    kernel = functools.partial(_dequant_kernel)
    return pl.pallas_call(
        kernel,
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, h, w, 1), lambda ni, ci: (ni, 0, 0, ci)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, w), lambda ni, ci: (ni, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, mean, std)


def _dequant_augment_kernel(
    x_ref, mean_ref, std_ref, flip_ref, crop_ref, o_ref, *, scale, out_h, out_w
):
    # x_ref: (1, H, W, 1) uint8/float ; mean/std: (1,) f32 ;
    # flip: (1,) i32 ; crop: (1, 2) i32 ; o_ref: (1, 1, out_h, out_w)
    oy = crop_ref[0, 0]
    ox = crop_ref[0, 1]
    # dynamic (top, left) crop straight out of the resident plane: one
    # VMEM slice, no gather
    y = x_ref[0, pl.ds(oy, out_h), pl.ds(ox, out_w), 0]
    y = y.astype(jnp.float32) * scale
    # both branches are computed on the VPU; select is elementwise
    y = jnp.where(flip_ref[0] != 0, y[:, ::-1], y)
    y = (y - mean_ref[0]) * (1.0 / std_ref[0])
    o_ref[0, 0] = y.astype(o_ref.dtype)


def dequant_normalize_augment(
    x: jax.Array,  # (N, H, W, C) uint8, or float already in [0, 1]
    mean: jax.Array,  # (C,) f32
    std: jax.Array,  # (C,) f32
    *,
    flip: jax.Array | None = None,  # (N,) nonzero = horizontal flip
    crop: jax.Array | None = None,  # (N, 2) (top, left) window offsets
    out_hw: tuple[int, int] | None = None,  # static window; None = full frame
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    """Fused decode tail: crop → flip → dequant → normalize → NCHW.

    Returns (N, C, out_h, out_w) ``out_dtype``.  Crop offsets are clamped
    in-bounds (``lax.dynamic_slice`` semantics, matching the ref).  Integer
    input is dequantized by 1/255; float input is assumed [0, 1] already.
    """
    n, h, w, c = x.shape
    oh, ow = out_hw if out_hw is not None else (h, w)
    if oh > h or ow > w:
        raise ValueError(f"out_hw={out_hw} exceeds input frame {(h, w)}")
    scale = (1.0 / 255.0) if jnp.issubdtype(x.dtype, jnp.integer) else 1.0
    if flip is None:
        flip = jnp.zeros((n,), jnp.int32)
    if crop is None:
        crop = jnp.zeros((n, 2), jnp.int32)
    crop = jnp.clip(
        crop.astype(jnp.int32), 0, jnp.array([h - oh, w - ow], jnp.int32)
    )
    kernel = functools.partial(
        _dequant_augment_kernel, scale=scale, out_h=oh, out_w=ow
    )
    return pl.pallas_call(
        kernel,
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, h, w, 1), lambda ni, ci: (ni, 0, 0, ci)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
            pl.BlockSpec((1,), lambda ni, ci: (ci,)),
            pl.BlockSpec((1,), lambda ni, ci: (ni,)),
            pl.BlockSpec((1, 2), lambda ni, ci: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda ni, ci: (ni, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, oh, ow), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, mean, std, flip.astype(jnp.int32), crop)

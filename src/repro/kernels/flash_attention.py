"""Pallas TPU flash attention (causal, GQA) — forward kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the kv axis
sequential ("arbitrary"); online-softmax state (m, l, acc) lives in VMEM
scratch and persists across the kv grid steps.  GQA is handled with a
BlockSpec index_map (kv head = q head // group) so K/V are never repeated
in HBM.  Fully-masked causal blocks are skipped with ``pl.when`` — the
2× causal win the jnp fallback cannot express.

Block sizes default to 128×128 (MXU-aligned); VMEM per step ≈
q(128·hd) + k/v(128·hd) + scores(128·128·4B) ≈ well under 1 MiB.

TARGET: TPU.  In this container it is validated with ``interpret=True``
against ``ref.flash_attention_ref`` (see tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, 1, bq, hd), (1, 1, bk, hd), (1, 1, bk, hd)
    o_ref,  # (1, 1, bq, hd)
    m_ref, l_ref, acc_ref,  # VMEM scratch: (bq,), (bq,), (bq, hd)
    *,
    block_q: int,
    block_k: int,
    sm_scale: float,
    causal: bool,
    q_offset: int,  # skv - sq: decode-style windows right-align q to kv end
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks strictly above the (offset) diagonal
    q_start = qi * block_q + q_offset
    k_start = kj * block_k
    should_run = (not causal) or (q_start + block_q - 1 >= k_start)

    @pl.when(should_run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0]  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        s = s * sm_scale
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, Hkv, Skv, hd)
    v: jax.Array,  # (B, Hkv, Skv, hd)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    assert not causal or sq <= skv, "causal requires sq <= skv (right-aligned)" 
    group = h // hkv
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / (hd**0.5)
    nq, nk = sq // block_q, skv // block_k

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        sm_scale=sm_scale,
        causal=causal,
        q_offset=skv - sq,
    )
    grid = (b, h, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, kj: (b_, h_ // group, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, qi, kj: (b_, h_ // group, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)

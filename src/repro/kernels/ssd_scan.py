"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, num_chunks) with the chunk axis sequential; the SSM
state (head_dim × d_state, fp32) lives in VMEM scratch and is carried
across chunk steps — the inter-chunk recurrence never round-trips HBM,
which is the TPU-native version of the paper's "keep the recurrent state
on-chip" trick.  Per chunk the dual (attention-like) form runs three
MXU matmuls: C·Bᵀ (Q×Q), scores·X (Q×P), and the state outer-product
update (rank-Q).  VMEM per step ≈ Q·(2N+P)·4B + Q²·4B ≈ 0.4 MiB for
Q=128, N=128, P=64.

GQA-style B/C groups are mapped with a BlockSpec index_map
(group = head // heads_per_group) so grouped tensors are not repeated.

TARGET: TPU; validated with ``interpret=True`` against ``ref.ssd_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, Q, 1, P)
    dt_ref,  # (1, Q, 1)
    a_ref,  # (1,)  per-head A (negative)
    b_ref,  # (1, Q, 1, N)
    c_ref,  # (1, Q, 1, N)
    y_ref,  # (1, Q, 1, P)
    hfin_ref,  # (1, 1, P, N)
    h_ref,  # VMEM scratch (P, N) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0].astype(jnp.float32)  # scalar
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)

    dA = dt * a  # (Q,)
    dA_cs = jnp.cumsum(dA)  # (Q,)

    # intra-chunk dual form: L[i,j] = exp(cs_i - cs_j) for i >= j
    seg = dA_cs[:, None] - dA_cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(li >= lj, jnp.exp(seg), 0.0)
    scores = (
        jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        * L
        * dt[None, :]
    )  # (Q, Q) — column j scaled by dt_j
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # carried prefix state contribution: y += exp(cs_i) * C_i · h
    h = h_ref[...]  # (P, N)
    y += jnp.exp(dA_cs)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: h = exp(cs_last)·h + Σ_q exp(cs_last - cs_q)·dt_q·x_qᵀB_q
    decay_to_end = jnp.exp(dA_cs[-1] - dA_cs) * dt  # (Q,)
    xw = x * decay_to_end[:, None]  # (Q, P)
    upd = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    h_ref[...] = h * jnp.exp(dA_cs[-1]) + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hfin_ref[0, 0] = h_ref[...]


def ssd_scan(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) fp32
    a: jax.Array,  # (H,) fp32, negative
    b: jax.Array,  # (B, L, G, N)
    c: jax.Array,  # (B, L, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), h_final (B,H,P,N) fp32)."""
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert h % g == 0 and l % chunk == 0, (h, g, l, chunk)
    hg = h // g
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid = (bsz, h, nc)
    y, hfin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, hfin

"""Device transfer stage (paper §5.7.2, adapted to JAX/TPU — DESIGN §2).

``DeviceTransfer`` is the terminal pipe stage: it places a host batch onto
devices with the training step's input sharding via ``jax.device_put`` —
JAX dispatches asynchronously, so with the pipeline keeping ≥1 batch in the
sink the H2D copy overlaps the running step (the CUDA-side "separate
stream" of the paper).  Per §2.1 there must be at most ONE transfer task:
build the stage with ``concurrency=1`` (the loader does).

``uint8_wire=True`` makes uint8 the end-to-end wire contract: loaders ship
uint8 payloads (slab rows arrive uint8 already and pass through untouched,
zero copies), float image payloads that slipped into the batch are
downcast from [0, 1] — out-of-range floats raise instead of silently
clipping — and the device side expands to bf16 on-chip.  4× fewer
host→device bytes than f32 (beyond-paper optimization,
kernels/dequant_normalize.py).  Integer payloads pass through untouched.

``device_decode=DeviceDecode(mean, std, ...)`` finishes the decode ON the
accelerator: right after ``device_put`` the transfer dispatches the fused
``dequant_normalize_augment`` kernel (uint8→bf16 dequant, per-channel
normalize, per-sample flip/crop augment, one VMEM pass, NCHW out), so the
host-side path never touches a pixel float — augment draws are tiny int
arrays from a seeded numpy generator.  Dispatch cost is counted in
``device_decode_ms`` (the kernel itself runs async on the device) and
surfaces on the transfer stage's stats row via the ``stats()`` probe.

Chunked dispatch: ``transfer_many`` is the vectorized-chunk twin of
``__call__`` — the engine hands it the batches a sink-side ``get_many``
drained and it issues their transfers back-to-back in arrival order.
Double buffering is shared with the per-batch path: each dispatched slab
enters the same hold ring, so slab *k* is recycled only after the whole
consumer window has moved past it, chunked or not.

Double buffering (zero-copy arena path): a batch arriving from an
``aggregate_into`` stage carries its owning slab under ``SLAB_KEY``.  The
slab's host memory must stay intact until nothing reads it anymore, so the
transfer keeps a ring of "staging" slabs — the last ``hold_slabs`` batches
— and releases the oldest back to the arena only as new transfers are
issued.

``hold_slabs`` defaults to ``consumer_window + 1 + dispatch_chunk``:
enough to cover every batch that can be live at once (the sink buffer +
the batch the consumer holds + one mid-handoff + the rest of a chunked
dispatch still un-put in the worker; ``dispatch_chunk=1`` recovers the
classic ``consumer_window + 2``).  That window matters because ``jax.device_put``
may *alias* host numpy memory instead of snapshotting it — and whether it
does is a per-buffer size/alignment decision inside XLA (small arrays get
copied, slab-sized ones get aliased on CPU), so it cannot be probed
reliably once up front.  Holding the full window is a few batch-buffers of
host memory; releasing early is silent data corruption.  Consumers that
retain batches beyond the current iteration must copy them.  No
``block_until_ready()`` ever enters the hot path.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import trace as _trace
from .arena import SLAB_KEY

#: absolute slack allowed past [0, 1] before a float wire payload is
#: rejected — covers resize/antialias ringing, not wrong normalization
_WIRE_EPS = 1e-3


def to_uint8_wire(v: Any) -> Any:
    """Downcast a [0,1]-normalized float image payload to the uint8 wire
    format (inverse of the on-chip ``x/255`` dequant).

    Already-uint8 arrays pass through unchanged — the zero-copy slab path
    ships uint8 natively and must not pay a copy here.  Float image
    payloads outside [0, 1] (beyond a tiny epsilon) raise ``ValueError``:
    silently clipping them would corrupt every pixel the consumer trains
    on, loudly is the only acceptable failure mode.  Anything that is not
    a floating-point image-shaped array passes through unchanged.
    """
    if (
        isinstance(v, np.ndarray)
        and v.dtype in (np.float32, np.float64)
        and v.ndim >= 3  # (H, W, C) or (N, H, W, C): image-like payloads only
    ):
        if v.size:
            lo, hi = float(v.min()), float(v.max())
            if lo < -_WIRE_EPS or hi > 1.0 + _WIRE_EPS:
                raise ValueError(
                    f"uint8_wire expects [0,1]-normalized floats "
                    f"(normalize_to_float convention); got range [{lo:.4g}, "
                    f"{hi:.4g}] — normalize on-chip via device_decode "
                    "instead of pre-scaling on the host"
                )
        return np.clip(np.rint(v * 255.0), 0.0, 255.0).astype(np.uint8)
    return v


@dataclasses.dataclass(frozen=True)
class DeviceDecode:
    """Config for the on-chip fused decode tail behind ``DeviceTransfer``.

    ``mean``/``std`` are per-channel (C,) stats in [0,1] units (the
    ImageNet convention).  ``out_hw`` crops every sample to a static
    window (random per-sample offsets when ``crop=True``, centered
    otherwise); ``flip=True`` mirrors each sample with p=0.5.  Augment
    randomness comes from a seeded numpy generator on the host — integer
    draws only, the pixels themselves are never touched host-side.
    """

    mean: tuple[float, ...]
    std: tuple[float, ...]
    field: str = "images"  # batch key holding (N, H, W, C) wire payloads
    out_hw: tuple[int, int] | None = None  # None = full frame
    flip: bool = False  # random horizontal flip (p=0.5)
    crop: bool = False  # random (vs centered) out_hw window placement
    out_dtype: Any = jnp.bfloat16
    seed: int = 0
    use_pallas: Any = "auto"  # "auto" | True | "interpret" | False


class DeviceTransfer:
    def __init__(
        self,
        shardings: Any | None = None,
        *,
        uint8_wire: bool = False,
        hold_slabs: int | None = None,
        consumer_window: int = 3,
        dispatch_chunk: int = 1,
        device_decode: DeviceDecode | None = None,
        tracer=None,
    ):
        if hold_slabs is None:
            # consumer window + the batch mid-handoff + every batch of the
            # current dispatch chunk still un-put in the worker (chunked
            # transfer_many issues the whole chunk before put_many runs)
            hold_slabs = consumer_window + 1 + max(1, dispatch_chunk)
        self.shardings = shardings
        self.uint8_wire = uint8_wire
        self.hold_slabs = hold_slabs  # slabs kept alive behind the current one
        self.device_decode = device_decode
        self.bytes_moved = 0
        self.num_batches = 0
        # fused on-chip decode accounting (host-side dispatch cost only —
        # the kernel runs async); surfaced via stats() → the stage probe
        self.device_decode_ms = 0.0
        self.device_decode_batches = 0
        # explicit tracer, else whatever is installed process-wide at call
        # time (host→device spans land on the worker thread's track)
        self._tracer = tracer
        self._held: deque[Any] = deque()
        if device_decode is not None:
            self._decode_mean = jnp.asarray(device_decode.mean, jnp.float32)
            self._decode_std = jnp.asarray(device_decode.std, jnp.float32)
            self._decode_rng = np.random.default_rng(device_decode.seed)

    def __call__(self, batch: Any) -> Any:
        slab = None
        if isinstance(batch, dict):
            slab = batch.pop(SLAB_KEY, None)
            if self.uint8_wire:
                batch = {k: to_uint8_wire(v) for k, v in batch.items()}
        nbytes = (
            sum(v.nbytes for v in batch.values() if hasattr(v, "nbytes"))
            if isinstance(batch, dict)
            else getattr(batch, "nbytes", 0)
        )
        self.bytes_moved += nbytes
        self.num_batches += 1
        tracer = self._tracer if self._tracer is not None else _trace.get_tracer()
        t0 = time.monotonic() if tracer.enabled else 0.0
        if self.shardings is None:
            out = jax.device_put(batch)
        else:
            out = jax.device_put(batch, self.shardings)
        if tracer.enabled:
            # dispatch time only: device_put is async, so this span is the
            # host-side cost; the wire time overlaps the consumer's step
            tracer.complete(
                "device_put", "transfer", t0, time.monotonic() - t0,
                {"bytes": nbytes, "batch": self.num_batches},
            )
        out = self._maybe_decode(out, tracer)
        if slab is not None:
            # The copy for `slab` is now in flight; recycle the one from
            # hold_slabs batches ago, whose copy is certainly consumed.
            self._held.append(slab)
            while len(self._held) > self.hold_slabs:
                self._held.popleft().release()
        return out

    def transfer_many(self, batches: list) -> list:
        """Vectorized-chunk entry point: dispatch a drained chunk of batches
        back-to-back, in order (wire as ``pipe(transfer.transfer_many,
        chunk=N, vectorized=True)``).  One executor call issues the whole
        chunk's ``device_put`` (+ fused decode) calls; the slab hold ring
        advances per batch exactly as on the per-item path.  The hold
        window must cover the chunk: up to ``len(batches) - 1`` results sit
        un-put in the worker while the chunk's tail is dispatched, so
        construct the transfer with ``dispatch_chunk=`` matching the
        stage's chunk (the loaders do) — an undersized window releases
        slabs the sink still aliases.
        """
        return [self(b) for b in batches]

    def _maybe_decode(self, out: Any, tracer) -> Any:
        """Dispatch the fused on-chip decode for the configured field."""
        dd = self.device_decode
        if dd is None or not isinstance(out, dict) or dd.field not in out:
            return out
        from ..kernels.ops import dequant_normalize_augment

        x = out[dd.field]
        n, h, w, _c = x.shape
        oh, ow = dd.out_hw if dd.out_hw is not None else (h, w)
        flip = crop = None
        if dd.flip:
            flip = self._decode_rng.integers(0, 2, n, dtype=np.int32)
        if oh != h or ow != w:
            if dd.crop:
                crop = np.stack(
                    [
                        self._decode_rng.integers(0, h - oh + 1, n, dtype=np.int32),
                        self._decode_rng.integers(0, w - ow + 1, n, dtype=np.int32),
                    ],
                    axis=1,
                )
            else:
                crop = np.tile(
                    np.array([[(h - oh) // 2, (w - ow) // 2]], np.int32), (n, 1)
                )
        t0 = time.monotonic()
        decoded = dequant_normalize_augment(
            x, self._decode_mean, self._decode_std, flip, crop,
            out_hw=dd.out_hw, out_dtype=dd.out_dtype,
            use_pallas=dd.use_pallas,
        )
        dt = time.monotonic() - t0
        self.device_decode_ms += dt * 1e3
        self.device_decode_batches += 1
        if tracer.enabled:
            tracer.complete(
                "device_decode", "transfer", t0, dt,
                {"batch": self.num_batches, "out_hw": [oh, ow]},
            )
        out = dict(out)
        out[dd.field] = decoded
        return out

    def stats(self) -> dict[str, float]:
        """Probe dict for the transfer stage's stats row (wire with
        ``pipe(..., cache=transfer)`` — the snapshot pulls these keys)."""
        return {
            "device_decode_ms": self.device_decode_ms,
            "device_decode_batches": self.device_decode_batches,
        }

    def flush(self) -> None:
        """Release every held slab (end of stream / teardown).  Callers must
        ensure pending transfers are consumed (e.g. the pipeline drained)."""
        while self._held:
            self._held.popleft().release()

"""Device transfer stage (paper §5.7.2, adapted to JAX/TPU — DESIGN §2).

``DeviceTransfer`` is the terminal pipe stage: it places a host batch onto
devices with the training step's input sharding via ``jax.device_put`` —
JAX dispatches asynchronously, so with the pipeline keeping ≥1 batch in the
sink the H2D copy overlaps the running step (the CUDA-side "separate
stream" of the paper).  Per §2.1 there must be at most ONE transfer task:
build the stage with ``concurrency=1`` (the loader does).

``uint8_wire=True`` sends image payloads as uint8 and lets the device-side
``dequant_normalize`` kernel expand to bf16 on-chip — 4× fewer host→device
bytes than f32 (beyond-paper optimization, kernels/dequant_normalize.py).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np


class DeviceTransfer:
    def __init__(self, shardings: Any | None = None, *, uint8_wire: bool = False):
        self.shardings = shardings
        self.uint8_wire = uint8_wire
        self.bytes_moved = 0

    def __call__(self, batch: dict) -> dict:
        if self.uint8_wire:
            batch = {
                k: (v if (isinstance(v, np.ndarray) and v.dtype == np.uint8) else v)
                for k, v in batch.items()
            }
        self.bytes_moved += sum(
            v.nbytes for v in batch.values() if hasattr(v, "nbytes")
        )
        if self.shardings is None:
            return jax.device_put(batch)
        return jax.device_put(batch, self.shardings)

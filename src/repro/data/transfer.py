"""Device transfer stage (paper §5.7.2, adapted to JAX/TPU — DESIGN §2).

``DeviceTransfer`` is the terminal pipe stage: it places a host batch onto
devices with the training step's input sharding via ``jax.device_put`` —
JAX dispatches asynchronously, so with the pipeline keeping ≥1 batch in the
sink the H2D copy overlaps the running step (the CUDA-side "separate
stream" of the paper).  Per §2.1 there must be at most ONE transfer task:
build the stage with ``concurrency=1`` (the loader does).

``uint8_wire=True`` downcasts float image payloads ([0, 1]-normalized, the
``normalize_to_float`` convention) to uint8 on the wire and lets the
device-side ``dequant_normalize`` kernel expand to bf16 on-chip — 4× fewer
host→device bytes than f32 (beyond-paper optimization,
kernels/dequant_normalize.py).  Integer payloads pass through untouched.

Double buffering (zero-copy arena path): a batch arriving from an
``aggregate_into`` stage carries its owning slab under ``SLAB_KEY``.  The
slab's host memory must stay intact until nothing reads it anymore, so the
transfer keeps a ring of "staging" slabs — the last ``hold_slabs`` batches
— and releases the oldest back to the arena only as new transfers are
issued.

``hold_slabs`` defaults to ``consumer_window + 2``: enough to cover every
batch that can be live at once (the sink buffer + the batch the consumer
holds + one mid-handoff).  That window matters because ``jax.device_put``
may *alias* host numpy memory instead of snapshotting it — and whether it
does is a per-buffer size/alignment decision inside XLA (small arrays get
copied, slab-sized ones get aliased on CPU), so it cannot be probed
reliably once up front.  Holding the full window is a few batch-buffers of
host memory; releasing early is silent data corruption.  Consumers that
retain batches beyond the current iteration must copy them.  No
``block_until_ready()`` ever enters the hot path.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any

import jax
import numpy as np

from ..core import trace as _trace
from .arena import SLAB_KEY


def to_uint8_wire(v: Any) -> Any:
    """Downcast a [0,1]-normalized float image payload to the uint8 wire
    format (inverse of the on-chip ``x/255`` dequant).  Anything that is not
    a floating-point image-shaped array passes through unchanged."""
    if (
        isinstance(v, np.ndarray)
        and v.dtype in (np.float32, np.float64)
        and v.ndim >= 3  # (H, W, C) or (N, H, W, C): image-like payloads only
    ):
        return np.clip(np.rint(v * 255.0), 0.0, 255.0).astype(np.uint8)
    return v


class DeviceTransfer:
    def __init__(
        self,
        shardings: Any | None = None,
        *,
        uint8_wire: bool = False,
        hold_slabs: int | None = None,
        consumer_window: int = 3,
        tracer=None,
    ):
        if hold_slabs is None:
            hold_slabs = consumer_window + 2
        self.shardings = shardings
        self.uint8_wire = uint8_wire
        self.hold_slabs = hold_slabs  # slabs kept alive behind the current one
        self.bytes_moved = 0
        self.num_batches = 0
        # explicit tracer, else whatever is installed process-wide at call
        # time (host→device spans land on the worker thread's track)
        self._tracer = tracer
        self._held: deque[Any] = deque()

    def __call__(self, batch: Any) -> Any:
        slab = None
        if isinstance(batch, dict):
            slab = batch.pop(SLAB_KEY, None)
            if self.uint8_wire:
                batch = {k: to_uint8_wire(v) for k, v in batch.items()}
        nbytes = (
            sum(v.nbytes for v in batch.values() if hasattr(v, "nbytes"))
            if isinstance(batch, dict)
            else getattr(batch, "nbytes", 0)
        )
        self.bytes_moved += nbytes
        self.num_batches += 1
        tracer = self._tracer if self._tracer is not None else _trace.get_tracer()
        t0 = time.monotonic() if tracer.enabled else 0.0
        if self.shardings is None:
            out = jax.device_put(batch)
        else:
            out = jax.device_put(batch, self.shardings)
        if tracer.enabled:
            # dispatch time only: device_put is async, so this span is the
            # host-side cost; the wire time overlaps the consumer's step
            tracer.complete(
                "device_put", "transfer", t0, time.monotonic() - t0,
                {"bytes": nbytes, "batch": self.num_batches},
            )
        if slab is not None:
            # The copy for `slab` is now in flight; recycle the one from
            # hold_slabs batches ago, whose copy is certainly consumed.
            self._held.append(slab)
            while len(self._held) > self.hold_slabs:
                self._held.popleft().release()
        return out

    def flush(self) -> None:
        """Release every held slab (end of stream / teardown).  Callers must
        ensure pending transfers are consumed (e.g. the pipeline drained)."""
        while self._held:
            self._held.popleft().release()

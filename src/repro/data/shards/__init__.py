"""Sharded record store: packed shards, mmap zero-copy reads, prefetch.

The storage-side counterpart of the SPDL compute pipeline: instead of one
file per sample (one ``open()+read()`` syscall pair each, hostile to both
local filesystems and object stores), samples are packed into a few large
**shard** files read through ``mmap`` with zero payload copies, fronted by
an async prefetcher with a byte-budgeted local cache for remote sources.

On-disk layout
--------------
A sharded dataset is a directory of shard files plus a JSON manifest::

    dataset/
      manifest.json            {"version": 1, "total": N,
                                "shards": [{"name", "n", "bytes"}, ...]}
      shard-00000.rpshard
      shard-00001.rpshard

Each ``.rpshard`` file is ``[header | payload | index]`` (little-endian):

* **header** (32 B): magic ``b"RPRSHRD1"``, ``version:u32``,
  ``n_samples:u32``, ``index_offset:u64``, ``payload_offset:u64``;
* **payload**: the encoded samples (codec.py ``RPR1`` blobs, but the format
  is payload-agnostic) packed back to back;
* **index** (16 B/sample, written after the payload so the writer streams):
  ``offset:u64``, ``length:u32``, ``crc32:u32``.

**Format v2 (columnar)** keeps the same header (``version=2``) but lays
the payload out as one contiguous *column region per named field*, with a
per-column index carrying per-(field, sample) offsets/lengths/crc32s.
Readers that only need some fields fetch only those columns — *projection
pushdown* — and the saving propagates through every layer: sparse
prefetch coalesces ranges per projected column, ranged sources download
only those spans, and peers serve column ranges from their warm caches.
See ``format.py`` for the byte-level spec.

Versioning: the magic pins the major layout, ``version`` the minor
revision; readers reject unknown magics and newer-than-self versions and
keep reading every older version ever shipped.  ``open_shard_reader``
peeks the header and returns the right reader class for either version.

CRC policy: crcs are computed over the encoded sample bytes at pack time
and verified on every read by default; a mismatch raises
``ShardCorruption`` for that sample only, so one flipped bit becomes a
per-sample hole under the pipeline's ``OnError.SKIP`` instead of a dead
shard or a silently wrong batch.

Remote source protocol
----------------------
The prefetcher talks to storage through a duck type:

``fetch(name) -> bytes``
    Download one whole object (shard or manifest).  Required.

``fetch_range(name, start, length) -> bytes``
    Download exactly ``length`` bytes at offset ``start``.  **Optional**;
    providing it unlocks *index-first fetch*: the prefetcher pulls the
    32-byte header + 16 B/sample index region first (``ShardIndex``),
    decides — from the sampler window's hints and the byte budget —
    whether the payload is worth committing to, and can serve reads from a
    sparse, partially-fetched shard (``SparseShardReader``), demand-
    fetching individual sample ranges as needed.  Sources advertise range
    support simply by having the method (wrappers like ``RetryingSource``
    forward it iff their inner source has it).

Error contract: ``FileNotFoundError`` = object does not exist (permanent);
``sources.SourceUnavailable`` (an ``OSError``) = transient, retryable.

Backends: ``LocalShardSource`` (directory), ``SimulatedLatencySource``
(deterministic object-storage stand-in), ``HttpShardSource`` (real HTTP(S)
with ``Range`` reads + connection reuse), ``RetryingSource`` (capped
exponential backoff + jitter around any of the above), and the peer
exchange tier (``peer.py``): ``PeerShardServer`` serves a rank's warm
cache out, ``PeerShardSource``/``TieredSource`` consult peers' warm caches
before the origin — the composed stack is origin → retry → peers →
prefetcher (``ShardDataset(url, peers=[...])`` builds it).  S3/GCS-native
sources are the next target behind the same duck type.

Public surface
--------------
``ShardWriter`` / ``ShardReader``  one-file pack/read (``format.py``;
                                   ``ShardIndex`` for index-only parses);
``ShardWriterV2`` / ``ShardReaderV2``  columnar (format v2) pack/read with
                                   field projection (``ShardIndexV2`` for
                                   index-only parses;
                                   ``open_shard_reader`` dispatches on the
                                   header version byte);
``ShardDataset`` / ``pack``        multi-shard dataset + migration tool
                                   (``dataset.py``; an ``http(s)://`` root
                                   builds the remote stack automatically);
``ShardPrefetcher`` + sources      async fetch, LRU-by-bytes local cache,
                                   index-first sparse fetch, sparse→full
                                   promotion
                                   (``prefetch.py``, ``sources.py``);
``PeerShardServer`` + tiers        peer-to-peer shard exchange between
                                   data ranks (``peer.py``);
``testing.serve_shards``           stdlib HTTP *origin* fixture with Range
                                   support for tests/benchmarks.

``python -m repro.data.shards SRC DST`` packs an ``ArrayDataset``
directory from the command line.
"""

from .dataset import (
    MANIFEST_NAME,
    ShardDataset,
    pack,
    validate_shard_name,
    write_manifest,
)
from .format import (
    MappedShardReader,
    ShardCorruption,
    ShardIndex,
    ShardIndexV2,
    ShardReader,
    ShardReaderV2,
    ShardWriter,
    ShardWriterV2,
    open_shard_reader,
)
from .membership import (
    TENANT_HEADER,
    AdmissionController,
    FleetMember,
    HashRing,
    MembershipRegistry,
    TokenBucket,
)
from .peer import PeerMiss, PeerShardServer, PeerShardSource, TieredSource
from .prefetch import (
    LocalShardSource,
    ShardPrefetcher,
    SimulatedLatencySource,
    SparseShardReader,
)
from .sources import (
    HttpShardSource,
    RangeNotSupported,
    RetryingSource,
    SourceUnavailable,
)

__all__ = [
    "MANIFEST_NAME",
    "TENANT_HEADER",
    "AdmissionController",
    "FleetMember",
    "HashRing",
    "HttpShardSource",
    "LocalShardSource",
    "MappedShardReader",
    "MembershipRegistry",
    "PeerMiss",
    "PeerShardServer",
    "PeerShardSource",
    "RangeNotSupported",
    "RetryingSource",
    "ShardCorruption",
    "ShardDataset",
    "ShardIndex",
    "ShardIndexV2",
    "ShardPrefetcher",
    "ShardReader",
    "ShardReaderV2",
    "ShardWriter",
    "ShardWriterV2",
    "SimulatedLatencySource",
    "SourceUnavailable",
    "SparseShardReader",
    "TieredSource",
    "TokenBucket",
    "open_shard_reader",
    "pack",
    "validate_shard_name",
    "write_manifest",
]

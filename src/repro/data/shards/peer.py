"""Peer-to-peer shard exchange between data ranks.

At multi-rank scale the object store is the last serialization point: every
rank independently re-downloads the same shards.  This module turns N
independent loaders into one cooperative cache — each rank serves its warm
``ShardPrefetcher`` cache to its peers, and a cache miss consults those
warm peers *before* anyone goes back to the origin.

The composed read path (``ShardDataset(url, peers=[...])`` assembles it)::

    origin (HttpShardSource)        authoritative, slow, retried
      └─ RetryingSource             backoff + jitter on origin flakiness
           └─ TieredSource          try warm peers first, then origin
                ├─ PeerShardSource  round-robin, health-tracked, fast-fail
                └─ (origin stack)
                     └─ ShardPrefetcher   local disk cache + scheduler
                          └─ PeerShardServer   serves THIS rank's cache out

Tier contract per request: the prefetcher's local cache answers first (no
network); on a miss the ``TieredSource`` asks each healthy peer once with a
short fast-fail timeout — a peer answers only from memory/disk it already
holds (whole shards, ranged reads, and resident sparse spans) and replies
with a structured 404 miss (``X-Shard-Miss``) for anything else, so a peer
miss costs one cheap round trip, never a cascading fetch.  Only when every
peer misses or is unhealthy does the request fall through to the retrying
origin.  Peers are an optimization tier: they are never authoritative for
existence (``PeerShardSource`` raises ``PeerMiss``, not
``FileNotFoundError``), and a dead or flaky peer is benched for
``cooldown_s`` and silently bypassed rather than retried.

Pieces:

``PeerShardServer``  HTTP server over a live ``ShardPrefetcher``: whole
                     shards (``200``) from full disk entries, ranged reads
                     (``206``) from full entries *and* resident sparse
                     spans (header/index regions of a sparse entry are
                     re-serialized from its parsed index), structured
                     ``404`` + ``X-Shard-Miss`` for non-resident data.
                     Strictly read-only: lookups go through
                     ``ShardPrefetcher.peek`` — serving a peer never
                     triggers a fetch or perturbs LRU order on this rank.
``PeerShardSource``  client half: a ``RemoteShardSource`` over a list of
                     peer URLs — round-robin start, one attempt per healthy
                     peer per request, failure cooldown, fast-fail timeout.
``TieredSource``     composes ``PeerShardSource`` in front of any origin
                     source; counts ``peer_hits`` / ``peer_bytes`` /
                     ``origin_bytes`` which flow through
                     ``ShardPrefetcher.stats()`` (``source_``-prefixed)
                     into ``StageStatsSnapshot`` and ``format_stats``.

Sparse→full promotion (``prefetch.py``) closes the loop: a sparse entry
that demand-fetches past ``promote_threshold`` upgrades to a whole-shard
disk entry — which this server can then serve whole to every other rank.

Columnar (format v2) shards need no special casing here: ranged reads are
absolute file offsets whatever the format, so a peer running a projected
read asks for **column regions** and this server answers them from full
entries or resident sparse spans exactly as it answers v1 sample ranges —
a rank that only ever fetched the ``image`` column serves those column
spans (plus the re-serialized header/column index) to its peers.

Fleet failure semantics
-----------------------
The elastic-fleet layer (``membership.py``) turns the static peer list
into a live ring.  What each event means, end to end:

=============  =============================================================
Event          Semantics
=============  =============================================================
**join**       A rank registers with the registry (``/fleet/register``) and
               starts heartbeating.  Consumers polling ``/fleet/members``
               add it via ``sync_membership`` — the consistent-hash ring
               remaps only the arcs the newcomer now owns (~1/N of the
               keyspace); every other shard keeps its owner and stays warm.
**leave**      Graceful: ``/fleet/leave`` removes the member, one ring
               rebuild, bounded remap.  Crash: heartbeats stop — after
               ``suspect_after_s`` the registry marks it *suspect* and
               consumers bench it straight into the request-path circuit
               breaker (``mark_suspect``) without burning a request
               timeout; after ``dead_after_s`` it is swept from the view
               and removed from the ring.  A peer already OPEN when the
               suspect verdict arrives is NOT double-benched: its existing
               cooldown stands (``mark_suspect`` never extends
               ``_down_until``).
**restart**    The rank re-registers (same or new URL).  Its prefetcher
               re-opens persisted full shards and sparse spans from the
               warm-restart sidecar (``persist_state=True``) instead of
               re-fetching, so it rejoins the fleet *warm*.  On the
               consumer side a suspect→live transition offers the peer
               exactly ONE half-open probe (``mark_live`` rewinds the
               cooldown; the probe — not the registry — closes the
               circuit).
**quota**      Admission control (``AdmissionController``): an over-quota
               tenant (``X-Tenant``) or an over-capacity server gets a
               structured ``429`` + ``Retry-After``.  ``RetryingSource``
               honors the hint; peers treat a 429 like any transport
               fault (bench + retry elsewhere), so one greedy consumer
               degrades alone instead of collapsing the fleet.
=============  =============================================================

``testing.ShardHTTPServer`` remains the *origin* fixture (serving a shard
directory); this module is the production peer tier grown out of it.
"""

from __future__ import annotations

import http.client
import http.server
import itertools
import json
import re
import threading
import time
import urllib.parse
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait

from ...core import trace as _trace
from ...core.metrics import CONTENT_TYPE_LATEST as _METRICS_CONTENT_TYPE
from .dataset import validate_shard_name
from .format import MappedShardReader
from .membership import TENANT_HEADER, HashRing
from .sources import HttpShardSource, RangeNotSupported, SourceUnavailable

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d+)?$")

#: response header naming why a peer could not serve a request
MISS_HEADER = "X-Shard-Miss"


class PeerMiss(Exception):
    """No peer could serve the request (not resident anywhere, or every
    peer is unhealthy).  The tiered source falls through to the origin on
    this — it never reaches the read path, and it never means the object
    does not exist (only the origin is authoritative for existence)."""


# ---------------------------------------------------------------------------
# serving side
# ---------------------------------------------------------------------------
class _PeerRequestHandler(http.server.BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: peers reuse connections
    server_version = "ShardPeer/1"

    def setup(self) -> None:
        super().setup()
        with self.server.lock:
            self.server.connections += 1

    def _send(self, status: int, body, extra: dict | None = None) -> None:
        self.send_response(status)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)
        with self.server.lock:
            self.server.bytes_served += len(body)

    def _miss(self, why: str) -> None:
        """Structured miss: 404 + X-Shard-Miss so a client (or a human with
        curl) can tell 'peer doesn't hold this' apart from a real origin
        404 — and observability can count sparse vs absent misses."""
        with self.server.lock:
            self.server.misses += 1
        self._send(404, why.encode(), {MISS_HEADER: why})

    def _fleet(self, op: str, query: str) -> None:
        """Registry endpoints (``/fleet/*``): JSON control plane riding the
        same port as the data plane.  Kept outside the shard request
        counters — membership chatter must not skew cache hit rates."""
        reg = self.server.registry
        params = dict(urllib.parse.parse_qsl(query))

        def _json(obj, status: int = 200) -> None:
            body = json.dumps(obj).encode()
            self._send(status, body, {"Content-Type": "application/json"})

        if op == "members":
            _json(reg.members())
        elif op == "register":
            pid, url = params.get("id"), params.get("url")
            if not pid or not url:
                _json({"error": "id and url required"}, 400)
                return
            _json(reg.register(pid, url))
        elif op == "heartbeat":
            pid = params.get("id")
            if not pid:
                _json({"error": "id required"}, 400)
                return
            _json({"ok": reg.heartbeat(pid)})
        elif op == "leave":
            pid = params.get("id")
            if not pid:
                _json({"error": "id required"}, 400)
                return
            reg.leave(pid)
            _json({"ok": True})
        else:
            _json({"error": f"unknown fleet op {op!r}"}, 404)

    def _admit(self, nbytes: int) -> bool:
        """Per-tenant quota gate, called just before a body is sent.  False
        means a 429 + Retry-After already went out."""
        adm = self.server.admission
        if adm is None:
            return True
        tenant = self.headers.get(TENANT_HEADER, "default")
        wait = adm.admit(tenant, nbytes)
        if wait is None:
            return True
        self._send(429, b"over quota", {"Retry-After": f"{wait:.3f}"})
        return False

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        srv = self.server
        path, _, query = self.path.partition("?")
        if path == "/metrics" and srv.metrics is not None:
            # mounted observability endpoint: Prometheus text exposition
            # (checked before shard resolution; "/metrics" is reserved)
            body = srv.metrics.render().encode()
            self._send(200, body, {"Content-Type": _METRICS_CONTENT_TYPE})
            return
        if path.startswith("/fleet/") and srv.registry is not None:
            # control plane (reserved like /metrics: validate_shard_name
            # rejects any "/" so no shard can ever shadow these paths)
            self._fleet(path[len("/fleet/") :], query)
            return
        adm = srv.admission
        if adm is not None and not adm.start_request():
            self._send(
                429, b"at capacity", {"Retry-After": f"{adm.retry_wait_s:.3f}"}
            )
            return
        try:
            with srv.lock:
                srv.requests += 1
            name = urllib.parse.unquote(path.lstrip("/"))
            try:
                validate_shard_name(name)
            except ValueError:
                self._miss("bad-name")  # peers only ever serve bare shard names
                return
            reader = srv.prefetcher.peek(name)  # never fetches, no LRU touch
            if reader is None:
                self._miss("absent")
                return
            range_header = self.headers.get("Range")
            try:
                if range_header:
                    self._serve_range(reader, range_header.strip())
                else:
                    self._serve_whole(reader)
            except Exception:
                # reader torn down mid-serve (prefetcher closed, entry evicted
                # and unmapped): a miss, not a 500 — the client has the origin
                self._miss("unavailable")
        finally:
            if adm is not None:
                adm.end_request()

    def _serve_whole(self, reader) -> None:
        if not isinstance(reader, MappedShardReader):
            # sparse entries cannot answer a whole-shard GET (only the
            # origin holds the full payload until promotion lands)
            self._miss("sparse")
            return
        body = reader.raw(0, reader.nbytes)
        if not self._admit(len(body)):
            return
        with self.server.lock:
            self.server.served_whole += 1
        self._send(200, body)

    def _serve_range(self, reader, range_header: str) -> None:
        m = _RANGE_RE.match(range_header)
        if m is None:
            self._miss("bad-range")
            return
        total = (
            reader.nbytes
            if isinstance(reader, MappedShardReader)
            else reader.index.total_bytes
        )
        start = int(m.group(1))
        end = int(m.group(2)) if m.group(2) is not None else total - 1
        if start >= total:
            self._send(416, b"", {"Content-Range": f"bytes */{total}"})
            return
        end = min(end, total - 1)
        length = end - start + 1
        body = reader.raw(start, length)
        if body is None:  # sparse entry: the range is not resident
            self._miss("cold-range")
            return
        if not self._admit(len(body)):
            return
        with self.server.lock:
            self.server.served_ranges += 1
        self._send(206, body, {"Content-Range": f"bytes {start}-{end}/{total}"})

    def log_message(self, *args) -> None:  # quiet: callers read counters
        pass


class PeerShardServer(http.server.ThreadingHTTPServer):
    """Serves a live ``ShardPrefetcher``'s warm cache to peer data ranks.

    Read-only window over the cache: whole shards and ranged reads from
    full disk entries, ranged reads of resident spans (plus re-serialized
    header/index regions) from sparse entries, and a structured
    ``404``/``X-Shard-Miss`` for everything else.  Never triggers a fetch.

    Usage (typically one per rank, next to the rank's prefetcher)::

        server = PeerShardServer(prefetcher).start()   # or: with ... as server:
        ...hand server.url to the other ranks' ``peers=[...]``...
        server.close()

    Counters (under ``lock``, also via ``stats()``): ``requests``,
    ``misses``, ``served_whole``, ``served_ranges``, ``bytes_served``,
    ``connections``.

    Optional fleet hooks:

    * ``registry=`` mounts the ``/fleet/*`` membership endpoints
      (``register``/``heartbeat``/``leave``/``members``) — any one rank's
      server can host the fleet registry alongside its data plane.
    * ``admission=`` gates every shard request through an
      ``AdmissionController`` (max-inflight cap + per-tenant token-bucket
      quotas keyed on the ``X-Tenant`` header) answering structured
      ``429`` + ``Retry-After`` when over.
    """

    daemon_threads = True

    def __init__(
        self,
        prefetcher,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        metrics=None,
        registry=None,
        admission=None,
    ):
        self.prefetcher = prefetcher
        # optional core.metrics.MetricsExporter: mounts GET /metrics on this
        # server (one port serves shards to peers AND telemetry to scrapers)
        self.metrics = metrics
        # optional membership.MembershipRegistry: mounts /fleet/* endpoints
        self.registry = registry
        # optional membership.AdmissionController: quota + inflight gating
        self.admission = admission
        self.lock = threading.Lock()
        self.requests = 0
        self.misses = 0
        self.served_whole = 0
        self.served_ranges = 0
        self.bytes_served = 0
        self.connections = 0
        self._thread: threading.Thread | None = None
        super().__init__((host, port), _PeerRequestHandler)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "PeerShardServer":
        """Serve in a daemon thread; returns self for chaining."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self.serve_forever, name="peer-shard-server", daemon=True
            )
            self._thread.start()
        return self

    def stats(self) -> dict[str, int]:
        with self.lock:
            out = {
                "requests": self.requests,
                "misses": self.misses,
                "served_whole": self.served_whole,
                "served_ranges": self.served_ranges,
                "bytes_served": self.bytes_served,
                "connections": self.connections,
            }
        if self.admission is not None:
            out.update(self.admission.stats())
        return out

    def close(self) -> None:
        if self._thread is not None:
            self.shutdown()  # only valid once serve_forever is running
            self._thread.join(timeout=5)
            self._thread = None
        self.server_close()

    def __enter__(self) -> "PeerShardServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# client side
# ---------------------------------------------------------------------------
#: per-peer circuit-breaker states
_CLOSED, _OPEN, _HALF_OPEN = 0, 1, 2


class PeerShardSource:
    """Reads from peer ranks' warm caches: round-robin, health-tracked,
    fast-fail.

    One ``HttpShardSource`` per peer (keep-alive reuse, short ``timeout`` —
    a peer on the same fabric answers in milliseconds or not at all).  Each
    request starts at a rotating peer and tries each *healthy* peer at most
    once: a structured 404 miss moves on to the next peer; a transport
    error trips that peer's circuit breaker.  Exhausting all peers raises
    ``PeerMiss`` — never ``FileNotFoundError``, because peers are not
    authoritative for existence.

    Circuit breaker (per peer): a transport error OPENs the circuit —
    every request skips the peer outright (its timeout must not tax the
    read path).  After ``cooldown_s`` the circuit goes HALF_OPEN: exactly
    ONE request is let through as a probe while everything else keeps
    skipping, so a still-dead peer costs one timeout per cooldown window,
    not one per concurrent fetch.  A probe that completes at the transport
    level (data back, or a structured miss) CLOSEs the circuit; a probe
    that fails re-OPENs it for another ``cooldown_s``.

    Placement: ``placement="round_robin"`` (default) keeps the PR-4
    behaviour — every healthy peer probed in rotating order.
    ``placement="ring"`` routes each request over a consistent-hash ring
    (``HashRing`` with ``vnodes`` points per peer) to the shard's owner
    plus ``replicas`` distinct backups: O(owner+replicas) probes instead
    of O(peers), and a membership change remaps only ~1/N of the
    keyspace.  Ring mode allows an *empty* initial peer list — the
    membership layer (``FleetMember.sync_membership``) grows and shrinks
    the ring live via ``add_peer``/``remove_peer``/``mark_suspect``/
    ``mark_live``.
    """

    def __init__(
        self,
        peer_urls,
        *,
        timeout: float = 2.0,
        cooldown_s: float = 5.0,
        headers: dict[str, str] | None = None,
        clock=time.monotonic,
        placement: str = "round_robin",
        replicas: int = 1,
        vnodes: int = 64,
    ):
        if placement not in ("round_robin", "ring"):
            raise ValueError(f"unknown placement {placement!r}")
        urls = [u.rstrip("/") for u in peer_urls]
        if not urls and placement != "ring":
            raise ValueError("PeerShardSource needs at least one peer URL")
        self._timeout = timeout
        self._headers = headers
        self._sources = [
            HttpShardSource(u, timeout=timeout, headers=headers) for u in urls
        ]
        self.peer_urls = [s.root_url for s in self._sources]
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = [_CLOSED] * len(self._sources)
        self._down_until = [0.0] * len(self._sources)
        self._rr = itertools.count()
        self.placement = placement
        self.replicas = replicas
        self._ring = (
            HashRing(self.peer_urls, vnodes=vnodes) if placement == "ring" else None
        )
        self._urls_index = {u: i for i, u in enumerate(self.peer_urls)}
        self.hits = 0
        self.misses = 0  # requests no peer could serve
        self.errors = 0  # transport failures observed (circuit trips)
        self.probes = 0  # half-open probe requests issued
        self.recoveries = 0  # probes that closed the circuit again
        self.bytes_fetched = 0
        self.suspected = 0  # membership-driven preemptive benchings
        self.ring_remaps = 0  # vnode arcs that changed owner, cumulative
        self.membership_changes = 0

    def _resolve_locked(self, i: int, src) -> int | None:
        """Re-anchor index ``i`` to ``src`` — membership mutations can
        shift the parallel lists between a request capturing an index and
        its outcome landing.  None = the peer was removed mid-request."""
        if 0 <= i < len(self._sources) and self._sources[i] is src:
            return i
        try:
            return self._sources.index(src)
        except ValueError:
            return None

    def _settle(self, i: int, src=None) -> None:
        """Peer ``i`` answered at the transport level: close its circuit
        (a successful probe is a recovery; a closed peer is a no-op)."""
        with self._lock:
            if src is not None:
                j = self._resolve_locked(i, src)
                if j is None:
                    return
                i = j
            recovered = self._state[i] == _HALF_OPEN
            changed = self._state[i] != _CLOSED
            if recovered:
                self.recoveries += 1
            self._state[i] = _CLOSED
            url = self.peer_urls[i]
        if changed:
            tracer = _trace.get_tracer()
            if tracer.enabled:
                tracer.instant(
                    "breaker:close", "peer",
                    {"peer": url, "recovered": recovered},
                )

    def _trip(self, i: int, src=None) -> None:
        """Peer ``i`` failed at the transport level: open its circuit."""
        with self._lock:
            if src is not None:
                j = self._resolve_locked(i, src)
                if j is None:
                    return
                i = j
            self.errors += 1
            self._state[i] = _OPEN
            self._down_until[i] = self._clock() + self.cooldown_s
            url = self.peer_urls[i]
        tracer = _trace.get_tracer()
        if tracer.enabled:
            tracer.instant(
                "breaker:open", "peer",
                {"peer": url, "cooldown_s": self.cooldown_s},
            )

    def _candidates_locked(self, key: str | None) -> list[int]:
        """Probe order for one request: ring owner + replicas when placed,
        rotating full scan otherwise."""
        n = len(self._sources)
        if n == 0:
            return []
        if self._ring is not None and key is not None:
            want = 1 + max(0, self.replicas)
            return [
                self._urls_index[u]
                for u in self._ring.owners(key, want)
                if u in self._urls_index
            ]
        start = next(self._rr) % n
        return [(start + k) % n for k in range(n)]

    def _try_each(self, op, what: str, key: str | None = None) -> bytes:
        with self._lock:
            now = self._clock()
            eligible = []  # (index, source) — identity survives list shifts
            admitted: set[int] = set()  # promoted to half-open, not yet probed
            for i in self._candidates_locked(key):
                state = self._state[i]
                if state == _CLOSED:
                    eligible.append((i, self._sources[i]))
                elif state == _OPEN and self._down_until[i] <= now:
                    # cooldown expired: let exactly THIS request through as
                    # the half-open probe; concurrent requests keep skipping
                    # until the probe settles the circuit one way or the other
                    self._state[i] = _HALF_OPEN
                    admitted.add(i)
                    eligible.append((i, self._sources[i]))
                # _HALF_OPEN (someone else's probe in flight) or a still-
                # cooling _OPEN peer: skip outright, no timeout paid
        try:
            for i, src in eligible:
                if i in admitted:
                    # the probe is actually going out: from here its outcome
                    # (settle or trip) owns the circuit transition
                    admitted.discard(i)
                    with self._lock:
                        self.probes += 1
                    tracer = _trace.get_tracer()
                    if tracer.enabled:
                        tracer.instant(
                            "breaker:probe", "peer", {"peer": src.root_url}
                        )
                try:
                    data = op(src)
                except FileNotFoundError:
                    # structured miss: the transport is fine, the peer just
                    # doesn't hold it — a healthy answer for the breaker
                    self._settle(i, src)
                    continue
                except (
                    SourceUnavailable,
                    OSError,
                    http.client.HTTPException,
                    # ValueError: the peer answered with malformed data — a
                    # short 206 or a 416 from a stale/torn copy under the same
                    # name.  Peers are never authoritative, so that copy must
                    # read as a breaker trip, not crash the read path.
                    ValueError,
                ):
                    # dead/flaky/stale peer: open its circuit so its timeout
                    # stops taxing every fetch; the origin tier covers it
                    self._trip(i, src)
                    continue
                self._settle(i, src)
                with self._lock:
                    self.hits += 1
                    self.bytes_fetched += len(data)
                return data
        finally:
            # An earlier peer served the request before an admitted probe was
            # attempted: hand the half-open slot back to OPEN (down_until is
            # already expired, so the NEXT request re-admits it) — otherwise
            # the peer would sit in HALF_OPEN forever and never recover.
            if admitted:
                with self._lock:
                    for i, src in eligible:
                        if i in admitted:
                            j = self._resolve_locked(i, src)
                            if j is not None and self._state[j] == _HALF_OPEN:
                                self._state[j] = _OPEN
        with self._lock:
            self.misses += 1
        raise PeerMiss(f"no peer could serve {what}")

    # -- membership hooks (driven by membership.FleetMember) ----------------
    def _rebuild_ring_locked(self) -> None:
        self._urls_index = {u: i for i, u in enumerate(self.peer_urls)}
        if self._ring is not None:
            moved = self._ring.rebuild(self.peer_urls)
            self.ring_remaps += moved
            self.membership_changes += 1

    def add_peer(self, url: str) -> bool:
        """Admit a new live peer (no-op if already present)."""
        url = url.rstrip("/")
        src = None
        with self._lock:
            if url in self._urls_index:
                return False
            src = HttpShardSource(url, timeout=self._timeout, headers=self._headers)
            self._sources.append(src)
            self.peer_urls.append(src.root_url)
            self._state.append(_CLOSED)
            self._down_until.append(0.0)
            self._rebuild_ring_locked()
        tracer = _trace.get_tracer()
        if tracer.enabled:
            tracer.instant("fleet:join", "peer", {"peer": url})
        return True

    def remove_peer(self, url: str) -> bool:
        """Drop a departed peer; its ring arcs move to the survivors."""
        url = url.rstrip("/")
        with self._lock:
            i = self._urls_index.get(url)
            if i is None:
                return False
            src = self._sources.pop(i)
            self.peer_urls.pop(i)
            self._state.pop(i)
            self._down_until.pop(i)
            self._rebuild_ring_locked()
        src.close()
        tracer = _trace.get_tracer()
        if tracer.enabled:
            tracer.instant("fleet:leave", "peer", {"peer": url})
        return True

    def mark_suspect(self, url: str) -> None:
        """Membership says this peer missed heartbeats: bench it NOW
        instead of paying a request-time timeout to find out.  A peer
        already OPEN (or probing) keeps its existing cooldown untouched —
        the registry's verdict must never *extend* a request-path bench
        (no double-benching)."""
        url = url.rstrip("/")
        with self._lock:
            i = self._urls_index.get(url)
            if i is None or self._state[i] != _CLOSED:
                return
            self._state[i] = _OPEN
            self._down_until[i] = self._clock() + self.cooldown_s
            self.suspected += 1
        tracer = _trace.get_tracer()
        if tracer.enabled:
            tracer.instant("breaker:suspect", "peer", {"peer": url})

    def mark_live(self, url: str) -> None:
        """Membership says a suspect peer heartbeats again: rewind its
        cooldown so the NEXT request admits exactly one half-open probe.
        Deliberately does NOT force-close the circuit — the data path, not
        the control plane, gets the final say on usability."""
        url = url.rstrip("/")
        with self._lock:
            i = self._urls_index.get(url)
            if i is None or self._state[i] != _OPEN:
                return
            self._down_until[i] = min(self._down_until[i], self._clock())

    def sync_membership(self, live_urls, suspect_urls=()) -> None:
        """Reconcile the peer set with a registry view: add newcomers,
        drop unknowns, bench suspects.  ``live_urls`` is the full member
        list (including suspects); ``suspect_urls`` flags the subset to
        bench preemptively."""
        want = {u.rstrip("/") for u in live_urls}
        with self._lock:
            have = set(self._urls_index)
        for url in want - have:
            self.add_peer(url)
        for url in have - want:
            self.remove_peer(url)
        for url in suspect_urls:
            self.mark_suspect(url)

    def shrink_replication(self) -> None:
        """Graceful-degradation hook (``core.health.shrink_replication``):
        serve from the ring owner only — replica probes are optional work
        worth shedding when the consumer is already behind.  One-way for
        this source's lifetime; a no-op under round-robin placement."""
        with self._lock:
            self.replicas = 0

    # -- RemoteShardSource protocol ----------------------------------------
    def fetch(self, name: str) -> bytes:
        return self._try_each(lambda s: s.fetch(name), name, key=name)

    def fetch_range(self, name: str, start: int, length: int) -> bytes:
        def op(src):
            try:
                return src.fetch_range(name, start, length)
            except RangeNotSupported as e:
                # defensive (a proxy in front of a peer answered 200): the
                # body is in hand, serve the slice — still a peer hit
                return bytes(memoryview(e.body)[start : start + length])

        data = self._try_each(op, f"{name}[{start}:+{length}]", key=name)
        if len(data) != length:
            # a torn peer copy must read as a miss, not corrupt the range
            raise PeerMiss(f"peer returned {len(data)} bytes for {name}+{length}")
        return data

    # -- visibility / lifecycle --------------------------------------------
    def stats(self) -> dict[str, float]:
        with self._lock:
            down = sum(1 for s in self._state if s != _CLOSED)
            return {
                "hits": self.hits,
                "misses": self.misses,
                "errors": self.errors,
                "probes": self.probes,
                "recoveries": self.recoveries,
                "bytes_fetched": self.bytes_fetched,
                "peers": len(self._sources),
                # a peer is down until a half-open probe actually closes its
                # circuit — an expired cooldown alone proves nothing
                "peers_down": down,
                "peers_live": len(self._sources) - down,
                "peers_suspect": down,
                "suspected": self.suspected,
                "ring_remaps": self.ring_remaps,
                "membership_changes": self.membership_changes,
                "replicas": self.replicas,
            }

    def close(self) -> None:
        for src in self._sources:
            src.close()


class TieredSource:
    """Warm peers in front of an origin source — the middle of the
    ``origin → retry → peers → prefetcher`` stack.

    Every ``fetch``/``fetch_range`` first asks ``PeerShardSource`` (cheap,
    fast-fail, may miss) and falls through to ``origin`` (authoritative,
    retried by its own ``RetryingSource`` wrapper) on ``PeerMiss``.  A
    ``RangeNotSupported`` from the origin propagates untouched so the
    prefetcher can install the whole body it carries.

    Hedging (``hedge_after_s``): the circuit breaker handles a peer that
    is *dead*; hedging handles one that is merely *slow* (network brownout,
    GC pause) without waiting out its full fast-fail timeout.  When the
    peer tier has not answered within ``hedge_after_s``, an origin fetch is
    launched *in parallel* and the first success wins — the loser is
    cancelled if it has not started, or its result discarded.  ``None``
    (default) disables hedging and keeps the strictly sequential tiers.

    ``disable_peers()`` is the graceful-degradation hook (see
    ``core.health``): it drops the stack to origin-only — no peer requests,
    no hedging — for when the peer fleet itself is the suspected problem.

    ``fetch_range`` is exposed iff the origin has it (the prefetcher's
    protocol sniffing must see the stack exactly as it would see the bare
    origin); ``range_supported`` mirrors the origin's view.

    Counters — ``peer_hits`` / ``peer_misses`` / ``peer_bytes`` /
    ``origin_fetches`` / ``origin_bytes`` / ``hedges`` / ``hedge_wins`` —
    flow through ``ShardPrefetcher.stats()`` as ``source_peer_hits`` etc.
    into ``StageStatsSnapshot`` and the ``format_stats`` dashboard.
    """

    def __init__(self, origin, peers, *, hedge_after_s: float | None = None):
        self.origin = origin
        self.peers = (
            peers if isinstance(peers, PeerShardSource) else PeerShardSource(peers)
        )
        if hedge_after_s is not None and hedge_after_s <= 0:
            raise ValueError("hedge_after_s must be > 0 seconds")
        self.hedge_after_s = hedge_after_s
        if hedge_after_s is not None:
            # Two pools, not one: on a shared pool the hedged origin fetch
            # queues BEHIND the pending peer lookups whose slowness it is
            # meant to bound, and peer-lookup queueing alone can exceed
            # hedge_after_s (spurious hedges).  Threads are created lazily,
            # so generous caps cost nothing at rest.
            self._peer_ex = ThreadPoolExecutor(
                max_workers=32, thread_name_prefix="repro-hedge-peer"
            )
            self._origin_ex = ThreadPoolExecutor(
                max_workers=32, thread_name_prefix="repro-hedge-origin"
            )
        else:
            self._peer_ex = None
            self._origin_ex = None
        self._lock = threading.Lock()
        self._peers_disabled = False
        self.peer_hits = 0
        self.peer_misses = 0
        self.peer_bytes = 0
        self.origin_fetches = 0
        self.origin_bytes = 0
        self.hedges = 0  # origin fetches launched because the peer was slow
        self.hedge_wins = 0  # hedged origin fetches that beat the peer
        # mirror the origin's protocol surface exactly (see class docstring)
        if callable(getattr(origin, "fetch_range", None)):
            self.fetch_range = self._fetch_range

    # -- degradation hook ---------------------------------------------------
    def disable_peers(self) -> None:
        """Drop to origin-only (idempotent, one-way for this source's
        lifetime): the health monitor calls this when the pipeline is
        degraded and the peer tier is optional work worth shedding."""
        with self._lock:
            self._peers_disabled = True

    @property
    def peers_disabled(self) -> bool:
        with self._lock:
            return self._peers_disabled

    def shrink_replication(self) -> None:
        """Degradation rung below ``disable_peers``: keep the peer tier but
        serve each shard from its ring owner only (skip replica probes)."""
        self.peers.shrink_replication()

    # -- internals ----------------------------------------------------------
    def _record_peer_win(self, data: bytes) -> None:
        with self._lock:
            self.peer_hits += 1
            self.peer_bytes += len(data)

    def _origin_call(self, call) -> bytes:
        try:
            data = call()
        except RangeNotSupported as e:
            with self._lock:
                self.origin_fetches += 1
                self.origin_bytes += len(e.body)  # the whole body crossed the wire
            raise
        with self._lock:
            self.origin_fetches += 1
            self.origin_bytes += len(data)
        return data

    def _peer_try(self, op) -> bytes | None:
        if self.peers_disabled:
            return None
        try:
            data = op(self.peers)
        except PeerMiss:
            with self._lock:
                self.peer_misses += 1
            return None
        self._record_peer_win(data)
        return data

    def _hedged(self, peer_op, origin_call, what: str) -> bytes:
        """Peer tier with a latency budget: give the peers ``hedge_after_s``
        to answer, then race an origin fetch against them.  First success
        wins; the loser is cancelled (not yet started) or discarded.  The
        budget runs from when the peer lookup actually STARTS executing —
        executor queueing is not peer slowness — but a lookup that cannot
        even start within the budget hedges immediately (a backed-up peer
        pool is as slow as a slow peer from the consumer's seat)."""
        started = threading.Event()
        t_start = [0.0]

        def timed_peer(p):
            t_start[0] = time.monotonic()
            started.set()
            return peer_op(p)

        peer_fut = self._peer_ex.submit(timed_peer, self.peers)
        slow = False
        try:
            if started.wait(self.hedge_after_s):
                budget = t_start[0] + self.hedge_after_s - time.monotonic()
                data = peer_fut.result(timeout=max(0.0, budget))
            else:
                slow = True  # never even started: hedge now
        except PeerMiss:
            with self._lock:
                self.peer_misses += 1
            return self._origin_call(origin_call)
        except FuturesTimeout:
            slow = True  # slow peer: hedge (below)
        except Exception:
            # the peer tier never raises anything else by contract; treat a
            # surprise as a miss — the origin is authoritative anyway
            with self._lock:
                self.peer_misses += 1
            return self._origin_call(origin_call)
        if not slow:
            self._record_peer_win(data)
            return data
        with self._lock:
            self.hedges += 1
        tracer = _trace.get_tracer()
        if tracer.enabled:
            tracer.instant(
                "hedge:start", "peer",
                {"what": what, "after_s": self.hedge_after_s},
            )
        origin_fut = self._origin_ex.submit(self._origin_call, origin_call)
        pending = {peer_fut, origin_fut}
        origin_exc: BaseException | None = None
        while pending:
            done, pending = futures_wait(pending, return_when=FIRST_COMPLETED)
            for f in done:
                try:
                    data = f.result()
                except RangeNotSupported:
                    # only the origin raises this, and it carries the whole
                    # body — a win; the slow peer's eventual result is moot
                    peer_fut.cancel()
                    with self._lock:
                        self.hedge_wins += 1
                    if tracer.enabled:
                        tracer.instant(
                            "hedge:win", "peer", {"what": what, "winner": "origin"}
                        )
                    raise
                except BaseException as e:  # noqa: BLE001 - collected below
                    if f is origin_fut:
                        origin_exc = e
                    else:
                        with self._lock:
                            self.peer_misses += 1
                    continue
                for p in pending:
                    p.cancel()
                if f is peer_fut:
                    self._record_peer_win(data)
                else:
                    with self._lock:
                        self.hedge_wins += 1
                if tracer.enabled:
                    tracer.instant(
                        "hedge:win", "peer",
                        {"what": what,
                         "winner": "peer" if f is peer_fut else "origin"},
                    )
                return data
        # both lanes failed: surface the origin's error (authoritative —
        # a FileNotFoundError here really means the object does not exist)
        assert origin_exc is not None
        raise origin_exc

    # -- RemoteShardSource protocol ----------------------------------------
    def fetch(self, name: str) -> bytes:
        if self._peer_ex is not None and not self.peers_disabled:
            return self._hedged(
                lambda p: p.fetch(name), lambda: self.origin.fetch(name), name
            )
        data = self._peer_try(lambda p: p.fetch(name))
        if data is not None:
            return data
        return self._origin_call(lambda: self.origin.fetch(name))

    def _fetch_range(self, name: str, start: int, length: int) -> bytes:
        if self._peer_ex is not None and not self.peers_disabled:
            return self._hedged(
                lambda p: p.fetch_range(name, start, length),
                lambda: self.origin.fetch_range(name, start, length),
                f"{name}[{start}:+{length}]",
            )
        data = self._peer_try(lambda p: p.fetch_range(name, start, length))
        if data is not None:
            return data
        return self._origin_call(
            lambda: self.origin.fetch_range(name, start, length)
        )

    @property
    def range_supported(self) -> bool:
        return bool(getattr(self.origin, "range_supported", True))

    # -- visibility / lifecycle --------------------------------------------
    def stats(self) -> dict[str, float]:
        origin_stats = getattr(self.origin, "stats", None)
        out = dict(origin_stats()) if callable(origin_stats) else {}
        with self._lock:
            out.update(
                peer_hits=self.peer_hits,
                peer_misses=self.peer_misses,
                peer_bytes=self.peer_bytes,
                origin_fetches=self.origin_fetches,
                origin_bytes=self.origin_bytes,
                hedges=self.hedges,
                hedge_wins=self.hedge_wins,
                peers_disabled=int(self._peers_disabled),
            )
        peer_stats = self.peers.stats()
        out["peer_errors"] = peer_stats.get("errors", 0)
        out["peers_down"] = peer_stats.get("peers_down", 0)
        out["peer_probes"] = peer_stats.get("probes", 0)
        out["peer_recoveries"] = peer_stats.get("recoveries", 0)
        out["peers_live"] = peer_stats.get("peers_live", 0)
        out["peers_suspect"] = peer_stats.get("peers_suspect", 0)
        out["ring_remaps"] = peer_stats.get("ring_remaps", 0)
        return out

    def close(self) -> None:
        for ex in (self._peer_ex, self._origin_ex):
            if ex is not None:
                ex.shutdown(wait=False, cancel_futures=True)
        self.peers.close()
        origin_close = getattr(self.origin, "close", None)
        if callable(origin_close):
            origin_close()

"""Packed shard file format: header + payload + per-sample index.

One shard file holds many encoded samples (codec.py ``RPR1`` blobs, but the
format is payload-agnostic) packed back to back, so a million-sample dataset
becomes a few hundred large files instead of a million tiny ones — one
``mmap`` per shard replaces an ``open()+read()+close()`` syscall triple per
sample, and reads become pointer arithmetic into the page cache.

On-disk layout (little-endian throughout)::

    [ header | payload region | index region ]

    header (32 bytes, fixed):
        magic         8s   b"RPRSHRD1" (version is the last byte: '1')
        version       u32  FORMAT_VERSION
        n_samples     u32
        index_offset  u64  file offset of the index region
        payload_off   u64  file offset of the payload region (= 32)

    index (n_samples x 16 bytes, written AFTER the payload so the writer
    streams samples without knowing sizes up front):
        offset        u64  absolute file offset of the sample
        length        u32  sample byte length
        crc32         u32  zlib.crc32 of the sample bytes

CRC policy: the crc is computed over the *encoded* sample bytes at write
time and verified on first read by default (``ShardReader.read(i)``); a
mismatch raises ``ShardCorruption`` for that sample only, so a flipped bit
surfaces as a per-sample hole in the pipeline rather than a dead shard.
Verification is memoized per sample (a bitset): the bytes behind a shard
file never change, so epoch 2+ over a warm cache skips the crc pass it
already paid — a failed check is never memoized, so a corrupt sample stays
a per-sample hole on every read.  ``verify_all()`` coalesces the whole
check into one sequential payload pass that fills the bitset up front —
the shard cache runs it at install time (on the fetch thread) and
``ShardDataset(verify_crc="eager")`` at mmap-open, taking the ~2x per-read
crc cost off the hot path while keeping the per-sample-hole contract.
Callers doing their own integrity checking pass ``verify=False`` and the
read is pure pointer math.

Versioning: the header magic pins the major layout; ``version`` is the
minor revision.  Readers reject a magic they don't know and a version newer
than theirs (forward-incompatible), and must keep reading every older
version they ever shipped.

``ShardReader.read`` returns a ``memoryview`` slice of the shard's mmap —
zero payload copies; the view stays valid for the life of the mapping (the
reader keeps it alive, and on Linux even an unlinked file's mapping stays
readable, which is what lets the local shard cache evict files with reads
still in flight).
"""

from __future__ import annotations

import mmap
import os
import pathlib
import struct
import zlib

import numpy as np

MAGIC = b"RPRSHRD1"
FORMAT_VERSION = 1
_HEADER = struct.Struct("<8sIIQQ")
HEADER_SIZE = _HEADER.size  # 32
_ENTRY = struct.Struct("<QII")
ENTRY_SIZE = _ENTRY.size  # 16
_INDEX_DTYPE = np.dtype([("off", "<u8"), ("len", "<u4"), ("crc", "<u4")])


class ShardCorruption(ValueError):
    """A shard (or one sample inside it) failed an integrity check."""


def parse_shard_header(header: bytes, name: str = "shard") -> tuple[int, int, int, int]:
    """Validate a 32-byte header blob; returns
    ``(version, n_samples, index_offset, payload_offset)``.

    This is the first step of index-first fetch: a 32-byte ranged read
    through here tells a remote reader where the index region lives (and
    rejects unfinalized / foreign files) before any payload moves."""
    if len(header) < HEADER_SIZE:
        raise ShardCorruption(
            f"{name}: header blob is {len(header)} bytes, need {HEADER_SIZE}"
        )
    magic, version, n, index_off, payload_off = _HEADER.unpack_from(header, 0)
    if magic != MAGIC:
        raise ShardCorruption(
            f"{name}: bad magic {bytes(magic)!r} (unfinalized or foreign file)"
        )
    if version > FORMAT_VERSION:
        raise ShardCorruption(
            f"{name}: shard version {version} is newer than reader {FORMAT_VERSION}"
        )
    return version, n, index_off, payload_off


class ShardIndex:
    """A shard's parsed header + index, held without its payload.

    This is what **index-first fetch** downloads: the fixed 32-byte header
    (which says where the index lives) and the 16-byte-per-sample index
    region — enough to know every sample's offset, length, and crc32, and
    therefore to fetch any subset of the payload with ranged reads instead
    of committing to the whole shard.
    """

    __slots__ = ("n_samples", "payload_off", "index_off", "offsets", "lengths", "crcs")

    def __init__(self, n_samples, payload_off, index_off, offsets, lengths, crcs):
        self.n_samples = n_samples
        self.payload_off = payload_off
        self.index_off = index_off
        self.offsets = offsets
        self.lengths = lengths
        self.crcs = crcs

    @property
    def total_bytes(self) -> int:
        """Size of the full shard file (header + payload + index)."""
        return self.index_off + self.n_samples * ENTRY_SIZE

    @property
    def payload_bytes(self) -> int:
        return self.index_off - self.payload_off

    @property
    def index_nbytes(self) -> int:
        """Bytes a reader must download to learn the index (header + index)."""
        return HEADER_SIZE + self.n_samples * ENTRY_SIZE

    def header_bytes(self) -> bytes:
        """Re-serialize the 32-byte header.  A sparse cache entry holds only
        the *parsed* index, so this is how a ``PeerShardServer`` answers a
        peer's header ranged read without keeping the original blob."""
        return _HEADER.pack(
            MAGIC, FORMAT_VERSION, self.n_samples, self.index_off, self.payload_off
        )

    def index_bytes(self) -> bytes:
        """Re-serialize the index region (16 B/sample) — the peer-serving
        twin of ``header_bytes``."""
        arr = np.empty(self.n_samples, dtype=_INDEX_DTYPE)
        arr["off"] = self.offsets
        arr["len"] = self.lengths
        arr["crc"] = self.crcs
        return arr.tobytes()

    @classmethod
    def parse(cls, header: bytes, index: bytes, name: str = "shard") -> "ShardIndex":
        """Validate + parse a header blob and its index-region blob.

        Applies the same checks as ``ShardReader.__init__`` (magic, version,
        extents) so a remote shard with a zero placeholder header — a
        crashed writer — is rejected here, before any payload is fetched.
        """
        version, n, index_off, payload_off = parse_shard_header(header, name)
        if payload_off > index_off:
            raise ShardCorruption(f"{name}: payload region starts past the index")
        if len(index) != n * ENTRY_SIZE:
            raise ShardCorruption(
                f"{name}: index region is {len(index)} bytes, expected {n * ENTRY_SIZE}"
            )
        parsed = np.frombuffer(index, _INDEX_DTYPE, count=n)
        offsets, lengths, crcs = parsed["off"], parsed["len"], parsed["crc"]
        if n and (
            int(offsets.min(initial=payload_off)) < payload_off
            or int((offsets.astype(np.int64) + lengths).max()) > index_off
        ):
            raise ShardCorruption(
                f"{name}: corrupt index: sample extents outside the payload region"
            )
        return cls(n, payload_off, index_off, offsets, lengths, crcs)


class ShardWriter:
    """Streams samples into one shard file; finalizes index + header on close.

    Usage::

        with ShardWriter(path) as w:
            for blob in blobs:
                w.add(blob)

    ``add`` returns the sample's position within the shard.  The file is not
    a valid shard until ``close()`` (the header is a zero placeholder while
    streaming), so a crashed writer leaves an obviously-invalid file rather
    than a silently short one.  That guarantee extends to exceptions raised
    inside the ``with`` body: ``__exit__`` then calls ``abort()`` — close
    without finalizing — instead of stamping a valid-looking header over a
    partial payload.  ``close()`` fsyncs the payload + index before the
    header write that validates them, so a crash between the two can't
    leave a magic-valid file whose contents never reached the disk.
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._f = open(self.path, "wb")
        self._f.write(b"\0" * HEADER_SIZE)
        self._entries: list[tuple[int, int, int]] = []
        self._closed = False

    def add(self, data) -> int:
        """Append one encoded sample; returns its index within the shard."""
        if self._closed:
            raise RuntimeError("ShardWriter already closed")
        data = memoryview(data)
        off = self._f.tell()
        self._f.write(data)
        self._entries.append((off, data.nbytes, zlib.crc32(data)))
        return len(self._entries) - 1

    @property
    def n_samples(self) -> int:
        return len(self._entries)

    @property
    def payload_bytes(self) -> int:
        return sum(ln for _, ln, _ in self._entries)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        index_off = self._f.tell()
        for entry in self._entries:
            self._f.write(_ENTRY.pack(*entry))
        # payload + index must be durable BEFORE the header makes the file
        # claim to be a valid shard — otherwise a crash between the two
        # writes leaves a magic-valid header over unsynced (possibly lost)
        # contents, defeating the zero-placeholder scheme.
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.seek(0)
        self._f.write(
            _HEADER.pack(
                MAGIC, FORMAT_VERSION, len(self._entries), index_off, HEADER_SIZE
            )
        )
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()

    def abort(self) -> None:
        """Abandon the shard: close the file WITHOUT finalizing it.

        The zero placeholder header stays, so readers reject the file —
        this is the path for an exception mid-stream (``__exit__`` takes it
        automatically).  Idempotent; a no-op after ``close()``.
        """
        if self._closed:
            return
        self._closed = True
        self._f.close()

    def __enter__(self) -> "ShardWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception inside the `with` body means the stream is partial:
        # finalizing would stamp a valid header over bad data — abort instead
        if exc_type is not None:
            self.abort()
        else:
            self.close()


class ShardReader:
    """mmap-backed random access into one shard file.

    ``read(i)`` returns a zero-copy ``memoryview`` of the sample bytes and
    (by default) verifies the per-sample crc32.  The whole index is parsed
    once into numpy arrays at open, so per-read work is two array loads, one
    slice, and (optionally) the crc pass.
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._file = open(self.path, "rb")
        try:
            self._mm = mmap.mmap(self._file.fileno(), 0, access=mmap.ACCESS_READ)
        except ValueError as e:  # empty file
            self._file.close()
            raise ShardCorruption(f"{self.path}: not a shard file ({e})") from e
        self._buf = memoryview(self._mm)
        size = len(self._mm)
        if size < HEADER_SIZE:
            self._fail(f"file is {size} bytes, header needs {HEADER_SIZE}")
        magic, version, n, index_off, payload_off = _HEADER.unpack_from(self._buf, 0)
        if magic != MAGIC:
            self._fail(f"bad magic {bytes(magic)!r} (unfinalized or foreign file)")
        if version > FORMAT_VERSION:
            self._fail(f"shard version {version} is newer than reader {FORMAT_VERSION}")
        if index_off + n * ENTRY_SIZE > size or payload_off > index_off:
            self._fail("truncated shard: index region extends past end of file")
        self.n_samples = n
        self._verified = np.zeros(n, dtype=bool)  # per-sample crc memo
        index = np.frombuffer(self._buf, _INDEX_DTYPE, count=n, offset=index_off)
        self.offsets = index["off"]
        self.lengths = index["len"]
        self.crcs = index["crc"]
        if n and (
            int(self.offsets.min(initial=payload_off)) < payload_off
            or int((self.offsets.astype(np.int64) + self.lengths).max()) > index_off
        ):
            self._fail("corrupt index: sample extents outside the payload region")

    def _fail(self, msg: str) -> None:
        path = self.path
        self.close()
        raise ShardCorruption(f"{path}: {msg}")

    def __len__(self) -> int:
        return self.n_samples

    @property
    def nbytes(self) -> int:
        return len(self._mm)

    def read(self, i: int, *, verify: bool = True) -> memoryview:
        """Zero-copy bytes of sample ``i`` (a slice of the shard's mmap)."""
        if not 0 <= i < self.n_samples:
            raise IndexError(f"sample {i} out of range [0, {self.n_samples})")
        off, ln = int(self.offsets[i]), int(self.lengths[i])
        view = self._buf[off : off + ln]
        # crc memo: the mapping is immutable, so one successful verification
        # covers every later read of the same sample (epoch 2+ of a warm
        # cache is pure pointer math).  A mismatch is never memoized — a
        # corrupt sample raises on every read, keeping the per-sample-hole
        # semantics.  Racing first reads both verify; both set the bit.
        if verify and not self._verified[i]:
            if zlib.crc32(view) != int(self.crcs[i]):
                raise ShardCorruption(f"{self.path}: sample {i} failed crc32 check")
            self._verified[i] = True
        return view

    def verify_all(self) -> int:
        """Verify every sample's crc32 in ONE sequential pass over the
        payload, memoizing each success into the per-sample bitset.

        This is the cache-install fast path: a freshly downloaded shard is
        checked once, in the fetching thread (off the hot read loop), and
        every subsequent ``read`` is pure pointer math.  The per-sample
        failure contract is preserved exactly: a corrupt sample's bit stays
        unset (it is never memoized), so reading it still raises
        ``ShardCorruption`` for that sample only.  Returns the number of
        corrupt samples found.
        """
        bad = 0
        for i in range(self.n_samples):
            if self._verified[i]:
                continue
            off, ln = int(self.offsets[i]), int(self.lengths[i])
            if zlib.crc32(self._buf[off : off + ln]) == int(self.crcs[i]):
                self._verified[i] = True
            else:
                bad += 1
        return bad

    def raw(self, start: int, length: int) -> memoryview:
        """Zero-copy raw file bytes ``[start, start+length)`` — the ranged
        read a ``PeerShardServer`` serves to other ranks (unverified here;
        the consuming rank's reader applies the per-sample crc)."""
        if start < 0 or length < 0 or start + length > len(self._mm):
            raise ValueError(
                f"{self.path}: range {start}+{length} outside {len(self._mm)}-byte shard"
            )
        return self._buf[start : start + length]

    def close(self) -> None:
        """Release the mapping.  Best-effort: if sample views are still
        alive the pages stay mapped until they are dropped (the OS, not us,
        owns reclamation) — never a dangling pointer, at worst a deferred
        unmap."""
        if getattr(self, "_buf", None) is not None:
            self._buf.release()
            self._buf = None
        if getattr(self, "_mm", None) is not None:
            try:
                self._mm.close()
            except BufferError:  # exported sample views keep the mapping alive
                pass
            self._mm = None
        if getattr(self, "_file", None) is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "ShardReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Packed shard file format: header + payload + per-sample index.

One shard file holds many encoded samples (codec.py ``RPR1`` blobs, but the
format is payload-agnostic) packed back to back, so a million-sample dataset
becomes a few hundred large files instead of a million tiny ones — one
``mmap`` per shard replaces an ``open()+read()+close()`` syscall triple per
sample, and reads become pointer arithmetic into the page cache.

Two layouts share the magic and the 32-byte header; the header's
``version`` field dispatches between them (``open_shard_reader``).

Format v1 — one opaque blob per sample
--------------------------------------
On-disk layout (little-endian throughout)::

    [ header | payload region | index region ]

    header (32 bytes, fixed):
        magic         8s   b"RPRSHRD1"
        version       u32  1
        n_samples     u32
        index_offset  u64  file offset of the index region
        payload_off   u64  file offset of the payload region (= 32)

    payload: sample blobs packed back to back

    index (n_samples x 16 bytes, written AFTER the payload so the writer
    streams samples without knowing sizes up front):
        offset        u64  absolute file offset of the sample
        length        u32  sample byte length
        crc32         u32  zlib.crc32 of the sample bytes

Format v2 — columnar fields with projection
-------------------------------------------
A sample is a dict of named **fields**; each field's values are stored
contiguously as a **column region**, so a reader that wants only
``{image}`` touches only the image column's byte range — the layout that
makes projection pushdown a ranged read, not a parse-and-discard::

    [ header | column 0 | column 1 | ... | index region ]

    header (32 bytes): as v1, but version = 2; payload_off = 32 and
        index_offset marks the end of the last column.

    column c: field c's per-sample blobs packed back to back, in schema
        order.  A column whose blobs all share one length is a **fixed**
        (vectorized-chunk) column: sample i lives at
        ``col_off + i * item_size`` — no per-sample index lookups, and a
        run of samples is one contiguous slice (``read_field_chunk``).

    index region (starts at index_offset, extends to end of file):
        preamble (16 bytes):
            index_len u64   total index-region bytes (incl. this preamble)
            n_fields  u32
            reserved  u32   0
        field table (n_fields variable-size entries):
            name_len  u8    UTF-8 byte length of the field name
            kind      u8    0 = variable-width, 1 = fixed-width
            item_size u32   fixed: bytes per sample; variable: 0
            col_off   u64   absolute file offset of the column region
            col_len   u64   column region byte length
            arr_off   u64   absolute file offset of the per-sample arrays
            name      ...   UTF-8 field name bytes
        per-sample arrays (one block per column, at its arr_off):
            variable column: n_samples x (off u64, len u32, crc32 u32)
                             — offsets absolute, confined to the column
            fixed column:    n_samples x (crc32 u32)

Parsers reject overlapping or out-of-extent column regions, truncated
index regions, and duplicate/empty field names (``ShardCorruption``) —
the index is remote-controlled data on the prefetch path.

CRC policy (both versions): the crc is computed over the *encoded* bytes
at write time — per sample in v1, per (field, sample) cell in v2 — and
verified on first read by default; a mismatch raises ``ShardCorruption``
for that sample (v1) or that field of that sample (v2) only, so a flipped
bit surfaces as a per-sample hole in the pipeline rather than a dead
shard.  Verification is memoized (a bitset per column): the bytes behind a
shard file never change, so epoch 2+ over a warm cache skips the crc pass
it already paid — a failed check is never memoized, so a corrupt cell
stays a hole on every read.  ``verify_all()`` coalesces the whole check
into one sequential pass that fills the bitsets up front — the shard cache
runs it at install time (on the fetch thread) and
``ShardDataset(verify_crc="eager")`` at mmap-open, taking the ~2x per-read
crc cost off the hot path while keeping the per-sample-hole contract.
Callers doing their own integrity checking pass ``verify=False`` and the
read is pure pointer math.

Versioning: the header magic pins the major layout; ``version`` selects
the minor revision.  Readers reject a magic they don't know and a version
newer than ``MAX_FORMAT_VERSION`` (forward-incompatible), and must keep
reading every older version they ever shipped.  ``ShardReader`` is the v1
reader and fails loudly on a v2 version byte (and vice versa for
``ShardReaderV2``); ``open_shard_reader(path)`` peeks the header and
dispatches, which is how every pre-v2 call site keeps reading v1 shards
byte-identically with zero changes.

Reads return ``memoryview`` slices of the shard's mmap — zero payload
copies; the view stays valid for the life of the mapping (the reader keeps
it alive, and on Linux even an unlinked file's mapping stays readable,
which is what lets the local shard cache evict files with reads still in
flight).
"""

from __future__ import annotations

import mmap
import os
import pathlib
import struct
import zlib

import numpy as np

MAGIC = b"RPRSHRD1"
FORMAT_VERSION = 1  # the one-blob-per-sample layout ShardWriter/ShardReader speak
FORMAT_VERSION_V2 = 2  # the columnar layout (ShardWriterV2/ShardReaderV2)
MAX_FORMAT_VERSION = FORMAT_VERSION_V2
_HEADER = struct.Struct("<8sIIQQ")
HEADER_SIZE = _HEADER.size  # 32
_ENTRY = struct.Struct("<QII")
ENTRY_SIZE = _ENTRY.size  # 16
_INDEX_DTYPE = np.dtype([("off", "<u8"), ("len", "<u4"), ("crc", "<u4")])
_CRC_DTYPE = np.dtype("<u4")
# v2 index region: preamble + per-field table entries (name bytes follow)
_INDEX_PREAMBLE = struct.Struct("<QII")  # index_len, n_fields, reserved
INDEX_PREAMBLE_SIZE = _INDEX_PREAMBLE.size  # 16
_FIELD_HEAD = struct.Struct("<BBIQQQ")  # name_len, kind, item_size, col_off, col_len, arr_off
_FIELD_HEAD_SIZE = _FIELD_HEAD.size  # 30
_KIND_VAR, _KIND_FIXED = 0, 1


class ShardCorruption(ValueError):
    """A shard (or one sample inside it) failed an integrity check."""


def parse_shard_header(header: bytes, name: str = "shard") -> tuple[int, int, int, int]:
    """Validate a 32-byte header blob; returns
    ``(version, n_samples, index_offset, payload_offset)``.

    This is the first step of index-first fetch: a 32-byte ranged read
    through here tells a remote reader which format version it is dealing
    with and where the index region lives (and rejects unfinalized /
    foreign files) before any payload moves."""
    if len(header) < HEADER_SIZE:
        raise ShardCorruption(
            f"{name}: header blob is {len(header)} bytes, need {HEADER_SIZE}"
        )
    magic, version, n, index_off, payload_off = _HEADER.unpack_from(header, 0)
    if magic != MAGIC:
        raise ShardCorruption(
            f"{name}: bad magic {bytes(magic)!r} (unfinalized or foreign file)"
        )
    if version > MAX_FORMAT_VERSION:
        raise ShardCorruption(
            f"{name}: shard version {version} is newer than reader {MAX_FORMAT_VERSION}"
        )
    return version, n, index_off, payload_off


def parse_index_preamble(blob: bytes, name: str = "shard") -> tuple[int, int]:
    """Validate the 16-byte v2 index preamble; returns
    ``(index_len, n_fields)``.  A remote reader fetches this after the
    header to learn how many more index bytes to pull."""
    if len(blob) < INDEX_PREAMBLE_SIZE:
        raise ShardCorruption(
            f"{name}: truncated column index: preamble is {len(blob)} bytes, "
            f"need {INDEX_PREAMBLE_SIZE}"
        )
    index_len, n_fields, _reserved = _INDEX_PREAMBLE.unpack_from(blob, 0)
    if index_len < INDEX_PREAMBLE_SIZE:
        raise ShardCorruption(
            f"{name}: corrupt column index: index_len {index_len} below preamble size"
        )
    return index_len, n_fields


class ShardIndex:
    """A v1 shard's parsed header + index, held without its payload.

    This is what **index-first fetch** downloads: the fixed 32-byte header
    (which says where the index lives) and the 16-byte-per-sample index
    region — enough to know every sample's offset, length, and crc32, and
    therefore to fetch any subset of the payload with ranged reads instead
    of committing to the whole shard.
    """

    __slots__ = ("n_samples", "payload_off", "index_off", "offsets", "lengths", "crcs")

    def __init__(self, n_samples, payload_off, index_off, offsets, lengths, crcs):
        self.n_samples = n_samples
        self.payload_off = payload_off
        self.index_off = index_off
        self.offsets = offsets
        self.lengths = lengths
        self.crcs = crcs

    @property
    def total_bytes(self) -> int:
        """Size of the full shard file (header + payload + index)."""
        return self.index_off + self.n_samples * ENTRY_SIZE

    @property
    def payload_bytes(self) -> int:
        return self.index_off - self.payload_off

    @property
    def index_nbytes(self) -> int:
        """Bytes a reader must download to learn the index (header + index)."""
        return HEADER_SIZE + self.n_samples * ENTRY_SIZE

    def header_bytes(self) -> bytes:
        """Re-serialize the 32-byte header.  A sparse cache entry holds only
        the *parsed* index, so this is how a ``PeerShardServer`` answers a
        peer's header ranged read without keeping the original blob."""
        return _HEADER.pack(
            MAGIC, FORMAT_VERSION, self.n_samples, self.index_off, self.payload_off
        )

    def index_bytes(self) -> bytes:
        """Re-serialize the index region (16 B/sample) — the peer-serving
        twin of ``header_bytes``."""
        arr = np.empty(self.n_samples, dtype=_INDEX_DTYPE)
        arr["off"] = self.offsets
        arr["len"] = self.lengths
        arr["crc"] = self.crcs
        return arr.tobytes()

    @classmethod
    def parse(cls, header: bytes, index: bytes, name: str = "shard") -> "ShardIndex":
        """Validate + parse a header blob and its index-region blob.

        Applies the same checks as ``ShardReader.__init__`` (magic, version,
        extents) so a remote shard with a zero placeholder header — a
        crashed writer — is rejected here, before any payload is fetched.
        """
        version, n, index_off, payload_off = parse_shard_header(header, name)
        if version != FORMAT_VERSION:
            raise ShardCorruption(
                f"{name}: format version {version} is not v1 "
                "(columnar v2 indexes parse via ShardIndexV2)"
            )
        if payload_off > index_off:
            raise ShardCorruption(f"{name}: payload region starts past the index")
        if len(index) != n * ENTRY_SIZE:
            raise ShardCorruption(
                f"{name}: index region is {len(index)} bytes, expected {n * ENTRY_SIZE}"
            )
        parsed = np.frombuffer(index, _INDEX_DTYPE, count=n)
        offsets, lengths, crcs = parsed["off"], parsed["len"], parsed["crc"]
        if n and (
            int(offsets.min(initial=payload_off)) < payload_off
            or int((offsets.astype(np.int64) + lengths).max()) > index_off
        ):
            raise ShardCorruption(
                f"{name}: corrupt index: sample extents outside the payload region"
            )
        return cls(n, payload_off, index_off, offsets, lengths, crcs)


class _Column:
    """One parsed v2 column: extent + per-sample arrays (fixed columns
    carry only crcs — offsets are pointer math off ``item_size``)."""

    __slots__ = ("name", "fixed", "item_size", "col_off", "col_len",
                 "offsets", "lengths", "crcs")

    def __init__(self, name, fixed, item_size, col_off, col_len, offsets, lengths, crcs):
        self.name = name
        self.fixed = fixed
        self.item_size = item_size
        self.col_off = col_off
        self.col_len = col_len
        self.offsets = offsets  # None for fixed columns
        self.lengths = lengths  # None for fixed columns
        self.crcs = crcs


class ShardIndexV2:
    """A v2 shard's parsed header + column index, held without its payload.

    The v2 twin of ``ShardIndex``: what index-first fetch downloads before
    deciding which *column ranges* to pull.  Knows every field's column
    extent and every (field, sample) cell's offset/length/crc32, so a
    projection (``fields=...``) turns into ranged reads confined to the
    requested columns — the non-requested columns' bytes never move.
    """

    __slots__ = ("n_samples", "payload_off", "index_off", "index_len",
                 "columns", "field_names", "_header", "_index_raw")

    def __init__(self, n_samples, payload_off, index_off, index_len,
                 columns, header_raw, index_raw):
        self.n_samples = n_samples
        self.payload_off = payload_off
        self.index_off = index_off
        self.index_len = index_len
        self.columns: dict[str, _Column] = columns
        self.field_names: tuple[str, ...] = tuple(columns)
        self._header = header_raw
        self._index_raw = index_raw

    @property
    def total_bytes(self) -> int:
        """Size of the full shard file (header + columns + index)."""
        return self.index_off + self.index_len

    @property
    def payload_bytes(self) -> int:
        return self.index_off - self.payload_off

    @property
    def index_nbytes(self) -> int:
        """Bytes a reader must download to learn the index (header + index)."""
        return HEADER_SIZE + self.index_len

    def header_bytes(self) -> bytes:
        return self._header

    def index_bytes(self) -> bytes:
        """The raw index region, byte-identical to what the writer wrote —
        a sparse cache entry answers peers' index-first ranged reads from
        this without holding any payload."""
        return self._index_raw

    def column(self, field: str) -> _Column:
        col = self.columns.get(field)
        if col is None:
            raise KeyError(
                f"unknown field {field!r} (shard has {list(self.field_names)})"
            )
        return col

    def resolve_fields(self, fields=None) -> tuple[str, ...]:
        """Normalize a projection: ``None`` means every field; unknown
        names raise ``KeyError`` (loudly — a typo'd projection must not
        silently read nothing)."""
        if fields is None:
            return self.field_names
        out = tuple(fields)
        for f in out:
            if f not in self.columns:
                raise KeyError(
                    f"unknown field {f!r} (shard has {list(self.field_names)})"
                )
        return out

    def locate(self, field: str, i: int) -> tuple[int, int, int]:
        """(absolute offset, length, crc32) of sample ``i``'s ``field`` cell."""
        col = self.column(field)
        if not 0 <= i < self.n_samples:
            raise IndexError(f"sample {i} out of range [0, {self.n_samples})")
        if col.fixed:
            return col.col_off + i * col.item_size, col.item_size, int(col.crcs[i])
        return int(col.offsets[i]), int(col.lengths[i]), int(col.crcs[i])

    def samples_nbytes(self, samples, fields=None) -> int:
        """Total payload bytes of ``samples`` restricted to ``fields`` —
        what the prefetcher's sparse-vs-full decision (and its
        ``bytes_skipped`` accounting) is computed from."""
        names = self.resolve_fields(fields)
        if not len(samples):
            return 0
        total = 0
        for f in names:
            col = self.columns[f]
            if col.fixed:
                total += col.item_size * len(samples)
            else:
                total += int(col.lengths[np.asarray(samples, dtype=np.int64)].sum())
        return total

    @classmethod
    def parse(cls, header: bytes, index: bytes, name: str = "shard") -> "ShardIndexV2":
        """Validate + parse a v2 header blob and its index-region blob.

        The index is remote-controlled data on the prefetch path, so every
        extent is checked: truncated regions, out-of-payload or
        **overlapping** column regions, arrays outside the index region,
        and cell extents outside their column all raise ``ShardCorruption``
        before any payload byte is trusted."""
        version, n, index_off, payload_off = parse_shard_header(header, name)
        if version != FORMAT_VERSION_V2:
            raise ShardCorruption(
                f"{name}: format version {version} is not v2 "
                "(one-blob v1 indexes parse via ShardIndex)"
            )
        if payload_off > index_off:
            raise ShardCorruption(f"{name}: payload region starts past the index")
        index_len, n_fields = parse_index_preamble(index, name)
        if index_len != len(index):
            raise ShardCorruption(
                f"{name}: truncated column index: region is {len(index)} bytes, "
                f"preamble claims {index_len}"
            )
        columns: dict[str, _Column] = {}
        pos = INDEX_PREAMBLE_SIZE
        for _ in range(n_fields):
            if pos + _FIELD_HEAD_SIZE > index_len:
                raise ShardCorruption(f"{name}: truncated column index: field table")
            name_len, kind, item_size, col_off, col_len, arr_off = (
                _FIELD_HEAD.unpack_from(index, pos)
            )
            pos += _FIELD_HEAD_SIZE
            if pos + name_len > index_len:
                raise ShardCorruption(f"{name}: truncated column index: field name")
            try:
                fname = bytes(index[pos : pos + name_len]).decode("utf-8")
            except UnicodeDecodeError as e:
                raise ShardCorruption(f"{name}: corrupt field name ({e})") from e
            pos += name_len
            if not fname or fname in columns:
                raise ShardCorruption(
                    f"{name}: corrupt column index: empty or duplicate field "
                    f"name {fname!r}"
                )
            if kind not in (_KIND_VAR, _KIND_FIXED):
                raise ShardCorruption(
                    f"{name}: field {fname!r} has unknown column kind {kind}"
                )
            if col_off < payload_off or col_off + col_len > index_off:
                raise ShardCorruption(
                    f"{name}: field {fname!r} column region outside the payload"
                )
            fixed = kind == _KIND_FIXED
            arr_nbytes = n * (_CRC_DTYPE.itemsize if fixed else ENTRY_SIZE)
            rel = arr_off - index_off
            if rel < INDEX_PREAMBLE_SIZE or rel + arr_nbytes > index_len:
                raise ShardCorruption(
                    f"{name}: field {fname!r} index arrays outside the index region"
                )
            if fixed:
                if item_size * n != col_len:
                    raise ShardCorruption(
                        f"{name}: field {fname!r}: fixed column length {col_len} "
                        f"!= {n} x item_size {item_size}"
                    )
                crcs = np.frombuffer(index, _CRC_DTYPE, count=n, offset=rel)
                offsets = lengths = None
            else:
                arr = np.frombuffer(index, _INDEX_DTYPE, count=n, offset=rel)
                offsets, lengths, crcs = arr["off"], arr["len"], arr["crc"]
                if n and (
                    int(offsets.min(initial=col_off)) < col_off
                    or int((offsets.astype(np.int64) + lengths).max())
                    > col_off + col_len
                ):
                    raise ShardCorruption(
                        f"{name}: field {fname!r}: cell extents outside the column"
                    )
            columns[fname] = _Column(
                fname, fixed, item_size, col_off, col_len, offsets, lengths, crcs
            )
        # column regions must not overlap: a cell of one field aliasing
        # another field's bytes would let one flipped region corrupt two
        # columns while each column's crcs still "verify"
        spans = sorted((c.col_off, c.col_len, c.name) for c in columns.values())
        for (a_off, a_len, a_name), (b_off, _b_len, b_name) in zip(spans, spans[1:]):
            if a_off + a_len > b_off:
                raise ShardCorruption(
                    f"{name}: overlapping column regions ({a_name!r} and {b_name!r})"
                )
        return cls(
            n, payload_off, index_off, index_len, columns,
            bytes(header[:HEADER_SIZE]), bytes(index),
        )


class ShardWriter:
    """Streams samples into one v1 shard file; finalizes index + header on
    close.

    Usage::

        with ShardWriter(path) as w:
            for blob in blobs:
                w.add(blob)

    ``add`` returns the sample's position within the shard.  The file is not
    a valid shard until ``close()`` (the header is a zero placeholder while
    streaming), so a crashed writer leaves an obviously-invalid file rather
    than a silently short one.  That guarantee extends to exceptions raised
    inside the ``with`` body: ``__exit__`` then calls ``abort()`` — close
    without finalizing — instead of stamping a valid-looking header over a
    partial payload.  ``close()`` fsyncs the payload + index before the
    header write that validates them, so a crash between the two can't
    leave a magic-valid file whose contents never reached the disk.
    """

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._f = open(self.path, "wb")
        self._f.write(b"\0" * HEADER_SIZE)
        self._entries: list[tuple[int, int, int]] = []
        self._closed = False

    def add(self, data) -> int:
        """Append one encoded sample; returns its index within the shard."""
        if self._closed:
            raise RuntimeError("ShardWriter already closed")
        data = memoryview(data)
        off = self._f.tell()
        self._f.write(data)
        self._entries.append((off, data.nbytes, zlib.crc32(data)))
        return len(self._entries) - 1

    @property
    def n_samples(self) -> int:
        return len(self._entries)

    @property
    def payload_bytes(self) -> int:
        return sum(ln for _, ln, _ in self._entries)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        index_off = self._f.tell()
        for entry in self._entries:
            self._f.write(_ENTRY.pack(*entry))
        # payload + index must be durable BEFORE the header makes the file
        # claim to be a valid shard — otherwise a crash between the two
        # writes leaves a magic-valid header over unsynced (possibly lost)
        # contents, defeating the zero-placeholder scheme.
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.seek(0)
        self._f.write(
            _HEADER.pack(
                MAGIC, FORMAT_VERSION, len(self._entries), index_off, HEADER_SIZE
            )
        )
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()

    def abort(self) -> None:
        """Abandon the shard: close the file WITHOUT finalizing it.

        The zero placeholder header stays, so readers reject the file —
        this is the path for an exception mid-stream (``__exit__`` takes it
        automatically).  Idempotent; a no-op after ``close()``.
        """
        if self._closed:
            return
        self._closed = True
        self._f.close()

    def __enter__(self) -> "ShardWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # an exception inside the `with` body means the stream is partial:
        # finalizing would stamp a valid header over bad data — abort instead
        if exc_type is not None:
            self.abort()
        else:
            self.close()


class ShardWriterV2:
    """Writes dict-of-fields samples into one columnar v2 shard file.

    Usage::

        with ShardWriterV2(path) as w:
            for sample in samples:          # {"image": b"...", "caption": b"..."}
                w.add(sample)

    The field set is fixed by ``fields=`` or by the first ``add`` (in dict
    order); every later sample must carry exactly the same fields.  Because
    columns are contiguous on disk but samples arrive row-wise, the writer
    buffers one shard's payload in memory and lays the columns out at
    ``close()`` — shard payloads are bounded (``pack`` rolls shards), so
    this is a per-shard, not per-dataset, cost.  A column whose blobs all
    share one length is stored **fixed** (item_size + per-sample crcs only);
    everything else gets the full per-sample (offset, length, crc) arrays.

    Crash/abort semantics match ``ShardWriter``: a zero placeholder header
    until the fsync'd close, ``abort()`` on exceptions inside ``with``.
    """

    def __init__(self, path: str | pathlib.Path, fields=None):
        self.path = pathlib.Path(path)
        self._f = open(self.path, "wb")
        self._f.write(b"\0" * HEADER_SIZE)
        self._names: tuple[str, ...] | None = (
            self._check_names(fields) if fields is not None else None
        )
        self._cols: dict[str, list[bytes]] = {}
        self._crcs: dict[str, list[int]] = {}
        self._n = 0
        self._payload = 0
        self._closed = False

    @staticmethod
    def _check_names(fields) -> tuple[str, ...]:
        names = tuple(fields)
        if not names:
            raise ValueError("a v2 shard needs at least one field")
        seen = set()
        for f in names:
            if not isinstance(f, str) or not f or len(f.encode("utf-8")) > 255:
                raise ValueError(f"bad field name {f!r} (non-empty str, <=255 UTF-8 bytes)")
            if f in seen:
                raise ValueError(f"duplicate field name {f!r}")
            seen.add(f)
        return names

    def add(self, sample: dict) -> int:
        """Append one dict-of-fields sample; returns its index."""
        if self._closed:
            raise RuntimeError("ShardWriterV2 already closed")
        if self._names is None:
            self._names = self._check_names(sample.keys())
        if set(sample.keys()) != set(self._names):
            raise ValueError(
                f"sample fields {sorted(sample)} != shard fields {sorted(self._names)}"
            )
        for name in self._names:
            blob = bytes(sample[name])
            self._cols.setdefault(name, []).append(blob)
            self._crcs.setdefault(name, []).append(zlib.crc32(blob))
            self._payload += len(blob)
        self._n += 1
        return self._n - 1

    @property
    def n_samples(self) -> int:
        return self._n

    @property
    def payload_bytes(self) -> int:
        return self._payload

    @property
    def field_names(self) -> tuple[str, ...] | None:
        return self._names

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        names = self._names or ()
        # columns: each field's blobs back to back, in schema order
        off = HEADER_SIZE
        col_meta: list[tuple[str, bool, int, int, list[int]]] = []
        for name in names:
            blobs = self._cols.get(name, [])
            lens = [len(b) for b in blobs]
            col_off = off
            for b in blobs:
                self._f.write(b)
            col_len = sum(lens)
            off += col_len
            fixed = self._n > 0 and len(set(lens)) == 1
            col_meta.append((name, fixed, col_off, col_len, lens))
        index_off = off
        # index region layout: preamble | field table | per-column arrays
        table_size = sum(_FIELD_HEAD_SIZE + len(n.encode("utf-8")) for n in names)
        arr_off = index_off + INDEX_PREAMBLE_SIZE + table_size
        table_parts: list[bytes] = []
        array_parts: list[bytes] = []
        for name, fixed, col_off, col_len, lens in col_meta:
            nb = name.encode("utf-8")
            if fixed:
                item_size = lens[0] if lens else 0
                arr = np.asarray(self._crcs.get(name, []), dtype=_CRC_DTYPE).tobytes()
            else:
                item_size = 0
                rec = np.empty(self._n, dtype=_INDEX_DTYPE)
                rec["off"] = col_off + np.concatenate(
                    ([0], np.cumsum(lens[:-1], dtype=np.int64))
                ) if lens else 0
                rec["len"] = lens
                rec["crc"] = self._crcs.get(name, [])
                arr = rec.tobytes()
            table_parts.append(
                _FIELD_HEAD.pack(
                    len(nb),
                    _KIND_FIXED if fixed else _KIND_VAR,
                    item_size,
                    col_off,
                    col_len,
                    arr_off,
                )
                + nb
            )
            array_parts.append(arr)
            arr_off += len(arr)
        index_len = (
            INDEX_PREAMBLE_SIZE
            + table_size
            + sum(len(a) for a in array_parts)
        )
        self._f.write(_INDEX_PREAMBLE.pack(index_len, len(names), 0))
        for part in table_parts:
            self._f.write(part)
        for part in array_parts:
            self._f.write(part)
        # same durability order as v1: columns + index durable BEFORE the
        # header write that makes the file claim to be a valid shard
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.seek(0)
        self._f.write(
            _HEADER.pack(MAGIC, FORMAT_VERSION_V2, self._n, index_off, HEADER_SIZE)
        )
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        self._cols = {}
        self._crcs = {}

    def abort(self) -> None:
        """Abandon the shard (zero placeholder header stays — see
        ``ShardWriter.abort``).  Idempotent; a no-op after ``close()``."""
        if self._closed:
            return
        self._closed = True
        self._f.close()
        self._cols = {}
        self._crcs = {}

    def __enter__(self) -> "ShardWriterV2":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.close()


class MappedShardReader:
    """Shared mmap plumbing for the full (on-disk) shard readers.

    ``isinstance(reader, MappedShardReader)`` is the "full shard resident
    on disk" test the cache and peer server dispatch on — true for both
    format versions, false for ``SparseShardReader`` entries."""

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._file = open(self.path, "rb")
        try:
            self._mm = mmap.mmap(self._file.fileno(), 0, access=mmap.ACCESS_READ)
        except ValueError as e:  # empty file
            self._file.close()
            raise ShardCorruption(f"{self.path}: not a shard file ({e})") from e
        self._buf = memoryview(self._mm)
        if len(self._mm) < HEADER_SIZE:
            self._fail(f"file is {len(self._mm)} bytes, header needs {HEADER_SIZE}")

    def _fail(self, msg: str) -> None:
        path = self.path
        self.close()
        raise ShardCorruption(f"{path}: {msg}")

    def __len__(self) -> int:
        return self.n_samples

    @property
    def nbytes(self) -> int:
        return len(self._mm)

    def raw(self, start: int, length: int) -> memoryview:
        """Zero-copy raw file bytes ``[start, start+length)`` — the ranged
        read a ``PeerShardServer`` serves to other ranks (unverified here;
        the consuming rank's reader applies the per-sample crc)."""
        if start < 0 or length < 0 or start + length > len(self._mm):
            raise ValueError(
                f"{self.path}: range {start}+{length} outside {len(self._mm)}-byte shard"
            )
        return self._buf[start : start + length]

    def close(self) -> None:
        """Release the mapping.  Best-effort: if sample views are still
        alive the pages stay mapped until they are dropped (the OS, not us,
        owns reclamation) — never a dangling pointer, at worst a deferred
        unmap."""
        if getattr(self, "_buf", None) is not None:
            self._buf.release()
            self._buf = None
        if getattr(self, "_mm", None) is not None:
            try:
                self._mm.close()
            except BufferError:  # exported sample views keep the mapping alive
                pass
            self._mm = None
        if getattr(self, "_file", None) is not None:
            self._file.close()
            self._file = None

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ShardReader(MappedShardReader):
    """mmap-backed random access into one **v1** shard file.

    ``read(i)`` returns a zero-copy ``memoryview`` of the sample bytes and
    (by default) verifies the per-sample crc32.  The whole index is parsed
    once into numpy arrays at open, so per-read work is two array loads, one
    slice, and (optionally) the crc pass.

    This is the v1 path: a columnar v2 shard is rejected loudly on the
    header's version byte (use ``ShardReaderV2``, or ``open_shard_reader``
    to dispatch automatically).
    """

    def __init__(self, path: str | pathlib.Path):
        super().__init__(path)
        size = len(self._mm)
        magic, version, n, index_off, payload_off = _HEADER.unpack_from(self._buf, 0)
        if magic != MAGIC:
            self._fail(f"bad magic {bytes(magic)!r} (unfinalized or foreign file)")
        if version > MAX_FORMAT_VERSION:
            self._fail(
                f"shard version {version} is newer than reader {MAX_FORMAT_VERSION}"
            )
        if version != FORMAT_VERSION:
            self._fail(
                f"format version {version} is not a v1 shard — columnar v2 "
                "shards need ShardReaderV2 (open_shard_reader dispatches on "
                "the version byte)"
            )
        if index_off + n * ENTRY_SIZE > size or payload_off > index_off:
            self._fail("truncated shard: index region extends past end of file")
        self.n_samples = n
        self._verified = np.zeros(n, dtype=bool)  # per-sample crc memo
        index = np.frombuffer(self._buf, _INDEX_DTYPE, count=n, offset=index_off)
        self.offsets = index["off"]
        self.lengths = index["len"]
        self.crcs = index["crc"]
        if n and (
            int(self.offsets.min(initial=payload_off)) < payload_off
            or int((self.offsets.astype(np.int64) + self.lengths).max()) > index_off
        ):
            self._fail("corrupt index: sample extents outside the payload region")

    def read(self, i: int, *, verify: bool = True) -> memoryview:
        """Zero-copy bytes of sample ``i`` (a slice of the shard's mmap)."""
        if not 0 <= i < self.n_samples:
            raise IndexError(f"sample {i} out of range [0, {self.n_samples})")
        off, ln = int(self.offsets[i]), int(self.lengths[i])
        view = self._buf[off : off + ln]
        # crc memo: the mapping is immutable, so one successful verification
        # covers every later read of the same sample (epoch 2+ of a warm
        # cache is pure pointer math).  A mismatch is never memoized — a
        # corrupt sample raises on every read, keeping the per-sample-hole
        # semantics.  Racing first reads both verify; both set the bit.
        if verify and not self._verified[i]:
            if zlib.crc32(view) != int(self.crcs[i]):
                raise ShardCorruption(f"{self.path}: sample {i} failed crc32 check")
            self._verified[i] = True
        return view

    def verify_all(self) -> int:
        """Verify every sample's crc32 in ONE sequential pass over the
        payload, memoizing each success into the per-sample bitset.

        This is the cache-install fast path: a freshly downloaded shard is
        checked once, in the fetching thread (off the hot read loop), and
        every subsequent ``read`` is pure pointer math.  The per-sample
        failure contract is preserved exactly: a corrupt sample's bit stays
        unset (it is never memoized), so reading it still raises
        ``ShardCorruption`` for that sample only.  Returns the number of
        corrupt samples found.
        """
        bad = 0
        for i in range(self.n_samples):
            if self._verified[i]:
                continue
            off, ln = int(self.offsets[i]), int(self.lengths[i])
            if zlib.crc32(self._buf[off : off + ln]) == int(self.crcs[i]):
                self._verified[i] = True
            else:
                bad += 1
        return bad


class ShardReaderV2(MappedShardReader):
    """mmap-backed random access into one **columnar v2** shard file.

    ``read_fields(i, fields=...)`` returns a dict of zero-copy
    ``memoryview`` slices — one per requested field, each verified against
    its own crc32 (memoized per (field, sample) cell, failures never
    memoized, so corruption stays a per-sample hole in exactly one field).
    Fixed-width columns additionally support ``read_field_chunk`` — one
    contiguous slice covering a run of samples, no per-sample work.
    """

    def __init__(self, path: str | pathlib.Path):
        super().__init__(path)
        size = len(self._mm)
        try:
            version, _n, index_off, _payload_off = parse_shard_header(
                bytes(self._buf[:HEADER_SIZE]), str(self.path)
            )
        except ShardCorruption as e:
            self._fail(str(e).split(": ", 1)[-1])
        if version != FORMAT_VERSION_V2:
            self._fail(
                f"format version {version} is not a v2 shard — one-blob v1 "
                "shards need ShardReader (open_shard_reader dispatches on "
                "the version byte)"
            )
        if index_off + INDEX_PREAMBLE_SIZE > size:
            self._fail("truncated column index: preamble extends past end of file")
        index_len, _n_fields = parse_index_preamble(
            bytes(self._buf[index_off : index_off + INDEX_PREAMBLE_SIZE]),
            str(self.path),
        )
        if index_off + index_len > size:
            self._fail("truncated column index: region extends past end of file")
        try:
            self.index = ShardIndexV2.parse(
                bytes(self._buf[:HEADER_SIZE]),
                bytes(self._buf[index_off : index_off + index_len]),
                str(self.path),
            )
        except ShardCorruption as e:
            self._fail(str(e).split(": ", 1)[-1])
        self.n_samples = self.index.n_samples
        self.field_names = self.index.field_names
        # per-(field, sample) crc memo — one bitset per column
        self._verified = {
            f: np.zeros(self.n_samples, dtype=bool) for f in self.field_names
        }

    def read_field(self, i: int, field: str, *, verify: bool = True) -> memoryview:
        """Zero-copy bytes of sample ``i``'s ``field`` cell."""
        off, ln, crc = self.index.locate(field, i)
        view = self._buf[off : off + ln]
        if verify and not self._verified[field][i]:
            if zlib.crc32(view) != crc:
                raise ShardCorruption(
                    f"{self.path}: sample {i} field {field!r} failed crc32 check"
                )
            self._verified[field][i] = True
        return view

    def read_fields(
        self, i: int, fields=None, *, verify: bool = True
    ) -> dict[str, memoryview]:
        """Projected read: ``{field: zero-copy memoryview}`` for the
        requested fields (all of them when ``fields`` is None).  Unknown
        field names raise ``KeyError``."""
        return {
            f: self.read_field(i, f, verify=verify)
            for f in self.index.resolve_fields(fields)
        }

    def read_field_chunk(
        self, field: str, start: int, count: int, *, verify: bool = True
    ) -> memoryview:
        """One contiguous slice covering samples ``[start, start+count)``
        of a **fixed-width** column — the vectorized-chunk read: no
        per-sample offsets, one memoryview, reshapeable by the caller.
        Each covered cell's crc is still checked (memoized), so corruption
        stays a per-sample hole: the bad sample index is named."""
        col = self.index.column(field)
        if not col.fixed:
            raise TypeError(
                f"field {field!r} is variable-width; chunk reads need a "
                "fixed (vectorized) column"
            )
        if start < 0 or count < 0 or start + count > self.n_samples:
            raise IndexError(
                f"chunk [{start}, {start + count}) outside [0, {self.n_samples})"
            )
        if verify:
            bits = self._verified[field]
            sz = col.item_size
            for i in range(start, start + count):
                if bits[i]:
                    continue
                off = col.col_off + i * sz
                if zlib.crc32(self._buf[off : off + sz]) != int(col.crcs[i]):
                    raise ShardCorruption(
                        f"{self.path}: sample {i} field {field!r} failed crc32 check"
                    )
                bits[i] = True
        a = col.col_off + start * col.item_size
        return self._buf[a : a + count * col.item_size]

    def verify_all(self) -> int:
        """One sequential crc pass over every column (the cache-install
        fast path; see ``ShardReader.verify_all``).  Corrupt cells are
        never memoized.  Returns the number of corrupt cells found."""
        bad = 0
        for f in self.field_names:
            col = self.index.column(f)
            bits = self._verified[f]
            for i in range(self.n_samples):
                if bits[i]:
                    continue
                off, ln, crc = self.index.locate(f, i)
                if zlib.crc32(self._buf[off : off + ln]) == crc:
                    bits[i] = True
                else:
                    bad += 1
        return bad


def open_shard_reader(path: str | pathlib.Path) -> ShardReader | ShardReaderV2:
    """Open a shard file, dispatching on the header's format-version byte:
    v1 → ``ShardReader``, v2 → ``ShardReaderV2``.  This is what the
    dataset and the shard cache call, so v1 shards written before the
    columnar format keep reading byte-identically with zero call-site
    changes."""
    path = pathlib.Path(path)
    with open(path, "rb") as f:
        head = f.read(HEADER_SIZE)
    version, _n, _index_off, _payload_off = parse_shard_header(head, str(path))
    if version >= FORMAT_VERSION_V2:
        return ShardReaderV2(path)
    return ShardReader(path)

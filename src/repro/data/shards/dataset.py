"""Multi-shard dataset + the ``pack`` migration tool.

A sharded dataset on disk is a directory::

    dataset/
      manifest.json          {"version": 1, "total": N, "shards": [...]}
      shard-00000.rpshard
      shard-00001.rpshard
      ...

Each manifest entry records ``{"name", "n", "bytes"}``; global sample ``i``
lives in the shard whose cumulative-count bucket contains ``i``.

``ShardDataset`` implements the repo-wide dataset protocol
(``read_bytes``/``__getitem__``/``__len__``) so every existing loader and
baseline accepts it unchanged (local mode pickles for the multiprocessing
baselines by reopening its mmaps per process; remote mode refuses to
pickle — construct the prefetcher inside the worker instead) — with the
difference that ``read_bytes``
returns a zero-copy ``memoryview`` of the shard's mmap (the codec consumes
any buffer, and the zero-copy loader path decompresses it straight into a
slab slot: mmap → decode_into → arena, no intermediate copies).

Two access modes:

* local (default): shards are files under ``root``, mmap'd lazily on first
  touch and kept open;
* remote: pass a ``ShardPrefetcher`` (``prefetch.py``) and shards are
  fetched through its bounded local cache — ``read_bytes`` blocks only on a
  cache miss, and loaders overlap upcoming fetches with decode via
  ``prefetcher.schedule``.  Passing an ``http(s)://`` URL as ``root`` is
  shorthand for the standard remote stack: ``HttpShardSource`` (range
  reads, connection reuse) wrapped in ``RetryingSource`` (backoff +
  jitter) behind a ``ShardPrefetcher`` at ``cache_dir``.  Adding
  ``peers=[url, ...]`` (other ranks' ``PeerShardServer`` addresses) slots
  a ``peer.TieredSource`` between retry and cache, so a local miss tries
  the peers' warm caches before the origin — the full stack is
  origin → retry → peers → prefetcher.

Shard names from the manifest are validated (``validate_shard_name``) to a
single bare path component before any cache path is built from them — the
manifest is remote-controlled data in remote mode.

Columnar shards (format v2, see ``format.py``) add **projection**: the
manifest carries ``"format_version": 2`` and a ``"fields"`` schema, a
sample is a dict of named fields, and ``ShardDataset(fields=("image",))``
narrows every layer below — ``read_fields`` returns only the requested
columns, and in remote mode the projection rides the prefetch hints so
sparse fetches pull only the requested columns' byte ranges off the wire.
``read_bytes`` (the one-blob protocol every loader speaks) keeps working
on a v2 dataset whenever exactly one field is in play — the sole schema
field, or a single-field projection — so single-field columnar datasets
drop into existing loaders unchanged; a multi-field dataset with no
projection fails loudly rather than guessing which column you meant.

``pack(dataset, out_dir)`` converts anything with ``read_bytes``/``len`` —
an ``ArrayDataset`` directory in particular — into this layout;
``pack(..., format_version=2, fields=("image",))`` migrates to columnar
shards (sources exposing ``read_fields`` keep all their fields).
"""

from __future__ import annotations

import json
import pathlib
import threading
from typing import Any

import numpy as np

from ..codec import decode_sample, parse_header
from .format import ShardWriter, ShardWriterV2, open_shard_reader

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1


def validate_shard_name(name: str) -> str:
    """Reject shard names that are not a bare, single path component.

    Manifest contents are *remote-controlled data* in remote mode, and the
    prefetcher joins shard names onto a local cache directory — a hostile
    or corrupted manifest containing ``../`` (or an absolute path, or a
    name that hides inside a subdirectory) must never escape it.  Applied
    at manifest parse AND at every cache entry point (defense in depth).
    """
    if (
        not isinstance(name, str)
        or not name
        or name != name.strip()
        or name in (".", "..")
        or any(c in name for c in ("/", "\\", "\0"))
        or name.startswith("~")
    ):
        raise ValueError(
            f"unsafe shard name {name!r}: must be a bare file name "
            "(single path component, no separators)"
        )
    return name


def _is_url(root) -> bool:
    return isinstance(root, str) and root.startswith(("http://", "https://"))


def write_manifest(
    root: pathlib.Path, shards: list[dict], extra: dict | None = None
) -> dict:
    manifest = {
        "version": MANIFEST_VERSION,
        "total": sum(s["n"] for s in shards),
        "shards": shards,
        **(extra or {}),
    }
    (root / MANIFEST_NAME).write_text(json.dumps(manifest, indent=2) + "\n")
    return manifest


class ShardDataset:
    """Map-style dataset over a packed-shard manifest (zero-copy reads).

    ``verify_crc`` controls where integrity checking runs:

    * ``True`` (default): lazily, per sample, on first read — memoized, so
      epoch 2+ is pure pointer math.  The right default for local shards
      whose bytes never crossed a wire.
    * ``"eager"``: one coalesced whole-payload pass per shard when the
      shard is first opened, on the opening thread (a loader's executor
      worker, never the event loop).  Every read afterwards is crc-free
      pointer math — this takes the ~2x per-read crc cost out of the cold
      hot path entirely (the engine bench's chunked-loader row).  Corrupt
      samples are never memoized, so they still raise per sample.
    * ``False``: no verification (caller does its own integrity checking).

    Prefetcher-backed (remote) datasets get eager semantics for free: the
    prefetcher verifies each shard once at cache-install time, on the
    fetching thread (see ``ShardPrefetcher._persist``).
    """

    def __init__(
        self,
        root: str | pathlib.Path,
        *,
        fields: tuple[str, ...] | list[str] | None = None,
        prefetcher: Any | None = None,
        verify_crc: bool | str = True,
        cache_dir: str | pathlib.Path | None = None,
        cache_bytes: int = 1 << 30,
        http_timeout: float = 30.0,
        retries: int = 4,
        peers: list[str] | None = None,
        peer_timeout: float = 2.0,
        fleet: str | None = None,
        persist_cache: bool = False,
    ):
        self._auto_cache_dir: pathlib.Path | None = None
        self._fleet_member = None
        owns_prefetcher = False
        if fleet and peers:
            raise TypeError(
                "fleet= discovers peers from the registry; don't also pass "
                "a static peers= list"
            )
        if (peers or fleet) and prefetcher is not None:
            raise TypeError(
                "peers= belongs to the URL-mode stack; with your own "
                "prefetcher, wrap its source in a peer.TieredSource instead"
            )
        if (peers or fleet) and not _is_url(root):
            raise TypeError("peers= needs an http(s):// root (no origin to tier)")
        if persist_cache and cache_dir is None:
            raise TypeError(
                "persist_cache= needs an explicit cache_dir= (an auto temp "
                "cache is deleted on close, so there is nothing to resume)"
            )
        if prefetcher is None and _is_url(root):
            # remote mode from a bare URL: build the standard source stack —
            # origin HTTP range reads → retry/backoff → (optional) warm-peer
            # tier → the prefetcher's local cache
            # (imports are local: prefetch.py imports this module)
            import tempfile

            from .prefetch import ShardPrefetcher
            from .sources import HttpShardSource, RetryingSource

            if cache_dir is None:
                cache_dir = tempfile.mkdtemp(prefix="repro-shard-cache-")
                self._auto_cache_dir = pathlib.Path(cache_dir)
            source = RetryingSource(
                HttpShardSource(root, timeout=http_timeout),
                max_retries=retries,
            )
            if peers:
                from .peer import PeerShardSource, TieredSource

                source = TieredSource(
                    source, PeerShardSource(peers, timeout=peer_timeout)
                )
            elif fleet:
                # elastic peer tier: membership comes from the registry and
                # shards route by consistent hash, so ranks can join/leave
                # mid-epoch without a config change
                from .membership import FleetMember
                from .peer import PeerShardSource, TieredSource

                ps = PeerShardSource(
                    [], timeout=peer_timeout, placement="ring"
                )
                source = TieredSource(source, ps)
                self._fleet_member = FleetMember(fleet, peers=ps)
            prefetcher = ShardPrefetcher(
                source,
                cache_dir,
                max_bytes=cache_bytes,
                verify_on_install=bool(verify_crc),
                persist_state=persist_cache,
            )
            owns_prefetcher = True
            if self._fleet_member is not None:
                self._fleet_member.start()
        self.root = root if _is_url(root) else pathlib.Path(root)
        self.prefetcher = prefetcher
        self.verify_crc = verify_crc
        try:
            if prefetcher is not None:
                manifest = json.loads(prefetcher.fetch_manifest())
            else:
                manifest_path = self.root / MANIFEST_NAME
                if not manifest_path.is_file():
                    raise FileNotFoundError(
                        f"no shard manifest at {manifest_path} — run "
                        "repro.data.shards.pack() (or python -m repro.data.shards) first"
                    )
                manifest = json.loads(manifest_path.read_text())
            if manifest.get("version", 0) > MANIFEST_VERSION:
                raise ValueError(
                    f"manifest version {manifest['version']} is newer than this reader"
                )
            self.manifest = manifest
            self.shard_names: list[str] = [
                validate_shard_name(s["name"]) for s in manifest["shards"]
            ]
            self.format_version = int(manifest.get("format_version", 1))
            schema = manifest.get("fields")
            self.schema_fields: tuple[str, ...] | None = (
                tuple(schema) if schema else None
            )
            self.fields: tuple[str, ...] | None = None
            if fields is not None:
                names = tuple(fields)
                if not names:
                    raise ValueError("fields= must name at least one field")
                if self.schema_fields is None:
                    raise TypeError(
                        "fields= projection needs a columnar (format v2) "
                        "dataset; this manifest has no field schema — "
                        "migrate with pack(..., format_version=2)"
                    )
                unknown = [f for f in names if f not in self.schema_fields]
                if unknown:
                    raise ValueError(
                        f"unknown fields {unknown} (schema has "
                        f"{list(self.schema_fields)})"
                    )
                self.fields = names
        except BaseException:
            # a stack built here must not leak its thread pool, sockets, or
            # temp cache dir when the manifest turns out to be bad
            if owns_prefetcher:
                if self._fleet_member is not None:
                    self._fleet_member.close()
                    self._fleet_member = None
                prefetcher.close()
                self._cleanup_auto_cache()
            raise
        self.shard_sizes: list[int] = [int(s["n"]) for s in manifest["shards"]]
        self._cum = np.cumsum([0] + self.shard_sizes)
        self._n = int(self._cum[-1])
        self._readers: dict[int, Any] = {}  # local mode, lazily opened
        self._readers_lock = threading.Lock()

    def _cleanup_auto_cache(self) -> None:
        if self._auto_cache_dir is not None:
            import shutil

            shutil.rmtree(self._auto_cache_dir, ignore_errors=True)
            self._auto_cache_dir = None

    # -- topology (consumed by the shard-aware sampler / prefetch wiring) ---
    @property
    def num_shards(self) -> int:
        return len(self.shard_names)

    def shard_of(self, i: int) -> int:
        """Shard index holding global sample ``i``."""
        if not 0 <= i < self._n:
            raise IndexError(f"sample {i} out of range [0, {self._n})")
        return int(np.searchsorted(self._cum, i, side="right")) - 1

    def shard_and_offset(self, i: int) -> tuple[int, int]:
        """(shard index, shard-local sample index) of global sample ``i`` —
        the shard-local half is what index-first prefetch hints carry."""
        shard = self.shard_of(i)
        return shard, i - int(self._cum[shard])

    @property
    def sample_meta(self) -> tuple[np.dtype, tuple[int, ...]] | None:
        """(dtype, shape) of sample 0 as recorded by ``pack`` in the
        manifest, or None for manifests predating the field.  Lets loaders
        sniff the sample layout without reading (for remote datasets:
        downloading a whole shard of) actual data.  On a columnar (v2)
        manifest this resolves through the single effective field when the
        projection (or sole schema field) narrows to one — the layout the
        one-blob loader path would actually read."""
        meta = self.manifest.get("sample0")
        if not meta:
            return None
        if "fields" in meta:  # v2 per-field layout
            names = self.fields or self.schema_fields or ()
            if len(names) == 1:
                return self.field_meta(names[0])
            return None
        return np.dtype(meta["dtype"]), tuple(meta["shape"])

    def field_meta(self, field: str) -> tuple[np.dtype, tuple[int, ...]] | None:
        """(dtype, shape) of ``field`` in sample 0 as recorded by a v2
        ``pack``, or None when unrecorded / not a codec blob."""
        meta = self.manifest.get("sample0") or {}
        fm = (meta.get("fields") or {}).get(field)
        if not fm or "dtype" not in fm:
            return None
        return np.dtype(fm["dtype"]), tuple(fm["shape"])

    def _sole_field(self, reader_fields) -> str:
        """The single field a one-blob ``read_bytes`` call maps to on a
        columnar shard — the projection if it names exactly one, else the
        shard's only field; anything wider fails loudly."""
        if self.fields is not None:
            if len(self.fields) == 1:
                return self.fields[0]
            raise TypeError(
                f"read_bytes is one-blob-per-sample but the projection names "
                f"{list(self.fields)}; use read_fields(i) for multi-field reads"
            )
        names = tuple(reader_fields)
        if len(names) == 1:
            return names[0]
        raise TypeError(
            f"read_bytes on a multi-field columnar dataset (fields "
            f"{list(names)}) needs a projection: ShardDataset(fields=(name,)) "
            "or read_fields(i, fields=...)"
        )

    def _reader(self, shard: int):
        if self.prefetcher is not None:
            name = self.shard_names[shard]
            if self.fields is not None:
                # projection rides along so sparse fetches pull only the
                # requested columns' ranges
                return self.prefetcher.reader(name, fields=self.fields)
            return self.prefetcher.reader(name)
        r = self._readers.get(shard)
        if r is None:
            # Open (and eagerly verify) OUTSIDE the lock: concurrent read
            # threads opening different shards must not serialize behind one
            # whole-payload crc pass.  The install is double-checked; a
            # losing duplicate is closed (safe — no views were handed out),
            # at worst duplicating one open/verify under a race.
            candidate = open_shard_reader(self.root / self.shard_names[shard])
            if self.verify_crc == "eager":
                # coalesced verification: one whole-payload pass on the
                # opening thread, then reads skip the crc (the per-sample
                # bitset keeps corrupt samples raising)
                candidate.verify_all()
            with self._readers_lock:
                r = self._readers.setdefault(shard, candidate)
            if r is not candidate:
                candidate.close()
        return r

    # -- dataset protocol ---------------------------------------------------
    def __len__(self) -> int:
        return self._n

    def read_bytes(self, i: int) -> memoryview:
        """Zero-copy encoded bytes of sample ``i`` (mmap slice).  On a
        columnar (v2) shard this reads the single effective field — see
        ``_sole_field``."""
        shard = self.shard_of(i)
        local = i - int(self._cum[shard])
        reader = self._reader(shard)
        names = getattr(reader, "field_names", None)  # set ⇒ columnar v2
        if names is not None:
            field = self._sole_field(names)
            return reader.read_field(local, field, verify=bool(self.verify_crc))
        return reader.read(local, verify=self.verify_crc)

    def read_fields(self, i: int, fields=None) -> dict[str, memoryview]:
        """Projected read of sample ``i``: ``{field: zero-copy memoryview}``.
        ``fields=None`` means the dataset's projection (all schema fields if
        none was set).  Columnar (format v2) datasets only."""
        shard = self.shard_of(i)
        local = i - int(self._cum[shard])
        reader = self._reader(shard)
        if getattr(reader, "field_names", None) is None:
            raise TypeError(
                "read_fields needs a columnar (format v2) dataset — "
                "migrate with pack(..., format_version=2)"
            )
        if fields is None:
            fields = self.fields
        return reader.read_fields(local, fields, verify=bool(self.verify_crc))

    def read_bytes_many(self, indices) -> list[memoryview]:
        """Bulk ``read_bytes``: one vectorized index→shard resolution for
        the whole batch (one ``searchsorted`` call instead of one per
        sample) and one reader lookup per shard *run* — the shard-aware
        sampler makes runs the common case.  Built for chunked read stages
        (``pipe(read_many, chunk=N, vectorized=True)``); out-of-range
        indices raise for the whole call."""
        idx = np.asarray(indices, dtype=np.int64)
        if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= self._n):
            raise IndexError(f"sample index out of range [0, {self._n})")
        shards = np.searchsorted(self._cum, idx, side="right") - 1
        locals_ = idx - self._cum[shards]
        verify = self.verify_crc
        out: list[memoryview] = []
        reader = None
        field = None
        cur = -1
        for s, li in zip(shards.tolist(), locals_.tolist()):
            if s != cur:
                reader = self._reader(s)
                names = getattr(reader, "field_names", None)
                field = self._sole_field(names) if names is not None else None
                cur = s
            if field is not None:
                out.append(reader.read_field(li, field, verify=bool(verify)))
            else:
                out.append(reader.read(li, verify=verify))
        return out

    def __getitem__(self, i: int) -> np.ndarray:
        return decode_sample(self.read_bytes(i))

    def close(self) -> None:
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        if self._fleet_member is not None:
            self._fleet_member.close()
            self._fleet_member = None
        if self.prefetcher is not None:
            self.prefetcher.close()
        # a cache dir we mkdtemp'd is ours to remove — leaving it would
        # leak up to cache_bytes of downloaded shards per dataset
        self._cleanup_auto_cache()

    # -- pickling (multiprocessing baselines fork/spawn the dataset) --------
    def __getstate__(self) -> dict:
        if self.prefetcher is not None:
            raise TypeError(
                "a prefetcher-backed ShardDataset cannot be pickled (the "
                "prefetcher owns threads and mmaps); pickle a local-mode "
                "ShardDataset and construct the prefetcher in the worker"
            )
        state = self.__dict__.copy()
        state["_readers"] = {}  # mmaps/locks are per-process; reopen lazily
        del state["_readers_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._readers = {}
        self._readers_lock = threading.Lock()


def _sniff_meta(blob) -> dict:
    """Per-sample codec metadata for the manifest; samples that are not
    codec blobs record an empty dict."""
    try:
        dtype, shape, _ = parse_header(blob)
        return {"dtype": dtype.name, "shape": list(shape)}
    except Exception:
        return {}


def pack(
    dataset: Any,
    out_dir: str | pathlib.Path,
    *,
    samples_per_shard: int = 1024,
    max_shard_bytes: int | None = None,
    prefix: str = "shard",
    format_version: int = 1,
    fields: tuple[str, ...] | list[str] | None = None,
) -> ShardDataset:
    """Pack any ``read_bytes``/``__len__`` dataset into a sharded directory.

    A shard rolls over at ``samples_per_shard`` samples or (if given)
    ``max_shard_bytes`` of payload, whichever comes first.  Unreadable
    source samples are packed as-is only if ``read_bytes`` succeeds —
    failures propagate (migration should not silently drop data).

    ``format_version=2`` writes columnar shards: a source exposing
    ``read_fields(i)`` (another v2 ``ShardDataset``, or any dict-of-blobs
    provider) keeps all its fields (``fields=`` selects a subset); a plain
    one-blob source packs its payload into a single column named by
    ``fields=("name",)`` (default ``"data"``).  The manifest gains
    ``"format_version"``, the field schema, and per-field ``sample0``
    metadata, so a v1→v2 migration is one ``pack`` call and projection
    works end to end on the result.
    """
    if samples_per_shard < 1:
        raise ValueError("samples_per_shard must be >= 1")
    if format_version not in (1, 2):
        raise ValueError(f"format_version must be 1 or 2, got {format_version}")
    if fields is not None and format_version != 2:
        raise TypeError("fields= only applies to format_version=2 (columnar)")
    columnar = format_version == 2
    # a source provides fields if it has read_fields AND is not itself a
    # one-blob ShardDataset (v1 datasets carry the method but it raises)
    reads_fields = (
        columnar
        and callable(getattr(dataset, "read_fields", None))
        and getattr(dataset, "schema_fields", ...) is not None
    )
    field_names: tuple[str, ...] | None = tuple(fields) if fields else None
    if columnar and not reads_fields and field_names is not None and len(field_names) > 1:
        raise TypeError(
            f"source has no read_fields — its one blob per sample cannot "
            f"split into {list(field_names)}; name at most one field"
        )
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    shards: list[dict] = []
    sample0: dict | None = None
    writer: ShardWriter | ShardWriterV2 | None = None

    def roll() -> None:
        nonlocal writer
        if writer is not None and writer.n_samples:
            writer.close()
            shards.append(
                {
                    "name": writer.path.name,
                    "n": writer.n_samples,
                    "bytes": writer.path.stat().st_size,
                }
            )
        writer = None

    try:
        for i in range(len(dataset)):
            if writer is None:
                path = out_dir / f"{prefix}-{len(shards):05d}.rpshard"
                writer = (
                    ShardWriterV2(path, fields=field_names)
                    if columnar
                    else ShardWriter(path)
                )
            if reads_fields:
                sample = {
                    k: bytes(v)
                    for k, v in dataset.read_fields(i, field_names).items()
                }
                if field_names is None:
                    field_names = tuple(sample)
            else:
                data = dataset.read_bytes(i)
                if columnar:
                    if field_names is None:
                        field_names = ("data",)
                    sample = {field_names[0]: data}
            if sample0 is None:
                # record sample 0's layout so loaders can sniff dtype/shape
                # from the manifest alone (a remote dataset would otherwise
                # download a whole shard just to peek at one header)
                if columnar:
                    sample0 = {
                        "fields": {k: _sniff_meta(v) for k, v in sample.items()}
                    }
                else:
                    sample0 = _sniff_meta(data)
            writer.add(sample if columnar else data)
            if writer.n_samples >= samples_per_shard or (
                max_shard_bytes is not None and writer.payload_bytes >= max_shard_bytes
            ):
                roll()
        roll()
    except BaseException:
        # failed migration: abort (never finalize a partial shard) and
        # remove the zero-header file so a retry doesn't find a stray
        if writer is not None:
            writer.abort()
            writer.path.unlink(missing_ok=True)
        raise
    extra: dict = {}
    if columnar:
        extra["format_version"] = 2
        extra["fields"] = list(field_names or ())
    if sample0:
        extra["sample0"] = sample0
    write_manifest(out_dir, shards, extra or None)
    return ShardDataset(out_dir)

"""Multi-shard dataset + the ``pack`` migration tool.

A sharded dataset on disk is a directory::

    dataset/
      manifest.json          {"version": 1, "total": N, "shards": [...]}
      shard-00000.rpshard
      shard-00001.rpshard
      ...

Each manifest entry records ``{"name", "n", "bytes"}``; global sample ``i``
lives in the shard whose cumulative-count bucket contains ``i``.

``ShardDataset`` implements the repo-wide dataset protocol
(``read_bytes``/``__getitem__``/``__len__``) so every existing loader and
baseline accepts it unchanged (local mode pickles for the multiprocessing
baselines by reopening its mmaps per process; remote mode refuses to
pickle — construct the prefetcher inside the worker instead) — with the
difference that ``read_bytes``
returns a zero-copy ``memoryview`` of the shard's mmap (the codec consumes
any buffer, and the zero-copy loader path decompresses it straight into a
slab slot: mmap → decode_into → arena, no intermediate copies).

Two access modes:

* local (default): shards are files under ``root``, mmap'd lazily on first
  touch and kept open;
* remote: pass a ``ShardPrefetcher`` (``prefetch.py``) and shards are
  fetched through its bounded local cache — ``read_bytes`` blocks only on a
  cache miss, and loaders overlap upcoming fetches with decode via
  ``prefetcher.schedule``.  Passing an ``http(s)://`` URL as ``root`` is
  shorthand for the standard remote stack: ``HttpShardSource`` (range
  reads, connection reuse) wrapped in ``RetryingSource`` (backoff +
  jitter) behind a ``ShardPrefetcher`` at ``cache_dir``.  Adding
  ``peers=[url, ...]`` (other ranks' ``PeerShardServer`` addresses) slots
  a ``peer.TieredSource`` between retry and cache, so a local miss tries
  the peers' warm caches before the origin — the full stack is
  origin → retry → peers → prefetcher.

Shard names from the manifest are validated (``validate_shard_name``) to a
single bare path component before any cache path is built from them — the
manifest is remote-controlled data in remote mode.

``pack(dataset, out_dir)`` converts anything with ``read_bytes``/``len`` —
an ``ArrayDataset`` directory in particular — into this layout.
"""

from __future__ import annotations

import json
import pathlib
import threading
from typing import Any

import numpy as np

from ..codec import decode_sample, parse_header
from .format import ShardReader, ShardWriter

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1


def validate_shard_name(name: str) -> str:
    """Reject shard names that are not a bare, single path component.

    Manifest contents are *remote-controlled data* in remote mode, and the
    prefetcher joins shard names onto a local cache directory — a hostile
    or corrupted manifest containing ``../`` (or an absolute path, or a
    name that hides inside a subdirectory) must never escape it.  Applied
    at manifest parse AND at every cache entry point (defense in depth).
    """
    if (
        not isinstance(name, str)
        or not name
        or name != name.strip()
        or name in (".", "..")
        or any(c in name for c in ("/", "\\", "\0"))
        or name.startswith("~")
    ):
        raise ValueError(
            f"unsafe shard name {name!r}: must be a bare file name "
            "(single path component, no separators)"
        )
    return name


def _is_url(root) -> bool:
    return isinstance(root, str) and root.startswith(("http://", "https://"))


def write_manifest(
    root: pathlib.Path, shards: list[dict], extra: dict | None = None
) -> dict:
    manifest = {
        "version": MANIFEST_VERSION,
        "total": sum(s["n"] for s in shards),
        "shards": shards,
        **(extra or {}),
    }
    (root / MANIFEST_NAME).write_text(json.dumps(manifest, indent=2) + "\n")
    return manifest


class ShardDataset:
    """Map-style dataset over a packed-shard manifest (zero-copy reads).

    ``verify_crc`` controls where integrity checking runs:

    * ``True`` (default): lazily, per sample, on first read — memoized, so
      epoch 2+ is pure pointer math.  The right default for local shards
      whose bytes never crossed a wire.
    * ``"eager"``: one coalesced whole-payload pass per shard when the
      shard is first opened, on the opening thread (a loader's executor
      worker, never the event loop).  Every read afterwards is crc-free
      pointer math — this takes the ~2x per-read crc cost out of the cold
      hot path entirely (the engine bench's chunked-loader row).  Corrupt
      samples are never memoized, so they still raise per sample.
    * ``False``: no verification (caller does its own integrity checking).

    Prefetcher-backed (remote) datasets get eager semantics for free: the
    prefetcher verifies each shard once at cache-install time, on the
    fetching thread (see ``ShardPrefetcher._persist``).
    """

    def __init__(
        self,
        root: str | pathlib.Path,
        *,
        prefetcher: Any | None = None,
        verify_crc: bool | str = True,
        cache_dir: str | pathlib.Path | None = None,
        cache_bytes: int = 1 << 30,
        http_timeout: float = 30.0,
        retries: int = 4,
        peers: list[str] | None = None,
        peer_timeout: float = 2.0,
    ):
        self._auto_cache_dir: pathlib.Path | None = None
        owns_prefetcher = False
        if peers and prefetcher is not None:
            raise TypeError(
                "peers= belongs to the URL-mode stack; with your own "
                "prefetcher, wrap its source in a peer.TieredSource instead"
            )
        if peers and not _is_url(root):
            raise TypeError("peers= needs an http(s):// root (no origin to tier)")
        if prefetcher is None and _is_url(root):
            # remote mode from a bare URL: build the standard source stack —
            # origin HTTP range reads → retry/backoff → (optional) warm-peer
            # tier → the prefetcher's local cache
            # (imports are local: prefetch.py imports this module)
            import tempfile

            from .prefetch import ShardPrefetcher
            from .sources import HttpShardSource, RetryingSource

            if cache_dir is None:
                cache_dir = tempfile.mkdtemp(prefix="repro-shard-cache-")
                self._auto_cache_dir = pathlib.Path(cache_dir)
            source = RetryingSource(
                HttpShardSource(root, timeout=http_timeout),
                max_retries=retries,
            )
            if peers:
                from .peer import PeerShardSource, TieredSource

                source = TieredSource(
                    source, PeerShardSource(peers, timeout=peer_timeout)
                )
            prefetcher = ShardPrefetcher(
                source,
                cache_dir,
                max_bytes=cache_bytes,
                verify_on_install=bool(verify_crc),
            )
            owns_prefetcher = True
        self.root = root if _is_url(root) else pathlib.Path(root)
        self.prefetcher = prefetcher
        self.verify_crc = verify_crc
        try:
            if prefetcher is not None:
                manifest = json.loads(prefetcher.fetch_manifest())
            else:
                manifest_path = self.root / MANIFEST_NAME
                if not manifest_path.is_file():
                    raise FileNotFoundError(
                        f"no shard manifest at {manifest_path} — run "
                        "repro.data.shards.pack() (or python -m repro.data.shards) first"
                    )
                manifest = json.loads(manifest_path.read_text())
            if manifest.get("version", 0) > MANIFEST_VERSION:
                raise ValueError(
                    f"manifest version {manifest['version']} is newer than this reader"
                )
            self.manifest = manifest
            self.shard_names: list[str] = [
                validate_shard_name(s["name"]) for s in manifest["shards"]
            ]
        except BaseException:
            # a stack built here must not leak its thread pool, sockets, or
            # temp cache dir when the manifest turns out to be bad
            if owns_prefetcher:
                prefetcher.close()
                self._cleanup_auto_cache()
            raise
        self.shard_sizes: list[int] = [int(s["n"]) for s in manifest["shards"]]
        self._cum = np.cumsum([0] + self.shard_sizes)
        self._n = int(self._cum[-1])
        self._readers: dict[int, ShardReader] = {}  # local mode, lazily opened
        self._readers_lock = threading.Lock()

    def _cleanup_auto_cache(self) -> None:
        if self._auto_cache_dir is not None:
            import shutil

            shutil.rmtree(self._auto_cache_dir, ignore_errors=True)
            self._auto_cache_dir = None

    # -- topology (consumed by the shard-aware sampler / prefetch wiring) ---
    @property
    def num_shards(self) -> int:
        return len(self.shard_names)

    def shard_of(self, i: int) -> int:
        """Shard index holding global sample ``i``."""
        if not 0 <= i < self._n:
            raise IndexError(f"sample {i} out of range [0, {self._n})")
        return int(np.searchsorted(self._cum, i, side="right")) - 1

    def shard_and_offset(self, i: int) -> tuple[int, int]:
        """(shard index, shard-local sample index) of global sample ``i`` —
        the shard-local half is what index-first prefetch hints carry."""
        shard = self.shard_of(i)
        return shard, i - int(self._cum[shard])

    @property
    def sample_meta(self) -> tuple[np.dtype, tuple[int, ...]] | None:
        """(dtype, shape) of sample 0 as recorded by ``pack`` in the
        manifest, or None for manifests predating the field.  Lets loaders
        sniff the sample layout without reading (for remote datasets:
        downloading a whole shard of) actual data."""
        meta = self.manifest.get("sample0")
        if not meta:
            return None
        return np.dtype(meta["dtype"]), tuple(meta["shape"])

    def _reader(self, shard: int) -> ShardReader:
        if self.prefetcher is not None:
            return self.prefetcher.reader(self.shard_names[shard])
        r = self._readers.get(shard)
        if r is None:
            # Open (and eagerly verify) OUTSIDE the lock: concurrent read
            # threads opening different shards must not serialize behind one
            # whole-payload crc pass.  The install is double-checked; a
            # losing duplicate is closed (safe — no views were handed out),
            # at worst duplicating one open/verify under a race.
            candidate = ShardReader(self.root / self.shard_names[shard])
            if self.verify_crc == "eager":
                # coalesced verification: one whole-payload pass on the
                # opening thread, then reads skip the crc (the per-sample
                # bitset keeps corrupt samples raising)
                candidate.verify_all()
            with self._readers_lock:
                r = self._readers.setdefault(shard, candidate)
            if r is not candidate:
                candidate.close()
        return r

    # -- dataset protocol ---------------------------------------------------
    def __len__(self) -> int:
        return self._n

    def read_bytes(self, i: int) -> memoryview:
        """Zero-copy encoded bytes of sample ``i`` (mmap slice)."""
        shard = self.shard_of(i)
        local = i - int(self._cum[shard])
        return self._reader(shard).read(local, verify=self.verify_crc)

    def read_bytes_many(self, indices) -> list[memoryview]:
        """Bulk ``read_bytes``: one vectorized index→shard resolution for
        the whole batch (one ``searchsorted`` call instead of one per
        sample) and one reader lookup per shard *run* — the shard-aware
        sampler makes runs the common case.  Built for chunked read stages
        (``pipe(read_many, chunk=N, vectorized=True)``); out-of-range
        indices raise for the whole call."""
        idx = np.asarray(indices, dtype=np.int64)
        if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= self._n):
            raise IndexError(f"sample index out of range [0, {self._n})")
        shards = np.searchsorted(self._cum, idx, side="right") - 1
        locals_ = idx - self._cum[shards]
        verify = self.verify_crc
        out: list[memoryview] = []
        reader = None
        cur = -1
        for s, li in zip(shards.tolist(), locals_.tolist()):
            if s != cur:
                reader = self._reader(s)
                cur = s
            out.append(reader.read(li, verify=verify))
        return out

    def __getitem__(self, i: int) -> np.ndarray:
        return decode_sample(self.read_bytes(i))

    def close(self) -> None:
        for r in self._readers.values():
            r.close()
        self._readers.clear()
        if self.prefetcher is not None:
            self.prefetcher.close()
        # a cache dir we mkdtemp'd is ours to remove — leaving it would
        # leak up to cache_bytes of downloaded shards per dataset
        self._cleanup_auto_cache()

    # -- pickling (multiprocessing baselines fork/spawn the dataset) --------
    def __getstate__(self) -> dict:
        if self.prefetcher is not None:
            raise TypeError(
                "a prefetcher-backed ShardDataset cannot be pickled (the "
                "prefetcher owns threads and mmaps); pickle a local-mode "
                "ShardDataset and construct the prefetcher in the worker"
            )
        state = self.__dict__.copy()
        state["_readers"] = {}  # mmaps/locks are per-process; reopen lazily
        del state["_readers_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._readers = {}
        self._readers_lock = threading.Lock()


def pack(
    dataset: Any,
    out_dir: str | pathlib.Path,
    *,
    samples_per_shard: int = 1024,
    max_shard_bytes: int | None = None,
    prefix: str = "shard",
) -> ShardDataset:
    """Pack any ``read_bytes``/``__len__`` dataset into a sharded directory.

    A shard rolls over at ``samples_per_shard`` samples or (if given)
    ``max_shard_bytes`` of payload, whichever comes first.  Unreadable
    source samples are packed as-is only if ``read_bytes`` succeeds —
    failures propagate (migration should not silently drop data).
    """
    if samples_per_shard < 1:
        raise ValueError("samples_per_shard must be >= 1")
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    shards: list[dict] = []
    sample0: dict | None = None
    writer: ShardWriter | None = None

    def roll() -> None:
        nonlocal writer
        if writer is not None and writer.n_samples:
            writer.close()
            shards.append(
                {
                    "name": writer.path.name,
                    "n": writer.n_samples,
                    "bytes": writer.path.stat().st_size,
                }
            )
        writer = None

    try:
        for i in range(len(dataset)):
            if writer is None:
                writer = ShardWriter(out_dir / f"{prefix}-{len(shards):05d}.rpshard")
            data = dataset.read_bytes(i)
            if sample0 is None:
                # record sample 0's layout so loaders can sniff dtype/shape
                # from the manifest alone (a remote dataset would otherwise
                # download a whole shard just to peek at one header);
                # samples that are not codec blobs simply leave the field out
                try:
                    dtype, shape, _ = parse_header(data)
                    sample0 = {"dtype": dtype.name, "shape": list(shape)}
                except Exception:
                    sample0 = {}
            writer.add(data)
            if writer.n_samples >= samples_per_shard or (
                max_shard_bytes is not None and writer.payload_bytes >= max_shard_bytes
            ):
                roll()
        roll()
    except BaseException:
        # failed migration: abort (never finalize a partial shard) and
        # remove the zero-header file so a retry doesn't find a stray
        if writer is not None:
            writer.abort()
            writer.path.unlink(missing_ok=True)
        raise
    write_manifest(out_dir, shards, {"sample0": sample0} if sample0 else None)
    return ShardDataset(out_dir)

"""Async shard prefetch + bounded local shard cache over a remote source.

SPDL's pipeline overlaps network, CPU, and GPU *within* a sample stream;
this module applies the same overlap at shard granularity for remote or
high-latency storage: while the decode stages chew on shard *k*, the
prefetcher is already pulling shards *k+1..k+d* into a local byte-budgeted
cache, so the read stage almost never blocks on the network.

Pieces:

``RemoteShardSource``      duck-typed backend: ``fetch(name) -> bytes``
                           plus optional ``fetch_range(name, start, length)``
                           (see ``sources.py`` for the real HTTP backend and
                           the retry/backoff wrapper).
``LocalShardSource``       trivial backend reading files from a directory
                           (also the base other sources usually wrap).
``SimulatedLatencySource`` wraps a source with a per-fetch latency floor +
                           bandwidth cap — a deterministic stand-in for
                           object storage in tests and benchmarks.
``SparseShardReader``      ``ShardReader``-compatible reads over a shard
                           whose index was fetched but whose payload is
                           only partially resident (index-first fetch).
``ShardPrefetcher``        the cache + scheduler: LRU-by-bytes local cache
                           of fetched shard files, fetch dedup (concurrent
                           requests for one shard share one download), and
                           a bounded background fetch pool whose in-flight
                           count is the ``prefetch_depth`` stat.

Index-first fetch
-----------------
When the source supports ``fetch_range`` (``index_first="auto"``), a
scheduled fetch that carries sample hints (``schedule(name, samples=...)``,
fed by the loaders' lookahead window) downloads the shard's 32-byte header
+ index region first and *decides* before committing to the payload: if the
hinted samples cover less than ``sparse_threshold`` of the payload bytes,
only their (coalesced) ranges are fetched and the cache entry is a
``SparseShardReader`` — ``bytes_cached`` counts just the resident bytes,
and a read of an un-fetched sample demand-fetches exactly that range.
Otherwise (or with no hints) the whole shard is fetched to disk as before.

Sparse→full promotion
---------------------
A sparse entry that keeps paying demand round trips was mis-predicted: once
its cumulative *demand-fetched* bytes (reads outside the hinted window, not
background top-ups) cross ``promote_threshold`` of the payload, the
prefetcher schedules ONE whole-shard GET in the background and swaps the
entry for a normal disk cache entry — subsequent reads are mmap slices, and
a ``PeerShardServer`` can then serve the whole shard to other ranks.  The
swap is an install, not a teardown: the displaced sparse reader is never
closed (an in-flight demand read may be holding it), just dropped, and the
``_promoting`` guard makes the upgrade a single fetch no matter how many
demand reads cross the threshold concurrently.

A Range-ignoring origin (a ranged read answered with a whole-shard ``200``,
surfaced by the source as ``RangeNotSupported`` carrying the body) takes
the same install path: the body that already crossed the wire becomes the
disk entry — exactly one wire fetch, never download-slice-discard-refetch.

Projection pushdown (columnar v2 shards)
----------------------------------------
On a columnar shard (format v2, see ``format.py``) the hints can carry a
**field projection** too (``schedule(name, samples=..., fields=("image",))``,
wired from ``ShardDataset(fields=...)``): the index-first decision then
counts only the requested columns' bytes, and the sparse entry coalesces
ranges **per requested column only** — the caption/metadata columns of an
image-only read never cross the wire.  ``bytes_skipped`` accounts the
payload bytes projection avoided fetching (hinted samples' non-requested
columns), and ``fields_requested`` counts the distinct field names hinted
so far; both feed the dashboard.  The per-(field, sample) crc keeps the
corruption contract: a bad cell is a hole in one field of one sample.

Tier composition: with ``peer.TieredSource`` as the source, every fetch
here first consults warm peer ranks and only then the retrying origin —
see ``peer.py`` for the full origin → retry → peers → prefetcher stack.

Security: shard names come from a *remote-controlled* manifest and are
joined to a local cache directory, so every entry point validates them as
a single path component (``validate_shard_name``) — a hostile manifest
containing ``../`` must not escape the cache.

Eviction contract: evicting a shard unlinks its cache file (or drops the
sparse entry's buffers) and drops the reader.  In-flight ``memoryview``
reads stay valid — on Linux the mapping outlives the unlink and the pages
are reclaimed when the last view drops; sparse spans are plain refcounted
``bytes`` — so eviction can never corrupt a sample that is mid-decode.

Stats (``stats()``) feed the pipeline dashboard: ``hits``/``misses`` per
*reader* request (a prefetched shard counts as a hit — that is the point),
``evictions``, ``bytes_cached``, ``prefetch_depth``, cumulative
``fetch_time`` seconds downloading, wire-level ``bytes_fetched`` /
``index_fetches`` / ``range_fetches``, sparse→full ``promotions``, and —
when the source exposes its own ``stats()`` (e.g. ``RetryingSource`` or
``peer.TieredSource``) — every source counter prefixed ``source_``
(``source_errors``, ``source_retries``, ``source_peer_hits``,
``source_origin_bytes``, ...).
"""

from __future__ import annotations

import bisect
import functools
import json
import logging
import os
import pathlib
import random
import struct
import threading
import time
import zlib
from collections import OrderedDict
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor

import numpy as np

from ...core import trace as _trace
from .dataset import MANIFEST_NAME, validate_shard_name
from .format import (
    ENTRY_SIZE,
    FORMAT_VERSION_V2,
    HEADER_SIZE,
    INDEX_PREAMBLE_SIZE,
    MappedShardReader,
    ShardCorruption,
    ShardIndex,
    ShardIndexV2,
    ShardReader,
    open_shard_reader,
    parse_index_preamble,
    parse_shard_header,
)
from .sources import RangeNotSupported

logger = logging.getLogger("repro.data.shards")


class LocalShardSource:
    """Reads shard files from a local directory (the trivial backend)."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)

    def fetch(self, name: str) -> bytes:
        return (self.root / name).read_bytes()

    def fetch_range(self, name: str, start: int, length: int) -> bytes:
        with open(self.root / name, "rb") as f:
            f.seek(start)
            return f.read(length)


class SimulatedLatencySource:
    """A ``RemoteShardSource`` with object-storage-shaped costs.

    Each fetch pays ``latency_s`` (request round-trip) plus
    ``nbytes / bandwidth_bps`` (transfer), then returns the inner source's
    bytes.  ``fetches``/``bytes_fetched`` make tests assert exactly how
    often the network was touched.

    ``ranges=True`` additionally exposes ``fetch_range`` (passing through
    to the inner source, paying the same per-request latency) so the
    index-first path can be exercised without a real server; the default
    stays range-less so whole-shard fetch counts in existing tests and
    benchmarks are unchanged.

    ``jitter_s`` adds a uniform ``[0, jitter_s)`` random extra delay per
    request, drawn from this source's OWN seeded ``random.Random(seed)`` —
    never the process-global RNG, so latency benchmarks and fault drills
    are reproducible run-to-run regardless of what else consumed random
    numbers (and two sources with the same seed pay identical jitter
    sequences).
    """

    def __init__(
        self,
        inner,
        *,
        latency_s: float = 0.01,
        bandwidth_bps: float | None = None,
        ranges: bool = False,
        jitter_s: float = 0.0,
        seed: int = 0,
    ):
        if jitter_s < 0:
            raise ValueError("jitter_s must be >= 0")
        self.inner = inner
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        self.jitter_s = jitter_s
        self._rng = random.Random(seed)
        self.fetches = 0
        self.range_fetches = 0
        self.bytes_fetched = 0
        self._lock = threading.Lock()
        if ranges and callable(getattr(inner, "fetch_range", None)):
            self.fetch_range = self._fetch_range

    def _pay(self, nbytes: int) -> None:
        delay = self.latency_s
        if self.bandwidth_bps:
            delay += nbytes / self.bandwidth_bps
        if self.jitter_s:
            with self._lock:  # Random isn't thread-safe; draws stay seeded
                delay += self._rng.random() * self.jitter_s
        if delay > 0:
            time.sleep(delay)

    def fetch(self, name: str) -> bytes:
        data = self.inner.fetch(name)
        self._pay(len(data))
        with self._lock:
            self.fetches += 1
            self.bytes_fetched += len(data)
        return data

    def _fetch_range(self, name: str, start: int, length: int) -> bytes:
        data = self.inner.fetch_range(name, start, length)
        self._pay(len(data))
        with self._lock:
            self.range_fetches += 1
            self.bytes_fetched += len(data)
        return data


class SparseShardReader:
    """``ShardReader``-compatible reads over a partially-fetched shard.

    Built by index-first fetch: the header + index came down first (a
    ``ShardIndex``), and payload **spans** — coalesced byte ranges covering
    the hinted samples — arrive via ``fetch_range``.  ``read(i)`` serves
    resident samples as zero-copy ``memoryview`` slices of their span; a
    non-resident sample triggers a demand range fetch of exactly that
    sample.  ``ensure(samples)`` tops up residency in bulk (the background
    path).

    Spans are plain ``bytes`` objects, so dropping the reader (cache
    eviction) never invalidates views already handed out — refcounts keep
    them alive, mirroring the mmap/unlink contract of the on-disk cache.
    Growth is reported to the owning cache through ``_on_grow(delta)`` so
    ``bytes_cached`` tracks partial shards accurately.

    Spans are absolute file offsets, so the machinery is format-agnostic:
    over a columnar (v2) ``ShardIndexV2`` the same reader serves
    ``read_field``/``read_fields``, and a ``fields=`` projection restricts
    which columns a sample's ranges cover — ``ensure``/``missing`` and the
    coalescer then touch only the projected columns' byte ranges.
    """

    def __init__(
        self,
        name: str,
        index: ShardIndex | ShardIndexV2,
        range_fetch,
        *,
        coalesce_gap: int = 1 << 16,
        fields: tuple[str, ...] | None = None,
    ):
        self.name = name
        self.index = index
        self._range_fetch = range_fetch  # (start, length) -> bytes
        self.coalesce_gap = coalesce_gap
        self._names = getattr(index, "field_names", None)  # None ⇒ v1
        if self._names is None:
            if fields is not None:
                raise TypeError(f"{name}: fields= projection needs a columnar index")
            self.fields = None
            self._proj: tuple[str, ...] | None = None
            self._verified = np.zeros(index.n_samples, dtype=bool)  # crc memo
        else:
            # projection resolved once (unknown names raise here, loudly)
            self.fields = tuple(fields) if fields is not None else None
            self._proj = index.resolve_fields(self.fields)
            # per-(field, sample) crc memo, one bitset per column
            self._verified = {
                f: np.zeros(index.n_samples, dtype=bool) for f in self._names
            }
        self._lock = threading.Lock()
        self._starts: list[int] = []  # sorted span start offsets
        self._spans: list[bytes] = []  # parallel span payloads
        self._bytes_held = 0
        self._closed = False
        self._on_grow = None  # installed by the owning ShardPrefetcher
        #: wire bytes pulled by demand ``read()`` misses (NOT hinted ensure
        #: top-ups) — the mis-prediction signal sparse→full promotion watches
        self.demand_bytes = 0

    # -- ShardReader-compatible surface ------------------------------------
    @property
    def n_samples(self) -> int:
        return self.index.n_samples

    def __len__(self) -> int:
        return self.index.n_samples

    @property
    def field_names(self) -> tuple[str, ...] | None:
        """Columnar field names, or None over a v1 index — the same
        dispatch marker the full readers carry."""
        return self._names

    @property
    def offsets(self):
        return self.index.offsets

    @property
    def lengths(self):
        return self.index.lengths

    @property
    def crcs(self):
        return self.index.crcs

    @property
    def nbytes(self) -> int:
        """Bytes actually resident (index + fetched spans) — what this
        entry costs the cache, NOT the full shard size."""
        with self._lock:
            return self.index.index_nbytes + self._bytes_held

    # -- span bookkeeping ---------------------------------------------------
    def _find_locked(self, off: int, ln: int) -> memoryview | None:
        j = bisect.bisect_right(self._starts, off) - 1
        if j >= 0:
            start, span = self._starts[j], self._spans[j]
            if start + len(span) >= off + ln:
                rel = off - start
                return memoryview(span)[rel : rel + ln]
        return None

    def _insert_locked(self, start: int, data: bytes) -> int:
        """Insert a span, keeping the list **nesting-free**: an incoming
        span already covered by a resident one is skipped, and resident
        spans fully inside the incoming one are dropped (their bytes were
        double-held).  Nesting-freedom is what makes the single-candidate
        lookup in ``_find_locked`` exact — without it a short later-start
        span could shadow a longer earlier one and force redundant demand
        fetches.  Returns the net change in resident bytes."""
        end = start + len(data)
        pos = bisect.bisect_left(self._starts, start)
        if pos > 0 and self._starts[pos - 1] + len(self._spans[pos - 1]) >= end:
            return 0  # covered by an earlier-starting span
        removed = 0
        k = pos
        while k < len(self._starts) and self._starts[k] + len(self._spans[k]) <= end:
            removed += len(self._spans[k])
            del self._starts[k]
            del self._spans[k]
        if k < len(self._starts) and self._starts[k] == start:
            # a same-start, longer span survives: it covers the new one
            self._bytes_held -= removed
            return -removed
        self._starts.insert(pos, start)
        self._spans.insert(pos, data)
        self._bytes_held += len(data) - removed
        return len(data) - removed

    def _sample_ranges(self, s: int) -> list[tuple[int, int]]:
        """Absolute (offset, length) byte ranges sample ``s`` occupies —
        one range over a v1 index, one per **projected** column over a
        columnar index (the projection pushdown point: non-requested
        columns contribute no ranges, so they are never fetched)."""
        if self._proj is not None:
            return [self.index.locate(f, s)[:2] for f in self._proj]
        return [(int(self.index.offsets[s]), int(self.index.lengths[s]))]

    def _intervals(self, samples: list[int]) -> list[tuple[int, int]]:
        """Coalesce sample indices into (start, length) fetch runs.

        Adjacent samples are byte-adjacent within a column (and v1 shards
        are one column), so a run of hinted samples becomes one ranged
        request per touched column; gaps up to ``coalesce_gap`` are
        fetched too (one round trip beats two)."""
        ranges: list[tuple[int, int]] = []
        for s in samples:
            ranges.extend(self._sample_ranges(s))
        ranges.sort()
        out: list[list[int]] = []
        for a, ln in ranges:
            b = a + ln
            if out and a - out[-1][1] <= self.coalesce_gap:
                out[-1][1] = max(out[-1][1], b)
            else:
                out.append([a, b])
        return [(a, b - a) for a, b in out]

    def missing(self, samples) -> list[int]:
        """Hinted samples not yet fully resident under the projection
        (sorted, deduped, in-range)."""
        wanted = sorted({int(s) for s in samples if 0 <= int(s) < self.n_samples})
        with self._lock:
            return [
                s
                for s in wanted
                if any(
                    ln and self._find_locked(off, ln) is None
                    for off, ln in self._sample_ranges(s)
                )
            ]

    def ensure(self, samples) -> int:
        """Fetch any non-resident hinted samples (coalesced); returns bytes
        added.  Used by the background top-up path."""
        gap = self.missing(samples)
        if not gap:
            return 0
        grown = 0
        for start, length in self._intervals(gap):
            data = self._range_fetch(start, length)
            with self._lock:
                if self._closed:
                    break
                grown += self._insert_locked(start, data)
        if grown and self._on_grow is not None:
            self._on_grow(grown)
        return grown

    def _read_range(self, off: int, ln: int) -> memoryview:
        """Resident bytes for ``[off, off+ln)``, demand-fetching exactly
        that range on a miss (the span race/growth bookkeeping both read
        paths share)."""
        if ln == 0:
            return memoryview(b"")
        with self._lock:
            if self._closed:
                raise RuntimeError(f"SparseShardReader({self.name}) is closed")
            view = self._find_locked(off, ln)
        if view is None:
            data = self._range_fetch(off, ln)  # demand: exactly this range
            grown = 0
            with self._lock:
                if self._closed:
                    raise RuntimeError(f"SparseShardReader({self.name}) is closed")
                # demand_bytes counts the wire bytes this miss cost even if
                # a racing read landed the same range first — promotion
                # watches what demand reads actually paid, not residency
                self.demand_bytes += len(data)
                view = self._find_locked(off, ln)  # demand race: keep winner
                if view is None:
                    grown = self._insert_locked(off, data)
                    view = self._find_locked(off, ln)  # nesting-free: found
            if grown and self._on_grow is not None:
                self._on_grow(grown)
        return view

    def read(self, i: int, *, verify: bool = True) -> memoryview:
        if self._names is not None:
            raise TypeError(
                f"{self.name}: columnar sparse entry — read one-blob samples "
                "via read_field/read_fields"
            )
        if not 0 <= i < self.n_samples:
            raise IndexError(f"sample {i} out of range [0, {self.n_samples})")
        off, ln = int(self.index.offsets[i]), int(self.index.lengths[i])
        view = self._read_range(off, ln)
        # crc memo (see ShardReader.read): spans are immutable once resident,
        # so one verification covers every later read; a mismatch is never
        # memoized, keeping the per-sample-hole corruption semantics
        if verify and not self._verified[i]:
            if zlib.crc32(view) != int(self.index.crcs[i]):
                raise ShardCorruption(f"{self.name}: sample {i} failed crc32 check")
            self._verified[i] = True
        return view

    def read_field(self, i: int, field: str, *, verify: bool = True) -> memoryview:
        """Sample ``i``'s ``field`` cell (columnar indexes only), demand-
        fetching exactly that cell's range on a miss."""
        if self._names is None:
            raise TypeError(f"{self.name}: v1 sparse entry has no fields")
        off, ln, crc = self.index.locate(field, i)
        view = self._read_range(off, ln)
        bits = self._verified[field]
        if verify and not bits[i]:
            if zlib.crc32(view) != crc:
                raise ShardCorruption(
                    f"{self.name}: sample {i} field {field!r} failed crc32 check"
                )
            bits[i] = True
        return view

    def read_fields(
        self, i: int, fields=None, *, verify: bool = True
    ) -> dict[str, memoryview]:
        """Projected read over the sparse entry: ``{field: memoryview}``.
        ``fields=None`` means this entry's own projection."""
        if self._names is None:
            raise TypeError(f"{self.name}: v1 sparse entry has no fields")
        if fields is None:
            fields = self.fields
        return {
            f: self.read_field(i, f, verify=verify)
            for f in self.index.resolve_fields(fields)
        }

    def raw(self, start: int, length: int) -> memoryview | None:
        """Resident raw shard bytes ``[start, start+length)`` or ``None``
        (the ``PeerShardServer`` ranged-read path).  The header and index
        regions are re-serialized from the parsed index — a sparse entry
        can always answer the index-first reads a peer's prefetcher issues;
        a payload range is served iff one resident span covers it whole."""
        if start < 0 or length < 0:
            return None
        with self._lock:
            if self._closed:
                return None
            if start + length <= HEADER_SIZE:
                return memoryview(self.index.header_bytes())[start : start + length]
            if start >= self.index.index_off:
                raw = self.index.index_bytes()
                rel = start - self.index.index_off
                if rel + length <= len(raw):
                    return memoryview(raw)[rel : rel + length]
                return None
            return self._find_locked(start, length)

    # -- warm-restart persistence ------------------------------------------
    def spans_snapshot(self) -> list[tuple[int, bytes]]:
        """Consistent ``(start, payload)`` snapshot of the resident spans —
        what the prefetcher's warm-restart sidecar persists.  Spans are
        immutable ``bytes``, so the copy is reference-cheap."""
        with self._lock:
            return list(zip(self._starts, self._spans))

    def restore_spans(self, spans) -> int:
        """Re-insert persisted ``(start, payload)`` spans (a restart's warm
        resume); returns resident bytes added.  Goes through the normal
        nesting-free insert, so overlapping/stale sidecar spans degrade to
        their net coverage instead of double-counting."""
        grown = 0
        with self._lock:
            if self._closed:
                return 0
            for start, data in spans:
                grown += self._insert_locked(int(start), bytes(data))
        if grown and self._on_grow is not None:
            self._on_grow(grown)
        return grown

    def close(self) -> None:
        with self._lock:
            self._closed = True
            # dropping the lists releases our refs; views already handed
            # out keep their span's bytes alive on their own
            self._starts = []
            self._spans = []
            self._bytes_held = 0


#: warm-restart sidecar magic (8 bytes) — versioned like the shard magic
_WARM_MAGIC = b"RPWARM01"
_WARM_DIR = ".warm"
_WARM_MANIFEST = "manifest.json"


class ShardPrefetcher:
    """Bounded local shard cache + background fetch scheduler.

    ``reader(name)`` is the synchronous path the dataset uses: cache hit →
    reader immediately; miss → fetch (joining an in-flight background
    fetch if one exists), install, evict LRU shards past ``max_bytes``.

    ``schedule(name, samples=None)`` is the asynchronous path the loader
    uses: start a background fetch (up to ``max_inflight`` concurrent)
    unless the shard is already cached or being fetched.  ``samples`` is
    the set of shard-local indices the caller's lookahead window wants —
    with an index-first-capable source it drives the sparse-vs-full
    decision (see the module docstring).  Scheduling is advisory —
    dropping a request is always safe because ``reader`` fetches on
    demand.
    """

    def __init__(
        self,
        source,
        cache_dir: str | pathlib.Path,
        *,
        max_bytes: int = 1 << 30,
        max_inflight: int = 2,
        index_first: bool | str = "auto",
        sparse_threshold: float = 0.75,
        promote_threshold: float | None = 0.5,
        coalesce_gap: int = 1 << 16,
        verify_on_install: bool = True,
        persist_state: bool = False,
    ):
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.source = source
        self.cache_dir = pathlib.Path(cache_dir)
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        #: warm restart: persist the cache manifest + sparse-span sidecars
        #: under ``cache_dir/.warm`` on close() (crash-safe fsync+rename)
        #: and re-open resident entries on construction instead of
        #: re-fetching them.  Needs a STABLE cache_dir across runs.
        self.persist_state = persist_state
        self._state_dir = self.cache_dir / _WARM_DIR
        self.max_bytes = max_bytes
        self.max_inflight = max_inflight
        has_range = callable(getattr(source, "fetch_range", None))
        if index_first == "auto":
            self.index_first = has_range
        else:
            self.index_first = bool(index_first)
            if self.index_first and not has_range:
                raise ValueError(
                    "index_first=True needs a source with fetch_range "
                    f"({type(source).__name__} has none)"
                )
        self.sparse_threshold = sparse_threshold
        #: crc-verify whole shards once at cache install (coalesced pass on
        #: the fetch thread) so reads skip the per-sample crc.  False for
        #: callers doing their own integrity checking (the URL-mode stack
        #: wires ``ShardDataset(verify_crc=False)`` through to here).
        self.verify_on_install = verify_on_install
        #: sparse→full promotion trigger: demand-fetched bytes as a fraction
        #: of the payload (None disables promotion)
        self.promote_threshold = promote_threshold
        self.coalesce_gap = coalesce_gap
        self._pool = ThreadPoolExecutor(
            max_workers=max_inflight, thread_name_prefix="shard-prefetch"
        )
        self._lock = threading.Lock()
        # name -> (reader, nbytes); insertion order is the LRU order
        self._cached: OrderedDict[str, tuple[ShardReader | SparseShardReader, int]] = (
            OrderedDict()
        )
        self._inflight: dict[str, Future] = {}
        self._indexes: dict[str, ShardIndex] = {}  # tiny: 16 B/sample arrays
        self._ensuring: set[str] = set()  # sparse top-ups in flight
        self._promoting: set[str] = set()  # sparse→full upgrades in flight
        #: cache-path writes running OUTSIDE _inflight/_promoting coverage
        #: (the demand-read RangeNotSupported install); counted because two
        #: demand reads on one shard can overlap
        self._writing: dict[str, int] = {}
        self._bg_inflight = 0  # pool fetches only (demand fetches excluded)
        self._closed = False
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.promotions = 0
        self.corrupt_samples = 0  # found by install-time verification
        self.bytes_cached = 0
        self.bytes_fetched = 0  # wire bytes: payloads + indexes + ranges
        self.bytes_skipped = 0  # payload bytes projection avoided fetching
        self._fields_requested: set[str] = set()  # distinct projected fields
        self.index_fetches = 0
        self.range_fetches = 0
        self.fetch_time = 0.0
        #: bytes re-opened from a previous run's persisted state instead of
        #: re-fetched (full cache files + sparse sidecar spans)
        self.warm_restart_bytes_reused = 0
        if self.persist_state:
            try:
                self._restore_state()
            except Exception:
                # a damaged warm state must never block a cold start
                logger.warning("warm-restart state unusable; starting cold",
                               exc_info=True)

    # -- manifest -----------------------------------------------------------
    def fetch_manifest(self) -> bytes:
        """The dataset manifest comes over the same wire as the shards."""
        data = self.source.fetch(MANIFEST_NAME)
        with self._lock:
            self.bytes_fetched += len(data)
        return data

    # -- fetch machinery ----------------------------------------------------
    def _range_fetch(self, name: str, start: int, length: int) -> bytes:
        with self._lock:
            # Owner-closed guard: a sparse reader that outlived the cache
            # (evicted, or handed out mid-shutdown) must not demand-fetch
            # into a closed/closing source — that surfaces backend socket
            # errors instead of the documented shutdown error.
            if self._closed:
                raise RuntimeError("ShardPrefetcher is closed")
            entry = self._cached.get(name)
        if entry is not None and isinstance(entry[0], MappedShardReader):
            # A full copy landed since this sparse reader was built
            # (promotion, or a Range-ignoring origin below): serve the range
            # locally — zero wire bytes, so no fetch counters move.
            return bytes(entry[0].raw(start, length))
        tracer = _trace.get_tracer()
        t0 = time.monotonic() if tracer.enabled else 0.0
        try:
            data = self.source.fetch_range(name, start, length)
        except RangeNotSupported as e:
            # the server ignored Range and the whole shard arrived: install
            # it as the disk entry (displacing the sparse one) and serve the
            # requested slice from the body in hand — one wire fetch, not
            # download-slice-discard-refetch
            with self._lock:
                self.range_fetches += 1
                self.bytes_fetched += len(e.body)
                # cover the path write: this runs on a demand reader's
                # thread, outside _inflight/_promoting, so a concurrent
                # eviction's unlink must be told the file is being replaced
                self._writing[name] = self._writing.get(name, 0) + 1
            try:
                reader = self._persist(name, e.body)
            finally:
                with self._lock:
                    left = self._writing[name] - 1
                    if left:
                        self._writing[name] = left
                    else:
                        del self._writing[name]
            self._replace_with_full(name, reader)
            data = bytes(memoryview(e.body)[start : start + length])
        else:
            with self._lock:
                self.range_fetches += 1
                self.bytes_fetched += len(data)
        if tracer.enabled:
            tracer.complete(
                f"range {name}", "shard", t0, time.monotonic() - t0,
                {"start": start, "length": length},
            )
        if len(data) != length:
            raise ShardCorruption(
                f"{name}: range {start}+{length} returned {len(data)} bytes"
            )
        return data

    def _get_index(self, name: str) -> ShardIndex | ShardIndexV2:
        """Header + index region of ``name`` via small ranged reads — two
        for v1 (header, then the fixed-size index), three for columnar v2
        (header, the 16-byte index preamble that says how long the column
        index is, then the rest of it).

        Cached in memory (indexes are tens of bytes/sample — thousands of
        shards fit in a few MB).  Concurrent first fetches of one index may
        duplicate the ~KB download; the setdefault keeps exactly one parse."""
        with self._lock:
            idx = self._indexes.get(name)
        if idx is not None:
            return idx
        header = self.source.fetch_range(name, 0, HEADER_SIZE)
        version, n, index_off, _payload_off = parse_shard_header(header, name)
        if version >= FORMAT_VERSION_V2:
            preamble = self.source.fetch_range(name, index_off, INDEX_PREAMBLE_SIZE)
            index_len, _n_fields = parse_index_preamble(preamble, name)
            rest = (
                self.source.fetch_range(
                    name,
                    index_off + INDEX_PREAMBLE_SIZE,
                    index_len - INDEX_PREAMBLE_SIZE,
                )
                if index_len > INDEX_PREAMBLE_SIZE
                else b""
            )
            index_bytes = preamble + rest
            idx = ShardIndexV2.parse(header, index_bytes, name)
        else:
            index_bytes = self.source.fetch_range(name, index_off, n * ENTRY_SIZE)
            idx = ShardIndex.parse(header, index_bytes, name)
        with self._lock:
            self.index_fetches += 1
            self.bytes_fetched += len(header) + len(index_bytes)
            return self._indexes.setdefault(name, idx)

    def _fetch_full(self, name: str) -> MappedShardReader:
        """Download one whole shard, persist it, open a reader."""
        data = self.source.fetch(name)
        with self._lock:
            self.bytes_fetched += len(data)
        return self._persist(name, data)

    def _persist(self, name: str, data: bytes) -> MappedShardReader:
        """Stage ``data`` durably under the cache dir and open a reader
        (format-version dispatched: v1 → ShardReader, v2 → ShardReaderV2)."""
        path = self.cache_dir / name
        # unique temp per fetch: two racing fetches of one shard must not
        # share a staging file (the loser's replace() would find it gone)
        tmp = path.with_suffix(f"{path.suffix}.{threading.get_ident():x}.part")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            # durable before the atomic rename: a crash right after
            # replace() must not leave a torn-but-magic-valid cache file
            os.fsync(f.fileno())
        tmp.replace(path)
        reader = open_shard_reader(path)
        if self.verify_on_install:
            # Coalesced crc: one whole-payload pass NOW, on this fetch
            # thread (pool worker or demand caller — never the event loop),
            # instead of one crc per sample on the hot read path; per-read
            # verification costs ~2x on cold reads.  Corrupt samples stay
            # unmemoized, so the per-sample-hole contract is untouched.
            # Local (non-prefetcher) datasets keep lazy per-sample verify —
            # their bytes were never on the wire, so the first-touch risk
            # profile is different.
            bad = reader.verify_all()
            if bad:
                # surface transit/origin corruption at the fetch, not one
                # ShardCorruption hole at a time later on the read path
                logger.warning(
                    "shard %s: %d corrupt sample(s) found at cache install",
                    name, bad,
                )
                with self._lock:
                    self.corrupt_samples += bad
        return reader

    def _fetch_entry(
        self, name: str, samples=None, fields=None
    ) -> MappedShardReader | SparseShardReader:
        """Fetch ``name`` honoring the index-first policy (any thread).

        With sample hints and a range-capable source: pull the index first,
        and if the hinted samples cover < ``sparse_threshold`` of the
        payload, fetch only their coalesced ranges (sparse entry).  On a
        columnar shard a ``fields`` projection narrows both the decision
        and the ranges to the requested columns — the avoided column bytes
        are credited to ``bytes_skipped``.  A ``fields`` projection with NO
        sample hints (a demand read through ``ShardDataset(fields=...)``
        whose schedule hint was dropped) still goes index-first with every
        sample wanted: fetching just the projected columns of the whole
        shard beats fetching the whole shard.  Otherwise — no hints, no
        ranges, or the window wants most of the shard anyway — fetch the
        whole shard to disk."""
        tracer = _trace.get_tracer()
        t0 = time.monotonic()
        try:
            # range_supported goes False the moment the source sees a server
            # ignore a Range header — from then on "ranged" reads move whole
            # bodies, so sparse fetch would COST bytes, not save them
            if (
                (samples or fields)
                and self.index_first
                and getattr(self.source, "range_supported", True)
            ):
                try:
                    idx = self._get_index(name)
                except RangeNotSupported as e:
                    # the index ranged read came back as the whole shard:
                    # the fetch is already done — persist the body in hand
                    # (one wire fetch; range_supported is now False, so
                    # later shards skip straight to _fetch_full)
                    with self._lock:
                        self.bytes_fetched += len(e.body)
                    return self._persist(name, e.body)
                if samples:
                    wanted = sorted(
                        {int(s) for s in samples if 0 <= int(s) < idx.n_samples}
                    )
                else:  # fields-only: every sample, projected columns only
                    wanted = list(range(idx.n_samples))
                columnar = hasattr(idx, "samples_nbytes")  # ShardIndexV2
                proj = tuple(fields) if (columnar and fields) else None
                if columnar:
                    # projection-aware cost: only the requested columns'
                    # bytes count (unknown field names raise KeyError here
                    # — a typo'd projection fails the fetch loudly)
                    wanted_bytes = idx.samples_nbytes(wanted, proj)
                else:
                    wanted_bytes = sum(int(idx.lengths[s]) for s in wanted)
                if wanted and wanted_bytes <= self.sparse_threshold * max(
                    idx.payload_bytes, 1
                ):
                    reader = SparseShardReader(
                        name,
                        idx,
                        functools.partial(self._range_fetch, name),
                        coalesce_gap=self.coalesce_gap,
                        fields=proj,
                    )
                    reader.ensure(wanted)
                    if proj:
                        skipped = idx.samples_nbytes(wanted, None) - wanted_bytes
                        if skipped > 0:
                            with self._lock:
                                self.bytes_skipped += skipped
                    return reader
            return self._fetch_full(name)
        finally:
            dt = time.monotonic() - t0
            with self._lock:
                self.fetch_time += dt
            if tracer.enabled:
                # one span per shard fetch, on whatever thread ran it
                # (prefetch pool or a demand caller)
                tracer.complete(f"fetch {name}", "shard", t0, dt)

    def _evict_over_budget_locked(self) -> list[str]:
        """LRU-evict past the byte budget; caller holds the lock and must
        pass the result to ``_unlink_evicted`` after releasing it."""
        evicted: list[str] = []
        while self.bytes_cached > self.max_bytes and len(self._cached) > 1:
            old_name, (old_reader, nbytes) = self._cached.popitem(last=False)
            self.bytes_cached -= nbytes
            self.evictions += 1
            evicted.append(old_name)
        return evicted

    def _unlink_evicted(self, evicted: list[str]) -> None:
        for old_name in evicted:
            # Unlink the file but do NOT close the reader: a concurrent
            # ``read`` may hold it (or views into it) right now.  The
            # mapping is dropped by refcount once the last holder lets go,
            # and the disk space returns with it (Linux unlink semantics).
            # Sparse entries have no file — unlink(missing_ok) covers both.
            # Re-check under the lock first: the shard may have been
            # re-fetched since we evicted it, in which case the file on
            # disk is the NEWER copy and belongs to that install (every
            # path write is covered by _inflight — or _promoting for a
            # sparse→full upgrade, or _writing for a demand-read whole-body
            # install — until the written file is safely open, so this
            # check is race-free).
            with self._lock:
                if (
                    old_name in self._cached
                    or old_name in self._inflight
                    or old_name in self._promoting
                    or old_name in self._writing
                ):
                    continue
                (self.cache_dir / old_name).unlink(missing_ok=True)

    def _install(self, name: str, reader) -> None:
        """Insert a fetched shard and evict LRU past the byte budget."""
        evicted: list[str] = []
        with self._lock:
            if name in self._cached:
                reader.close()  # lost an install race: keep the first copy
                return
            if self._closed:
                # Shutdown mid-fetch: don't cache, but leave the reader
                # open — the demand caller may still be about to use it
                # (it is reclaimed by refcount once dropped).
                return
            self._cached[name] = (reader, reader.nbytes)
            self.bytes_cached += reader.nbytes
            if isinstance(reader, SparseShardReader):
                # from here on demand/top-up growth adjusts bytes_cached
                reader._on_grow = functools.partial(self._sparse_grow, name, reader)
            evicted = self._evict_over_budget_locked()
        self._unlink_evicted(evicted)

    def _sparse_grow(self, name: str, reader: SparseShardReader, delta: int) -> None:
        """A sparse entry fetched more payload: keep ``bytes_cached`` honest,
        re-run eviction, and check the sparse→full promotion trigger.
        No-op if the entry was already evicted (the orphaned reader's spans
        are refcount-reclaimed on their own)."""
        evicted: list[str] = []
        with self._lock:
            entry = self._cached.get(name)
            if entry is None or entry[0] is not reader:
                return
            self._cached[name] = (reader, entry[1] + delta)
            self.bytes_cached += delta
            evicted = self._evict_over_budget_locked()
            # Promotion trigger: demand reads (not hinted top-ups) have paid
            # promote_threshold of the payload in round trips — the sparse
            # bet lost, so upgrade via ONE whole-shard GET.  The _promoting
            # guard makes this deterministic under concurrent demand reads:
            # however many cross the threshold at once, exactly one fetch.
            if (
                self.promote_threshold is not None
                and not self._closed
                and name in self._cached  # eviction above may have taken it
                and name not in self._promoting
                and reader.demand_bytes
                >= self.promote_threshold * max(reader.index.payload_bytes, 1)
            ):
                self._promoting.add(name)
                self._bg_inflight += 1
                self._pool.submit(self._promote_task, name, reader)
        self._unlink_evicted(evicted)

    def _replace_with_full(
        self, name: str, reader: MappedShardReader, *, promotion: bool = False
    ) -> None:
        """Install a freshly-persisted full reader over ``name``'s current
        entry (typically its sparse predecessor).  The displaced sparse
        reader is NOT closed — the caller is often one of its in-flight
        demand reads — just dropped; refcounts reclaim its spans."""
        evicted: list[str] = []
        with self._lock:
            if self._closed:
                # shutdown race: don't cache, but leave the reader open for
                # the caller (reclaimed by refcount once dropped)
                return
            entry = self._cached.get(name)
            if entry is not None and isinstance(entry[0], MappedShardReader):
                reader.close()  # lost the race to another full copy
                return
            self.bytes_cached += reader.nbytes - (entry[1] if entry else 0)
            self._cached[name] = (reader, reader.nbytes)
            self._cached.move_to_end(name)  # the shard is hot: refresh LRU
            if promotion:
                self.promotions += 1
            evicted = self._evict_over_budget_locked()
        self._unlink_evicted(evicted)

    def _promote_task(self, name: str, sparse_reader: SparseShardReader) -> None:
        """Sparse→full promotion (pool thread): one whole-shard GET turns a
        demand-chatty sparse entry into a normal disk entry — which a
        ``PeerShardServer`` can then serve whole to other ranks."""
        try:
            with self._lock:
                entry = self._cached.get(name)
                live = (
                    not self._closed
                    and entry is not None
                    and entry[0] is sparse_reader
                )
            if live:
                with _trace.get_tracer().span(f"promote {name}", "shard"):
                    self._replace_with_full(
                        name, self._fetch_full(name), promotion=True
                    )
        except Exception:
            pass  # advisory: the sparse entry keeps serving; demand reads may retrigger
        finally:
            with self._lock:
                self._promoting.discard(name)
                self._bg_inflight -= 1

    def _fetch_and_install(self, name: str, samples=None, fields=None):
        try:
            reader = self._fetch_entry(name, samples, fields)
            self._install(name, reader)
            with self._lock:
                installed = self._cached.get(name)
            # A racing install may have kept a different reader object;
            # always hand back the cached one so there is one live mapping.
            return installed[0] if installed is not None else reader
        finally:
            with self._lock:
                self._inflight.pop(name, None)
                self._bg_inflight -= 1

    def _ensure_task(self, name: str, reader: SparseShardReader, samples) -> None:
        try:
            # projection credit for the top-up: the gap samples' fetch pulls
            # only the projected columns, so the other columns' bytes are
            # skipped wire traffic too (same accounting as the first fetch)
            skipped = 0
            idx = reader.index
            if reader.fields is not None and hasattr(idx, "samples_nbytes"):
                gap = reader.missing(samples)
                if gap:
                    skipped = idx.samples_nbytes(gap, None) - idx.samples_nbytes(
                        gap, reader.fields
                    )
            reader.ensure(samples)
            if skipped > 0:
                with self._lock:
                    self.bytes_skipped += skipped
        except Exception:
            pass  # advisory top-up: demand reads cover whatever is missing
        finally:
            with self._lock:
                self._ensuring.discard(name)
                self._bg_inflight -= 1

    def schedule(self, name: str, samples=None, fields=None) -> bool:
        """Start a background fetch of ``name``; False if dropped (cached,
        already in flight, saturated, or closed).  Saturation counts only
        *background* fetches: a demand fetch runs on its caller's thread,
        so it must not consume a prefetch slot — otherwise a cold-miss
        stall would starve exactly the lookahead meant to prevent the next
        one.

        ``samples`` (shard-local indices the caller will read) feeds the
        index-first sparse/full decision; for an already-cached *sparse*
        entry it instead schedules a background top-up of any hinted
        samples not yet resident.  ``fields`` (columnar shards) projects
        the fetch onto the named columns only."""
        validate_shard_name(name)
        if fields:
            with self._lock:
                self._fields_requested.update(fields)
        with self._lock:
            if self._closed:
                return False
            entry = self._cached.get(name)
            if entry is None:
                if name in self._inflight or self._bg_inflight >= self.max_inflight:
                    return False
                self._bg_inflight += 1
                fut = self._pool.submit(
                    self._fetch_and_install, name, samples, fields
                )
                self._inflight[name] = fut
                return True
            reader = entry[0]
            if (
                not samples
                or not isinstance(reader, SparseShardReader)
                or name in self._ensuring
                or self._bg_inflight >= self.max_inflight
            ):
                return False
        # sparse top-up candidacy: compute missing() OUTSIDE the global lock
        # (it bisects per hinted sample under the reader's own lock — too
        # much work to serialize every concurrent cache hit behind)
        if not reader.missing(samples):
            return False
        with self._lock:
            if (
                self._closed
                or name in self._ensuring
                or self._bg_inflight >= self.max_inflight
            ):
                return False
            self._ensuring.add(name)
            self._bg_inflight += 1
            self._pool.submit(self._ensure_task, name, reader, samples)
        return True

    def reader(
        self, name: str, samples=None, fields=None
    ) -> MappedShardReader | SparseShardReader:
        """Blocking get: the reader for ``name``, fetching on miss.

        Concurrent requests for one shard share a single download: the
        first requester (or an earlier ``schedule``) owns the fetch, later
        ones join its future.  ``samples`` and ``fields`` hints behave as
        in ``schedule`` (they only matter on a miss).
        """
        my_fut: Future | None = None
        if fields:
            with self._lock:
                self._fields_requested.update(fields)
        with self._lock:
            if self._closed:
                raise RuntimeError("ShardPrefetcher is closed")
            entry = self._cached.get(name)
            if entry is not None:
                # hit path: no name validation — everything in _cached came
                # through a validated fetch.  Skip the LRU shuffle when the
                # name is already most-recent (the sequential common case).
                if next(reversed(self._cached)) != name:
                    self._cached.move_to_end(name)  # refresh LRU position
                self.hits += 1
                tracer = _trace.get_tracer()
                if tracer.enabled:
                    tracer.instant("cache:hit", "shard", {"shard": name})
                return entry[0]
            validate_shard_name(name)
            self.misses += 1
            tracer = _trace.get_tracer()
            if tracer.enabled:
                tracer.instant("cache:miss", "shard", {"shard": name})
            fut = self._inflight.get(name)
            if fut is None:
                my_fut = self._inflight[name] = Future()
        if my_fut is None:
            try:
                return fut.result()  # join the in-flight fetch
            except CancelledError:
                # close() cancelled the queued background fetch we joined;
                # surface the documented shutdown error, not pool internals
                raise RuntimeError("ShardPrefetcher is closed") from None
        try:
            reader = self._fetch_entry(name, samples, fields)
            self._install(name, reader)
            with self._lock:
                installed = self._cached.get(name)
            result = installed[0] if installed is not None else reader
            my_fut.set_result(result)
            return result
        except BaseException as e:
            my_fut.set_exception(e)
            raise
        finally:
            with self._lock:
                self._inflight.pop(name, None)

    def peek(self, name: str) -> ShardReader | SparseShardReader | None:
        """Non-mutating cache lookup — the ``PeerShardServer`` read path.

        Returns the resident reader or ``None``: no hit/miss accounting, no
        LRU refresh, and **never a fetch** — a peer asking for a shard must
        not make THIS rank download anything on its behalf."""
        with self._lock:
            if self._closed:
                return None
            entry = self._cached.get(name)
            return entry[0] if entry is not None else None

    # -- warm restart --------------------------------------------------------
    # A restarted rank re-fetching shards it already paid for is the
    # ROADMAP carry-over this closes: full entries are already durable
    # cache files (fsync+rename at _persist), so the manifest only has to
    # name them; sparse entries additionally persist their resident spans
    # to a ``.warm/<name>.spans`` sidecar.  Sidecar layout::
    #
    #     RPWARM01 | u32 meta_len | meta JSON | header | index | spans | u32 crc
    #
    # with the crc32 over everything between magic and trailer — a torn
    # sidecar (crash mid-rename is already impossible; crash mid-*write*
    # leaves a .part file we never read) or a hand-damaged one fails the
    # crc and is skipped, never trusted.

    def _write_atomic(self, path: pathlib.Path, data: bytes) -> None:
        """The PR-3 crash-safety pattern: write + fsync a unique temp, then
        atomically rename over the target."""
        tmp = path.with_suffix(f"{path.suffix}.{threading.get_ident():x}.part")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        tmp.replace(path)

    @staticmethod
    def _encode_sparse(reader: SparseShardReader) -> bytes | None:
        spans = reader.spans_snapshot()
        try:
            header = bytes(reader.index.header_bytes())
            index_bytes = bytes(reader.index.index_bytes())
        except Exception:
            return None
        meta = {
            "name": reader.name,
            "fields": list(reader.fields) if reader.fields is not None else None,
            "index_len": len(index_bytes),
            "spans": [[int(s), len(d)] for s, d in spans],
        }
        meta_blob = json.dumps(meta).encode()
        parts = [struct.pack("<I", len(meta_blob)), meta_blob, header, index_bytes]
        parts.extend(bytes(d) for _, d in spans)
        payload = b"".join(parts)
        return _WARM_MAGIC + payload + struct.pack("<I", zlib.crc32(payload))

    def _restore_sparse(self, name: str, blob: bytes) -> SparseShardReader:
        if len(blob) < len(_WARM_MAGIC) + 8 or not blob.startswith(_WARM_MAGIC):
            raise ValueError(f"{name}: not a warm-restart sidecar")
        payload = blob[len(_WARM_MAGIC) : -4]
        (crc,) = struct.unpack("<I", blob[-4:])
        if zlib.crc32(payload) != crc:
            raise ValueError(f"{name}: sidecar crc mismatch (torn write?)")
        (meta_len,) = struct.unpack_from("<I", payload, 0)
        off = 4
        meta = json.loads(payload[off : off + meta_len])
        off += meta_len
        if meta.get("name") != name:
            raise ValueError(f"{name}: sidecar names {meta.get('name')!r}")
        header = payload[off : off + HEADER_SIZE]
        off += HEADER_SIZE
        index_len = int(meta["index_len"])
        index_bytes = payload[off : off + index_len]
        off += index_len
        version, _n, _index_off, _payload_off = parse_shard_header(header, name)
        if version >= FORMAT_VERSION_V2:
            idx = ShardIndexV2.parse(header, index_bytes, name)
        else:
            idx = ShardIndex.parse(header, index_bytes, name)
        fields = tuple(meta["fields"]) if meta.get("fields") else None
        reader = SparseShardReader(
            name,
            idx,
            functools.partial(self._range_fetch, name),
            coalesce_gap=self.coalesce_gap,
            fields=fields,
        )
        spans: list[tuple[int, bytes]] = []
        for start, ln in meta.get("spans", ()):
            spans.append((int(start), payload[off : off + int(ln)]))
            off += int(ln)
        if off != len(payload):
            raise ValueError(f"{name}: sidecar length mismatch")
        reader.restore_spans(spans)
        with self._lock:
            self._indexes.setdefault(name, idx)
        return reader

    def save_state(self) -> int:
        """Persist the cache manifest + sparse sidecars under
        ``cache_dir/.warm``; returns the number of entries saved.  Called
        automatically from ``close()`` when ``persist_state=True``; safe to
        call mid-run for checkpoint-style durability."""
        self._state_dir.mkdir(parents=True, exist_ok=True)
        with self._lock:
            snapshot = [(name, r) for name, (r, _) in self._cached.items()]
        entries: list[dict] = []
        kept: set[str] = set()
        for name, reader in snapshot:  # LRU order, oldest first
            if isinstance(reader, MappedShardReader):
                # the cache file IS the durable state; just index it
                if (self.cache_dir / name).exists():
                    entries.append({"name": name, "kind": "full"})
            elif isinstance(reader, SparseShardReader):
                blob = self._encode_sparse(reader)
                if blob is None:
                    continue
                side = self._state_dir / f"{name}.spans"
                self._write_atomic(side, blob)
                kept.add(side.name)
                entries.append({"name": name, "kind": "sparse"})
        manifest = {
            "version": 1,
            "verified": bool(self.verify_on_install),
            "entries": entries,
        }
        self._write_atomic(
            self._state_dir / _WARM_MANIFEST,
            json.dumps(manifest, indent=1).encode(),
        )
        # prune sidecars for entries that no longer exist (evicted/promoted)
        for p in self._state_dir.glob("*.spans"):
            if p.name not in kept:
                p.unlink(missing_ok=True)
        return len(entries)

    def _restore_state(self) -> None:
        """Re-open the previous run's resident entries (constructor path —
        single-threaded, cache empty).  Every entry is best-effort: a
        missing file, torn sidecar, or corrupt shard is skipped and simply
        re-fetched on demand like any cold shard."""
        manifest_path = self._state_dir / _WARM_MANIFEST
        try:
            manifest = json.loads(manifest_path.read_text())
        except (OSError, ValueError):
            return
        if manifest.get("version") != 1:
            return
        prior_verified = bool(manifest.get("verified"))
        reused = 0
        for entry in manifest.get("entries", ()):  # oldest-first keeps LRU
            name, kind = entry.get("name"), entry.get("kind")
            if not name:
                continue
            try:
                validate_shard_name(name)
                if kind == "full":
                    reader = open_shard_reader(self.cache_dir / name)
                    if self.verify_on_install and not prior_verified:
                        bad = reader.verify_all()
                        if bad:
                            logger.warning(
                                "shard %s: %d corrupt sample(s) at warm restart",
                                name, bad,
                            )
                            with self._lock:
                                self.corrupt_samples += bad
                elif kind == "sparse":
                    side = self._state_dir / f"{name}.spans"
                    reader = self._restore_sparse(name, side.read_bytes())
                else:
                    continue
            except Exception:
                continue
            nbytes = reader.nbytes
            self._install(name, reader)
            with self._lock:
                installed = self._cached.get(name)
                if installed is not None and installed[0] is reader:
                    reused += nbytes
        if reused:
            with self._lock:
                self.warm_restart_bytes_reused += reused
            tracer = _trace.get_tracer()
            if tracer.enabled:
                tracer.instant(
                    "cache:warm-restart", "shard", {"bytes_reused": reused}
                )

    # -- visibility / lifecycle --------------------------------------------
    @property
    def prefetch_depth(self) -> int:
        """In-flight *background* fetches (demand fetches excluded — they
        run on their caller's thread, not the prefetch pool)."""
        with self._lock:
            return self._bg_inflight

    def stats(self) -> dict[str, float]:
        with self._lock:
            out = {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "bytes_cached": self.bytes_cached,
                "max_bytes": self.max_bytes,
                "prefetch_depth": self._bg_inflight,
                "fetch_time": self.fetch_time,
                "bytes_fetched": self.bytes_fetched,
                "bytes_skipped": self.bytes_skipped,
                "fields_requested": len(self._fields_requested),
                "index_fetches": self.index_fetches,
                "range_fetches": self.range_fetches,
                "promotions": self.promotions,
                "corrupt_samples": self.corrupt_samples,
                "warm_restart_bytes_reused": self.warm_restart_bytes_reused,
                "sparse_shards": sum(
                    1
                    for r, _ in self._cached.values()
                    if isinstance(r, SparseShardReader)
                ),
            }
        source_stats = getattr(self.source, "stats", None)
        if callable(source_stats):
            for k, v in source_stats().items():
                out[f"source_{k}"] = v
        return out

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # Queued-but-unstarted background fetches are cancelled by the pool
        # shutdown (joiners of a cancelled future get the documented
        # RuntimeError, translated in ``reader``); running ones finish
        # (their install no-ops once closed).  Demand-fetch futures in
        # ``_inflight`` are hand-made and owned by the fetching thread —
        # cancelling them here would make that thread's set_result() blow
        # up with InvalidStateError, so they are left to complete.
        self._pool.shutdown(wait=True, cancel_futures=True)
        if self.persist_state:
            # after the pool drains (cache content settled), before readers
            # close (sparse snapshots need their spans still resident)
            try:
                self.save_state()
            except Exception:
                logger.warning(
                    "failed to persist warm-restart state", exc_info=True
                )
        with self._lock:
            for reader, _ in self._cached.values():
                reader.close()
            self._cached.clear()
            self._indexes.clear()
            self.bytes_cached = 0
        source_close = getattr(self.source, "close", None)
        if callable(source_close):
            source_close()

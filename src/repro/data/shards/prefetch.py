"""Async shard prefetch + bounded local shard cache over a remote source.

SPDL's pipeline overlaps network, CPU, and GPU *within* a sample stream;
this module applies the same overlap at shard granularity for remote or
high-latency storage: while the decode stages chew on shard *k*, the
prefetcher is already pulling shards *k+1..k+d* into a local byte-budgeted
cache, so the read stage almost never blocks on the network.

Pieces:

``RemoteShardSource``      duck-typed backend: ``fetch(name) -> bytes``.
``LocalShardSource``       trivial backend reading files from a directory
                           (also the base other sources usually wrap).
``SimulatedLatencySource`` wraps a source with a per-fetch latency floor +
                           bandwidth cap — a deterministic stand-in for
                           object storage in tests and benchmarks.
``ShardPrefetcher``        the cache + scheduler: LRU-by-bytes local cache
                           of fetched shard files, fetch dedup (concurrent
                           requests for one shard share one download), and
                           a bounded background fetch pool whose in-flight
                           count is the ``prefetch_depth`` stat.

Eviction contract: evicting a shard unlinks its cache file and drops the
reader.  In-flight ``memoryview`` reads stay valid — on Linux the mapping
outlives the unlink and the pages are reclaimed when the last view drops —
so eviction can never corrupt a sample that is mid-decode.

Stats (``stats()``) feed the pipeline dashboard: ``hits``/``misses`` per
*reader* request (a prefetched shard counts as a hit — that is the point),
``evictions``, ``bytes_cached``, ``prefetch_depth``, and cumulative
``fetch_time`` seconds spent downloading.
"""

from __future__ import annotations

import pathlib
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor

from .dataset import MANIFEST_NAME
from .format import ShardReader


class LocalShardSource:
    """Reads shard files from a local directory (the trivial backend)."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)

    def fetch(self, name: str) -> bytes:
        return (self.root / name).read_bytes()


class SimulatedLatencySource:
    """A ``RemoteShardSource`` with object-storage-shaped costs.

    Each fetch pays ``latency_s`` (request round-trip) plus
    ``nbytes / bandwidth_bps`` (transfer), then returns the inner source's
    bytes.  ``fetches``/``bytes_fetched`` make tests assert exactly how
    often the network was touched.
    """

    def __init__(
        self,
        inner,
        *,
        latency_s: float = 0.01,
        bandwidth_bps: float | None = None,
    ):
        self.inner = inner
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        self.fetches = 0
        self.bytes_fetched = 0
        self._lock = threading.Lock()

    def fetch(self, name: str) -> bytes:
        data = self.inner.fetch(name)
        delay = self.latency_s
        if self.bandwidth_bps:
            delay += len(data) / self.bandwidth_bps
        if delay > 0:
            time.sleep(delay)
        with self._lock:
            self.fetches += 1
            self.bytes_fetched += len(data)
        return data


class ShardPrefetcher:
    """Bounded local shard cache + background fetch scheduler.

    ``reader(name)`` is the synchronous path the dataset uses: cache hit →
    mmap reader immediately; miss → fetch (joining an in-flight background
    fetch if one exists), install, evict LRU shards past ``max_bytes``.

    ``schedule(name)`` is the asynchronous path the loader uses: start a
    background fetch (up to ``max_inflight`` concurrent) unless the shard is
    already cached or being fetched.  Scheduling is advisory — dropping a
    request is always safe because ``reader`` fetches on demand.
    """

    def __init__(
        self,
        source,
        cache_dir: str | pathlib.Path,
        *,
        max_bytes: int = 1 << 30,
        max_inflight: int = 2,
    ):
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.source = source
        self.cache_dir = pathlib.Path(cache_dir)
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        self.max_bytes = max_bytes
        self.max_inflight = max_inflight
        self._pool = ThreadPoolExecutor(
            max_workers=max_inflight, thread_name_prefix="shard-prefetch"
        )
        self._lock = threading.Lock()
        # name -> (reader, nbytes); insertion order is the LRU order
        self._cached: OrderedDict[str, tuple[ShardReader, int]] = OrderedDict()
        self._inflight: dict[str, Future] = {}
        self._bg_inflight = 0  # pool fetches only (demand fetches excluded)
        self._closed = False
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_cached = 0
        self.fetch_time = 0.0

    # -- manifest -----------------------------------------------------------
    def fetch_manifest(self) -> bytes:
        """The dataset manifest comes over the same wire as the shards."""
        return self.source.fetch(MANIFEST_NAME)

    # -- fetch machinery ----------------------------------------------------
    def _fetch_to_cache(self, name: str) -> ShardReader:
        """Download one shard, persist it, open a reader (pool thread)."""
        t0 = time.monotonic()
        data = self.source.fetch(name)
        path = self.cache_dir / name
        # unique temp per fetch: two racing fetches of one shard must not
        # share a staging file (the loser's replace() would find it gone)
        tmp = path.with_suffix(
            f"{path.suffix}.{threading.get_ident():x}.part"
        )
        tmp.write_bytes(data)
        tmp.replace(path)  # atomic: a reader never sees a torn file
        reader = ShardReader(path)
        with self._lock:
            self.fetch_time += time.monotonic() - t0
        return reader

    def _install(self, name: str, reader: ShardReader) -> None:
        """Insert a fetched shard and evict LRU past the byte budget."""
        evicted: list[str] = []
        with self._lock:
            if name in self._cached:
                reader.close()  # lost an install race: keep the first copy
                return
            if self._closed:
                # Shutdown mid-fetch: don't cache, but leave the reader
                # open — the demand caller may still be about to use it
                # (it is reclaimed by refcount once dropped).
                return
            self._cached[name] = (reader, reader.nbytes)
            self.bytes_cached += reader.nbytes
            while self.bytes_cached > self.max_bytes and len(self._cached) > 1:
                old_name, (_old_reader, nbytes) = self._cached.popitem(last=False)
                self.bytes_cached -= nbytes
                self.evictions += 1
                evicted.append(old_name)
        for old_name in evicted:
            # Unlink the file but do NOT close the reader: a concurrent
            # ``read_bytes`` may hold it (or views into it) right now.  The
            # mapping is dropped by refcount once the last holder lets go,
            # and the disk space returns with it (Linux unlink semantics).
            # Re-check under the lock first: the shard may have been
            # re-fetched since we evicted it, in which case the file on
            # disk is the NEWER copy and belongs to that install (every
            # path write is covered by _inflight membership until its
            # install lands in _cached, so this check is race-free).
            with self._lock:
                if old_name in self._cached or old_name in self._inflight:
                    continue
                (self.cache_dir / old_name).unlink(missing_ok=True)

    def _fetch_and_install(self, name: str) -> ShardReader:
        try:
            reader = self._fetch_to_cache(name)
            self._install(name, reader)
            with self._lock:
                installed = self._cached.get(name)
            # A racing install may have kept a different reader object;
            # always hand back the cached one so there is one live mapping.
            return installed[0] if installed is not None else reader
        finally:
            with self._lock:
                self._inflight.pop(name, None)
                self._bg_inflight -= 1

    def schedule(self, name: str) -> bool:
        """Start a background fetch of ``name``; False if dropped (cached,
        already in flight, saturated, or closed).  Saturation counts only
        *background* fetches: a demand fetch runs on its caller's thread,
        so it must not consume a prefetch slot — otherwise a cold-miss
        stall would starve exactly the lookahead meant to prevent the next
        one."""
        with self._lock:
            if (
                self._closed
                or name in self._cached
                or name in self._inflight
                or self._bg_inflight >= self.max_inflight
            ):
                return False
            self._bg_inflight += 1
            fut = self._pool.submit(self._fetch_and_install, name)
            self._inflight[name] = fut
        return True

    def reader(self, name: str) -> ShardReader:
        """Blocking get: the mmap reader for ``name``, fetching on miss.

        Concurrent requests for one shard share a single download: the
        first requester (or an earlier ``schedule``) owns the fetch, later
        ones join its future.
        """
        my_fut: Future | None = None
        with self._lock:
            if self._closed:
                raise RuntimeError("ShardPrefetcher is closed")
            entry = self._cached.get(name)
            if entry is not None:
                self._cached.move_to_end(name)  # refresh LRU position
                self.hits += 1
                return entry[0]
            self.misses += 1
            fut = self._inflight.get(name)
            if fut is None:
                my_fut = self._inflight[name] = Future()
        if my_fut is None:
            return fut.result()  # join the in-flight fetch
        try:
            reader = self._fetch_to_cache(name)
            self._install(name, reader)
            with self._lock:
                installed = self._cached.get(name)
            result = installed[0] if installed is not None else reader
            my_fut.set_result(result)
            return result
        except BaseException as e:
            my_fut.set_exception(e)
            raise
        finally:
            with self._lock:
                self._inflight.pop(name, None)

    # -- visibility / lifecycle --------------------------------------------
    @property
    def prefetch_depth(self) -> int:
        """In-flight *background* fetches (demand fetches excluded — they
        run on their caller's thread, not the prefetch pool)."""
        with self._lock:
            return self._bg_inflight

    def stats(self) -> dict[str, float]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "bytes_cached": self.bytes_cached,
                "max_bytes": self.max_bytes,
                "prefetch_depth": self._bg_inflight,
                "fetch_time": self.fetch_time,
            }

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # Queued-but-unstarted background fetches are cancelled by the pool
        # shutdown; running ones finish (their install no-ops once closed).
        # Demand-fetch futures in ``_inflight`` are hand-made and owned by
        # the fetching thread — cancelling them here would make that
        # thread's set_result() blow up with InvalidStateError, so they are
        # left to complete on their own.
        self._pool.shutdown(wait=True, cancel_futures=True)
        with self._lock:
            for reader, _ in self._cached.values():
                reader.close()
            self._cached.clear()
            self.bytes_cached = 0

"""In-process HTTP shard server: the *origin* fixture behind HTTP-backend
tests, ``benchmarks/bench_shards.py``, and ``examples/imagenet_pipeline.py``
(it serves a shard *directory*, modeling the object store; the production
peer tier that serves a live prefetcher's warm cache grew out of this into
``peer.PeerShardServer``).

Pure stdlib (``http.server``) so the suite needs no extra dependency, but
with the two behaviors a real object-store front end has that
``SimpleHTTPRequestHandler`` lacks:

* ``Range: bytes=a-b`` → ``206 Partial Content`` (the thing index-first
  fetch exists to exploit) — disable with ``support_ranges=False`` to model
  a server that ignores Range and always sends the full body;
* keep-alive (HTTP/1.1 + explicit ``Content-Length``) so connection-reuse
  in ``HttpShardSource`` is actually exercised.

Observability for assertions: ``requests``, ``bytes_served``,
``connections`` counters, and ``fail_next = N`` to answer the next N
requests with 503 (drives the retry/backoff path deterministically).

Chaos faults (the fault-injection layer behind ``benchmarks/bench_faults``
and ``tests/test_faults.py``) — all default off, all settable live:

* ``fail_next = N`` — answer the next N requests with 503 (pre-existing);
* ``flaky_rate = p`` — answer each request with 503 with probability ``p``
  from the server's seeded ``chaos_rng`` (reproducible flakiness);
* ``stall_next = N`` / ``stall_s`` — sleep ``stall_s`` before answering
  the next N requests (a slow/unresponsive server, triggers client
  timeouts and hedging);
* ``truncate_next = N`` — advertise the full ``Content-Length`` but close
  the connection mid-body for the next N requests (the mid-body
  disconnect that must surface as ``SourceUnavailable``, never as a
  short installed payload);
* ``slow_bps = B`` — throttle every body write to ``B`` bytes/second (a
  bandwidth-starved origin or slow peer);
* ``kill()`` — process death: stop accepting AND sever in-flight
  keep-alive connections (``shutdown()`` alone leaves persistent
  connections serviceable, which is a restart, not a crash).

Counters for assertions: ``stalls``, ``truncations``, ``flaky_failures``.

Telemetry: pass ``metrics=`` (a ``core.metrics.MetricsExporter``) to mount
``GET /metrics`` on the same port — Prometheus text scrapes ride the shard
port, and deliberately bypass the request counters and chaos faults so a
scrape never perturbs a test's assertions or consumes a fault budget.

Admission control: pass ``admission=`` (a
``membership.AdmissionController``) to gate requests the way the
production peer tier does — a request over the max-inflight cap, or a
body that would bust its tenant's (``X-Tenant`` header) token-bucket
quota, answers a structured ``429`` + ``Retry-After`` instead of data.
The origin fixture gets this so the admission path can be exercised and
benchmarked without a peer fleet.
"""

from __future__ import annotations

import contextlib
import http.server
import pathlib
import random
import re
import socket
import threading
import time
import urllib.parse

from ...core.metrics import CONTENT_TYPE_LATEST as _METRICS_CONTENT_TYPE
from .membership import TENANT_HEADER

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d+)?$")


class _ShardRequestHandler(http.server.BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: connection reuse is real
    server_version = "ShardHTTP/1"

    def setup(self) -> None:
        super().setup()
        srv = self.server
        with srv.lock:
            srv.connections += 1

    def _send(self, status: int, body: bytes, extra: dict | None = None) -> None:
        self.send_response(status)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self._write_body(body)
        with self.server.lock:
            self.server.bytes_served += len(body)

    def _write_body(self, body: bytes) -> None:
        bps = self.server.slow_bps
        if not bps or not body:
            self.wfile.write(body)
            return
        # bandwidth throttle: write in slices, sleeping each one's cost
        step = max(1, int(bps * 0.05))  # ~20 writes/second granularity
        for off in range(0, len(body), step):
            piece = body[off : off + step]
            self.wfile.write(piece)
            self.wfile.flush()
            time.sleep(len(piece) / bps)

    def _send_truncated(self, status: int, body: bytes, extra: dict | None) -> None:
        """Mid-body disconnect: advertise the full Content-Length, write
        half the body, then drop the connection — the client's read sees
        an IncompleteRead, never a clean short body."""
        self.send_response(status)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body[: len(body) // 2])
        self.wfile.flush()
        self.close_connection = True
        with contextlib.suppress(OSError):
            self.connection.shutdown(socket.SHUT_RDWR)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        srv = self.server
        if srv.dead:
            # killed server: drop the socket without an HTTP response so
            # reused keep-alive connections see a reset, not a clean 5xx
            self.close_connection = True
            with contextlib.suppress(OSError):
                self.connection.shutdown(socket.SHUT_RDWR)
            return
        # /metrics is reserved (never a shard name) and served outside the
        # chaos/counter path: a scrape must not consume a fault budget
        if self.path.split("?", 1)[0] == "/metrics" and srv.metrics is not None:
            self._send(
                200,
                srv.metrics.render().encode(),
                {"Content-Type": _METRICS_CONTENT_TYPE},
            )
            return
        adm = srv.admission
        if adm is not None and not adm.start_request():
            # over the inflight cap: structured throttle, never a hang
            self._send(
                429, b"at capacity", {"Retry-After": f"{adm.retry_wait_s:.3f}"}
            )
            return
        try:
            self._serve_checked()
        finally:
            if adm is not None:
                adm.end_request()

    def _admit(self, nbytes: int) -> bool:
        """Tenant quota gate just before a body send; False means a 429 +
        Retry-After already went out."""
        adm = self.server.admission
        if adm is None:
            return True
        tenant = self.headers.get(TENANT_HEADER, "default")
        wait = adm.admit(tenant, nbytes)
        if wait is None:
            return True
        self._send(429, b"over quota", {"Retry-After": f"{wait:.3f}"})
        return False

    def _serve_checked(self) -> None:
        srv = self.server
        with srv.lock:
            srv.requests += 1
            fail = srv.fail_next > 0
            if fail:
                srv.fail_next -= 1
            elif srv.flaky_rate > 0 and srv.chaos_rng.random() < srv.flaky_rate:
                fail = True
                srv.flaky_failures += 1
            stall = srv.stall_next > 0
            if stall:
                srv.stall_next -= 1
                srv.stalls += 1
            truncate = srv.truncate_next > 0
            if truncate:
                srv.truncate_next -= 1
                # counted at decision time: the client can see the severed
                # socket before the handler thread runs another line
                srv.truncations += 1
        if stall:
            time.sleep(srv.stall_s)
        if fail:
            self._send(503, b"injected failure")
            return
        # resolve strictly within the served root (the server side of the
        # same traversal defense the shard cache applies to names)
        rel = urllib.parse.unquote(self.path.lstrip("/"))
        path = (srv.root / rel).resolve()
        if srv.root not in path.parents and path != srv.root:
            self._send(404, b"")
            return
        if not path.is_file():
            self._send(404, b"")
            return
        data = path.read_bytes()
        range_header = self.headers.get("Range")
        if range_header and srv.support_ranges:
            m = _RANGE_RE.match(range_header.strip())
            if m:
                start = int(m.group(1))
                end = int(m.group(2)) if m.group(2) is not None else len(data) - 1
                if start >= len(data):
                    self._send(
                        416, b"", {"Content-Range": f"bytes */{len(data)}"}
                    )
                    return
                end = min(end, len(data) - 1)
                body = data[start : end + 1]
                extra = {"Content-Range": f"bytes {start}-{end}/{len(data)}"}
                if not self._admit(len(body)):
                    return
                if truncate:
                    self._send_truncated(206, body, extra)
                else:
                    self._send(206, body, extra)
                return
        if not self._admit(len(data)):
            return
        if truncate:
            self._send_truncated(200, data, None)
        else:
            self._send(200, data)

    def log_message(self, *args) -> None:  # quiet: tests read counters
        pass


class ShardHTTPServer(http.server.ThreadingHTTPServer):
    """Serves a shard directory; counters under ``lock`` for assertions."""

    daemon_threads = True

    def __init__(
        self,
        root: str | pathlib.Path,
        *,
        support_ranges: bool = True,
        chaos_seed: int = 0,
        metrics=None,
        admission=None,
    ):
        self.root = pathlib.Path(root).resolve()
        self.support_ranges = support_ranges
        # optional core.metrics.MetricsExporter mounted at GET /metrics
        self.metrics = metrics
        # optional membership.AdmissionController gating every request
        self.admission = admission
        self.lock = threading.Lock()
        self.requests = 0
        self.bytes_served = 0
        self.connections = 0
        self.fail_next = 0
        # chaos faults (all off by default; see module docstring)
        self.chaos_rng = random.Random(chaos_seed)
        self.flaky_rate = 0.0
        self.stall_next = 0
        self.stall_s = 0.5
        self.truncate_next = 0
        self.slow_bps: int | None = None
        self.stalls = 0
        self.truncations = 0
        self.flaky_failures = 0
        self.dead = False
        super().__init__(("127.0.0.1", 0), _ShardRequestHandler)

    def kill(self) -> None:
        """Model peer/origin *death* (not graceful restart): stop accepting
        new connections and make every in-flight keep-alive connection fail
        at the transport level on its next request."""
        self.dead = True
        self.shutdown()
        self.server_close()

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


@contextlib.contextmanager
def serve_shards(
    root: str | pathlib.Path,
    *,
    support_ranges: bool = True,
    chaos_seed: int = 0,
    metrics=None,
    admission=None,
):
    """Context manager: serve ``root`` on a loopback port; yields the server
    (use ``server.url`` as an ``HttpShardSource`` root)."""
    server = ShardHTTPServer(
        root, support_ranges=support_ranges, chaos_seed=chaos_seed,
        metrics=metrics, admission=admission,
    )
    thread = threading.Thread(
        target=server.serve_forever, name="shard-http", daemon=True
    )
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)

"""In-process HTTP shard server: the *origin* fixture behind HTTP-backend
tests, ``benchmarks/bench_shards.py``, and ``examples/imagenet_pipeline.py``
(it serves a shard *directory*, modeling the object store; the production
peer tier that serves a live prefetcher's warm cache grew out of this into
``peer.PeerShardServer``).

Pure stdlib (``http.server``) so the suite needs no extra dependency, but
with the two behaviors a real object-store front end has that
``SimpleHTTPRequestHandler`` lacks:

* ``Range: bytes=a-b`` → ``206 Partial Content`` (the thing index-first
  fetch exists to exploit) — disable with ``support_ranges=False`` to model
  a server that ignores Range and always sends the full body;
* keep-alive (HTTP/1.1 + explicit ``Content-Length``) so connection-reuse
  in ``HttpShardSource`` is actually exercised.

Observability for assertions: ``requests``, ``bytes_served``,
``connections`` counters, and ``fail_next = N`` to answer the next N
requests with 503 (drives the retry/backoff path deterministically).
"""

from __future__ import annotations

import contextlib
import http.server
import pathlib
import re
import threading
import urllib.parse

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d+)?$")


class _ShardRequestHandler(http.server.BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"  # keep-alive: connection reuse is real
    server_version = "ShardHTTP/1"

    def setup(self) -> None:
        super().setup()
        srv = self.server
        with srv.lock:
            srv.connections += 1

    def _send(self, status: int, body: bytes, extra: dict | None = None) -> None:
        self.send_response(status)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)
        with self.server.lock:
            self.server.bytes_served += len(body)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        srv = self.server
        with srv.lock:
            srv.requests += 1
            fail = srv.fail_next > 0
            if fail:
                srv.fail_next -= 1
        if fail:
            self._send(503, b"injected failure")
            return
        # resolve strictly within the served root (the server side of the
        # same traversal defense the shard cache applies to names)
        rel = urllib.parse.unquote(self.path.lstrip("/"))
        path = (srv.root / rel).resolve()
        if srv.root not in path.parents and path != srv.root:
            self._send(404, b"")
            return
        if not path.is_file():
            self._send(404, b"")
            return
        data = path.read_bytes()
        range_header = self.headers.get("Range")
        if range_header and srv.support_ranges:
            m = _RANGE_RE.match(range_header.strip())
            if m:
                start = int(m.group(1))
                end = int(m.group(2)) if m.group(2) is not None else len(data) - 1
                if start >= len(data):
                    self._send(
                        416, b"", {"Content-Range": f"bytes */{len(data)}"}
                    )
                    return
                end = min(end, len(data) - 1)
                body = data[start : end + 1]
                self._send(
                    206,
                    body,
                    {"Content-Range": f"bytes {start}-{end}/{len(data)}"},
                )
                return
        self._send(200, data)

    def log_message(self, *args) -> None:  # quiet: tests read counters
        pass


class ShardHTTPServer(http.server.ThreadingHTTPServer):
    """Serves a shard directory; counters under ``lock`` for assertions."""

    daemon_threads = True

    def __init__(self, root: str | pathlib.Path, *, support_ranges: bool = True):
        self.root = pathlib.Path(root).resolve()
        self.support_ranges = support_ranges
        self.lock = threading.Lock()
        self.requests = 0
        self.bytes_served = 0
        self.connections = 0
        self.fail_next = 0
        super().__init__(("127.0.0.1", 0), _ShardRequestHandler)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


@contextlib.contextmanager
def serve_shards(root: str | pathlib.Path, *, support_ranges: bool = True):
    """Context manager: serve ``root`` on a loopback port; yields the server
    (use ``server.url`` as an ``HttpShardSource`` root)."""
    server = ShardHTTPServer(root, support_ranges=support_ranges)
    thread = threading.Thread(
        target=server.serve_forever, name="shard-http", daemon=True
    )
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()
        thread.join(timeout=5)
